"""Perf hillclimb driver (§Perf): compile tagged variants of the three
chosen cells and report roofline-term deltas vs the swept baseline.

    PYTHONPATH=src python tools/hillclimb.py [--cell gemma_long] [--variant v1_ring]

Each variant is (hp overrides, sharding-table overrides, model-config
overrides) — the three levers the framework exposes. Results land in
results/perf/<cell>__<variant>.json; EXPERIMENTS.md §Perf narrates the
hypothesis -> measurement log.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# must import dryrun FIRST: it sets XLA_FLAGS before jax init
from repro.launch import dryrun  # noqa: E402
from repro.train.step import TrainHParams  # noqa: E402

import argparse  # noqa: E402
import json  # noqa: E402


CELLS = {
    # worst roofline fraction + collective-bound decode
    "gemma_long": ("gemma3_1b", "long_500k"),
    # most collective-bound heavy training cell
    "llama4_train": ("llama4_maverick_400b_a17b", "train_4k"),
    # most representative of the paper's technique (bkm router every layer)
    "granite_train": ("granite_moe_3b_a800m", "train_4k"),
}

HP_400B = dict(microbatches=2, grad_acc_dtype="bfloat16")

VARIANTS = {
    "gemma_long": {
        # H1: the swa layers' 512k-long caches are gathered/streamed per
        # layer; a window ring cache cuts their bytes+wire by S/window=1024x
        "v1_ring": dict(cfg_overrides={"swa_ring_cache": True}),
        # H1 partially refuted: wire is ~all FSDP weight all-gathers (27/
        # layer-group, ~36 MB each). For B=1 decode of a 1.3B model the
        # weights fit per-chip sharded over `model` alone -> keep them
        # resident (embed axis unsharded), zero weight collectives.
        "v2_resident_weights": dict(cfg_overrides={"swa_ring_cache": True},
                                    overrides={"embed": None}),
        # H1c: remaining wire = w_down all-gathers forced by the replicated
        # MLP intermediate (act_mlp=None under seq-SP decode). Shard h over
        # `model` -> contraction psums a [1,1,d] vector (4.6 KB) instead of
        # gathering a 32 MB weight per layer.
        "v3_mlp_tp": dict(cfg_overrides={"swa_ring_cache": True},
                          overrides={"embed": None, "act_mlp": "model"}),
        # H1d: now memory-bound on reading f32 weights; serve in bf16
        # (halves the dominant term; standard serving precision)
        "v4_bf16_weights": dict(
            cfg_overrides={"swa_ring_cache": True,
                           "param_dtype": "bfloat16"},
            overrides={"embed": None, "act_mlp": "model"}),
    },
    "llama4_train": {
        # H2: DP gradient reduction dominates wire; int8+error-feedback
        # halves it vs the bf16 baseline accumulator
        "v1_int8grad": dict(hp=TrainHParams(grad_compress="int8", **HP_400B)),
        # H3: per-layer FSDP all-gather of expert weights is the other big
        # contributor; keeping experts resident (sharded expert x e_mlp)
        # trades it for small activation psums
        "v2_resident_experts": dict(
            hp=TrainHParams(**HP_400B),
            overrides={"e_embed": None, "e_mlp": "data"}),
        "v3_both": dict(
            hp=TrainHParams(grad_compress="int8", **HP_400B),
            overrides={"e_embed": None, "e_mlp": "data"}),
        # H4: top-1 routing under the paper's influence balancing stays
        # near target load -> drop capacity factor 1.25 -> 1.0 (-20% expert
        # compute/dispatch) on top of resident experts
        "v4_capacity1": dict(
            hp=TrainHParams(**HP_400B),
            overrides={"e_embed": None, "e_mlp": "data"},
            cfg_overrides={}),  # moe cf=1.0 filled in main()
        # H7: FSDP weight all-gathers repeat per microbatch; a single
        # microbatch halves them IF the activation footprint still fits
        # (resident experts + cf=1.0 freed headroom)
        "v5_mb1": dict(
            hp=TrainHParams(microbatches=1, grad_acc_dtype="bfloat16"),
            overrides={"e_embed": None, "e_mlp": "data"},
            cfg_overrides={}),
    },
    "granite_train": {
        "v1_int8grad": dict(hp=TrainHParams(grad_compress="int8")),
        # H5: the paper's influence balancing keeps realized loads near
        # target, so expert capacity (and with it dispatch memory + expert
        # FLOPs) can drop from 1.25x to 1.0x without meaningful drops
        # (benchmarks/moe_router.py measures the drop rate)
        "v2_capacity1": dict(cfg_overrides={
            "moe": None}),  # placeholder replaced below (nested dataclass)
        "v3_both": dict(hp=TrainHParams(grad_compress="int8"),
                        cfg_overrides={"moe": None}),
        # H6: memory-bound -> cut traffic: (a) drop remat (3B model has HBM
        # headroom; removes the recompute pass), (b) never materialize the
        # K=8-times repeated dispatch source (gather via idx//K)
        "v4_noremat": dict(hp=TrainHParams(remat=False),
                           cfg_overrides={"moe": None}),
        "v5_noremat_norepeat": dict(hp=TrainHParams(remat=False),
                                    cfg_overrides={"moe": None}),
    },
}


def _granite_cf(cf: float, no_repeat: bool = False):
    import dataclasses
    from repro import configs
    base = configs.get_config("granite_moe_3b_a800m")
    return {"moe": dataclasses.replace(base.moe, capacity_factor=cf,
                                       dispatch_no_repeat=no_repeat)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), default=None)
    ap.add_argument("--variant", default=None)
    args = ap.parse_args()

    import dataclasses
    from repro import configs as _cfgs
    _l4 = _cfgs.get_config("llama4_maverick_400b_a17b")
    VARIANTS["llama4_train"]["v4_capacity1"]["cfg_overrides"] = {
        "moe": dataclasses.replace(_l4.moe, capacity_factor=1.0)}
    VARIANTS["llama4_train"]["v5_mb1"]["cfg_overrides"] = {
        "moe": dataclasses.replace(_l4.moe, capacity_factor=1.0)}
    VARIANTS["granite_train"]["v2_capacity1"]["cfg_overrides"] = \
        _granite_cf(1.0)
    VARIANTS["granite_train"]["v3_both"]["cfg_overrides"] = _granite_cf(1.0)
    VARIANTS["granite_train"]["v4_noremat"]["cfg_overrides"] = \
        _granite_cf(1.0)
    VARIANTS["granite_train"]["v5_noremat_norepeat"]["cfg_overrides"] = \
        _granite_cf(1.0, no_repeat=True)

    os.makedirs("results/perf", exist_ok=True)
    cells = [args.cell] if args.cell else list(CELLS)
    for cell in cells:
        arch, shape = CELLS[cell]
        base_path = f"results/dryrun/{arch}__{shape}__single.json"
        base = json.load(open(base_path)) if os.path.exists(base_path) else {}
        brl = base.get("roofline", {})
        print(f"\n=== {cell}: {arch} x {shape}")
        if brl:
            print(f"  baseline: c={brl['compute_s']:.4g} m={brl['memory_s']:.4g} "
                  f"coll={brl['collective_s']:.4g} bound={brl['bottleneck']} "
                  f"frac={brl['roofline_frac']:.4g}")
        variants = VARIANTS[cell]
        names = [args.variant] if args.variant else list(variants)
        for name in names:
            spec = variants[name]
            out = f"results/perf/{cell}__{name}.json"
            if os.path.exists(out) and json.load(open(out)).get("ok"):
                rec = json.load(open(out))
            else:
                try:
                    rec = dryrun.run_cell(arch, shape, "single", tag=name,
                                          **spec)
                except Exception as e:
                    import traceback
                    rec = {"ok": False, "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(out, "w") as f:
                    json.dump(rec, f, indent=1)
            rl = rec.get("roofline", {})
            if rl:
                def delta(key):
                    if not brl or not brl.get(key):
                        return ""
                    return f" ({(rl[key]/brl[key]-1)*100:+.1f}%)"
                print(f"  {name}: c={rl['compute_s']:.4g}{delta('compute_s')} "
                      f"m={rl['memory_s']:.4g}{delta('memory_s')} "
                      f"coll={rl['collective_s']:.4g}{delta('collective_s')} "
                      f"bound={rl['bottleneck']} "
                      f"frac={rl['roofline_frac']:.4g}{delta('roofline_frac')}")
            else:
                print(f"  {name}: FAILED {rec.get('error')}")


if __name__ == "__main__":
    main()
