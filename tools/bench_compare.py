"""Benchmark-JSON regression gate (the CI ``bench-gate`` job).

Usage::

    python -m benchmarks.run --quick --only quality,scaling --json
    python tools/bench_compare.py --baseline benchmarks/baselines \
        --current . [--tolerance 0.10] [--gate-time]

Diffs the machine-readable ``BENCH_*.json`` files against checked-in
baselines and exits non-zero on a regression:

* quality rows (matched by graph/tool): ``cut``, ``totalCommVol`` and
  ``imbalance`` must not regress by more than ``--tolerance`` (default
  10%; imbalance gets an extra absolute slack of 0.01 — it is an
  epsilon-bounded quantity, not a ratio-scaled one).
* scaling ``spmd`` rows (matched by method/devices): structural coverage
  — every baseline (method, devices) row must exist, covering device
  counts {1, 2, 4, 8} — plus ``imbalance``, ``iters`` (slack of 2
  movement iterations) and the ``balanced`` flag.
* scaling ``hotloop`` section: the fused assign+reduce sweep must be
  bit-exact vs the unfused fallback, >= 1.3x over the legacy two-sweep
  hot loop and >= 1.1x over the PR 4 fixed-chunk fused baseline
  (absolute floors, independent of the baseline values — both are
  same-run interleaved ratios, immune to machine speed); the
  break-even-vs-fallback floor is wall-clock-noise-bound and therefore
  soft unless ``--gate-time``. The (n, k) config must match the
  baseline.
* scaling ``roofline`` record (``compare_roofline``): record presence
  and schema-field coverage are hard; the measured utilization numbers
  (an absolute 0.1% sanity floor and a >10% regression envelope vs
  baseline) are wall-clock-derived and soft unless ``--gate-time``.
* scaling ``weak_scaling`` record (``compare_weak_scaling``): the
  out-of-core memory gate — measured incremental peak RSS of the
  streaming sharded deal + solve must stay <= ``rss_ceiling`` (1.25x)
  times the analytic sharded working set, the probe problem must be
  float32, and the chunked-deal / 2-D-mesh bit-parity booleans must
  hold. All hard: RSS high-water marks come from a dedicated fresh
  subprocess, so the ratio is not wall-clock-noise-bound.
* repartition: the warm-vs-cold acceptance floors hold absolutely
  (``iters_ratio >= 3``, ``migration_ratio <= 0.30``, every step of both
  runs balanced), and the warm run's mean iterations / mean migration
  fraction must not regress by more than ``--tolerance`` vs baseline.
* serving (the multi-tenant PartitionServer stream): structural schema
  check (config commensurability + every summary field present), the
  absolute warm-path floors — cold/warm ``iters_ratio >= 3``,
  ``warm_hit_rate >= 0.7`` (and no worse than 0.05 below baseline),
  every request balanced in both runs — all hard; the throughput floor
  (``problems_per_s``) and p99 latency ceiling are wall-clock-derived
  and therefore soft unless ``--gate-time``.
* experiments (the §5 comparison matrix): full method x mesh-zoo cell
  coverage (base + label-propagation-refined sibling rows), per-cell
  ``cut`` / ``totalCommVol`` / ``imbalance`` regression vs baseline,
  every geographer cell balanced, refined rows never worse than their
  unrefined siblings (cut monotonicity + imbalance preservation,
  within-run, absolute), the paper-trend floor — geographer's
  comm-volume geomean over the zoo must stay <= sfc's and rcb's
  (ratio <= 1.0, absolute) — the tightened refined-trend ceilings
  (refined geographer vs sfc/rcb, below the raw 0.79/0.86 ratios), and
  the refinement-gain claim (refined/unrefined geographer comm-volume
  geomean < 1.0).
* wall-clock metrics are reported but only gated with ``--gate-time``
  (shared CI runners are noisy); the time gate multiplier is
  ``--time-tolerance`` (default 100%).

A baseline row or file with no current counterpart is a coverage
regression and fails the gate.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

FAIL, WARN = "FAIL", "warn"


def _load(path: str):
    with open(path) as f:
        return json.load(f)


class Report:
    def __init__(self):
        self.rows: list[tuple[str, str, str]] = []   # (severity, where, msg)

    def add(self, severity: str, where: str, msg: str):
        self.rows.append((severity, where, msg))

    def gate(self, ok: bool, where: str, msg: str, hard: bool = True):
        if not ok:
            self.add(FAIL if hard else WARN, where, msg)

    @property
    def failures(self):
        return [r for r in self.rows if r[0] == FAIL]


def _regressed(cur, base, tol: float, abs_slack: float = 0.0) -> bool:
    """Lower-is-better metric: True when cur exceeds the gated envelope."""
    if base is None or cur is None:
        return False
    return cur > base * (1.0 + tol) + abs_slack


def _fmt(cur, base) -> str:
    return f"current={cur} baseline={base}"


def compare_quality(base, cur, tol: float, rep: Report):
    # commensurability first: quick-vs-full runs must never be compared —
    # every metric would differ for config reasons, masking or inventing
    # regressions
    for fld in ("n", "k"):
        rep.gate(base.get(fld) == cur.get(fld), f"quality.config.{fld}",
                 "incommensurable runs (regenerate baselines with the "
                 "same --quick setting): " + _fmt(cur.get(fld),
                                                  base.get(fld)))
    cur_rows = {(r["graph"], r["tool"]): r for r in cur.get("rows", [])}
    for b in base.get("rows", []):
        key = (b["graph"], b["tool"])
        where = f"quality[{b['graph']}/{b['tool']}]"
        c = cur_rows.get(key)
        if c is None:
            rep.add(FAIL, where, "row missing from current run")
            continue
        for met, slack in (("cut", 2.0), ("totalCommVol", 2.0),
                           ("imbalance", 0.01)):
            rep.gate(not _regressed(c.get(met), b.get(met), tol, slack),
                     f"{where}.{met}", _fmt(c.get(met), b.get(met)))


HOTLOOP_SPEEDUP_FLOOR = 1.3    # fused >= 1.3x over the legacy hot loop
HOTLOOP_FALLBACK_FLOOR = 0.9   # fusing must never cost (noise slack)
HOTLOOP_PR4_FLOOR = 1.1        # adaptive chunk >= 1.1x over PR 4 fused


def compare_hotloop(base, cur, rep: Report, gate_time: bool):
    hot = cur.get("hotloop")
    if hot is None:
        rep.add(FAIL, "scaling.hotloop",
                "hot-loop section missing from current run")
        return
    bhot = base.get("hotloop", {})
    for fld in ("n", "k"):
        rep.gate(bhot.get(fld) == hot.get(fld),
                 f"scaling.hotloop.config.{fld}",
                 "incommensurable hot-loop runs: "
                 + _fmt(hot.get(fld), bhot.get(fld)))
    rep.gate(bool(hot.get("bitexact", False)), "scaling.hotloop.bitexact",
             "fused and unfused-fallback results are not bit-identical")
    rep.gate(bool(hot.get("labels_equal", False)),
             "scaling.hotloop.labels",
             "hot-loop variants disagree on the assignment")
    rep.gate(hot.get("speedup_vs_legacy", 0.0) >= HOTLOOP_SPEEDUP_FLOOR,
             "scaling.hotloop.speedup_vs_legacy",
             f"fused speedup {hot.get('speedup_vs_legacy')} below the "
             f">= {HOTLOOP_SPEEDUP_FLOOR}x floor over the legacy "
             "two-sweep hot loop")
    rep.gate(hot.get("speedup_vs_pr4_fused", 0.0) >= HOTLOOP_PR4_FLOOR,
             "scaling.hotloop.speedup_vs_pr4_fused",
             f"fused sweep {hot.get('speedup_vs_pr4_fused')}x vs the "
             f"PR 4 fixed-chunk fused baseline — the adaptive-chunk "
             f"roofline win must hold the >= {HOTLOOP_PR4_FLOOR}x floor")
    # the fallback ratio hovers near 1.0 by design (the fallback re-reads
    # the points but does the same arithmetic), so on shared runners it is
    # soft-gated like every other wall-clock metric (--gate-time hardens)
    rep.gate(hot.get("speedup_vs_fallback", 0.0) >= HOTLOOP_FALLBACK_FLOOR,
             "scaling.hotloop.speedup_vs_fallback",
             f"fused sweep {hot.get('speedup_vs_fallback')}x vs the "
             "unfused fallback — fusing must not cost",
             hard=gate_time)


# roofline record: structural coverage is hard (the record and every
# schema field must exist — a silently dropped profile is a coverage
# regression), the utilization numbers are wall-clock-derived and
# therefore soft unless --gate-time (shared runners are noisy), with a
# >10% regression envelope vs baseline per the profile's charter
ROOFLINE_FIELDS = ("platform", "backend", "n", "d", "k", "ai",
                   "compute_s", "memory_s", "bound_s", "bottleneck",
                   "measured_s", "utilization")
ROOFLINE_REGRESSION_TOL = 0.10


def compare_roofline(base, cur, rep: Report, gate_time: bool):
    roof = cur.get("roofline")
    if roof is None:
        rep.add(FAIL, "scaling.roofline",
                "roofline record missing from current run")
        return
    for fld in ROOFLINE_FIELDS:
        rep.gate(roof.get(fld) is not None, f"scaling.roofline.{fld}",
                 "schema field missing/null from the roofline record")
    broof = base.get("roofline", {})
    for fld in ("n", "d", "k"):
        rep.gate(broof.get(fld) == roof.get(fld),
                 f"scaling.roofline.config.{fld}",
                 "incommensurable roofline records: "
                 + _fmt(roof.get(fld), broof.get(fld)))
    util, butil = roof.get("utilization"), broof.get("utilization")
    if util is not None:
        # sanity floor: a three-orders-of-magnitude miss means the model
        # or the measurement broke, not that the machine was busy
        rep.gate(util >= 1e-3, "scaling.roofline.utilization",
                 f"measured utilization {util} below the absolute 0.1% "
                 "sanity floor", hard=gate_time)
        if butil:
            rep.gate(util >= butil * (1.0 - ROOFLINE_REGRESSION_TOL),
                     "scaling.roofline.utilization_regression",
                     f"measured hotloop utilization regressed >10%: "
                     + _fmt(util, butil), hard=gate_time)


WEAK_SCALING_FIELDS = ("n", "k", "devices", "chunk", "peak_rss_bytes",
                       "incremental_peak_bytes", "working_set_bytes",
                       "rss_ratio", "rss_ceiling", "time_s", "imbalance",
                       "points_dtype")


def compare_weak_scaling(base, cur, rep: Report):
    """Hard memory-ceiling gate on the out-of-core weak-scaling record:
    the measured incremental peak RSS of the streaming sharded deal +
    solve must stay under ``rss_ceiling`` x the analytic working set
    (a reintroduced O(n) float64 host copy blows it), and the bit-parity
    booleans (chunked deal == one-shot, 2-D mesh == flat) must hold."""
    rec = cur.get("weak_scaling")
    if rec is None:
        rep.add(FAIL, "scaling.weak_scaling",
                "weak_scaling memory record missing from current run")
        return
    for fld in WEAK_SCALING_FIELDS:
        rep.gate(rec.get(fld) is not None, f"scaling.weak_scaling.{fld}",
                 "schema field missing/null from the weak_scaling record")
    brec = base.get("weak_scaling", {})
    for fld in ("n", "k", "devices", "chunk"):
        rep.gate(brec.get(fld) == rec.get(fld),
                 f"scaling.weak_scaling.config.{fld}",
                 "incommensurable weak_scaling records: "
                 + _fmt(rec.get(fld), brec.get(fld)))
    rep.gate(rec.get("points_dtype") == "float32",
             "scaling.weak_scaling.points_dtype",
             "probe problem must be float32 — the record exists to prove "
             "no float64 up-cast: " + _fmt(rec.get("points_dtype"),
                                           "float32"))
    ratio, ceil = rec.get("rss_ratio"), rec.get("rss_ceiling")
    if ratio is not None and ceil is not None:
        rep.gate(ratio <= ceil, "scaling.weak_scaling.rss_ratio",
                 f"peak host RSS blew the memory ceiling: incremental "
                 f"peak = {ratio:.3f}x the analytic sharded working set "
                 f"(ceiling {ceil}x) — an O(n) full-host or float64 "
                 "staging copy has crept back into the deal/solve path")
    rep.gate(rec.get("chunked_deal_bitexact") is True,
             "scaling.weak_scaling.chunked_deal_bitexact",
             "chunked deal is not bit-identical to the one-shot deal")
    rep.gate(rec.get("mesh2d_labels_equal") is True,
             "scaling.weak_scaling.mesh2d_labels_equal",
             "2-D device mesh labels differ from the flat-mesh run")


def compare_scaling(base, cur, tol: float, rep: Report,
                    gate_time: bool, time_tol: float):
    rep.gate(base.get("quick") == cur.get("quick"), "scaling.config.quick",
             "incommensurable runs (regenerate baselines with the same "
             "--quick setting): " + _fmt(cur.get("quick"),
                                         base.get("quick")))
    compare_hotloop(base, cur, rep, gate_time)
    compare_roofline(base, cur, rep, gate_time)
    compare_weak_scaling(base, cur, rep)
    cur_rows = {(r["method"], r["devices"]): r for r in cur.get("spmd", [])}
    seen_devices = {r["devices"] for r in cur.get("spmd", [])}
    for d in (1, 2, 4, 8):
        rep.gate(d in seen_devices, f"scaling.spmd.devices={d}",
                 "no scaling row for this device count")
    for b in base.get("spmd", []):
        key = (b["method"], b["devices"])
        where = f"scaling[{b['method']}/devices={b['devices']}]"
        c = cur_rows.get(key)
        if c is None:
            rep.add(FAIL, where, "row missing from current run")
            continue
        rep.gate((c.get("n"), c.get("k")) == (b.get("n"), b.get("k")),
                 f"{where}.config",
                 f"incommensurable rows: current n={c.get('n')} "
                 f"k={c.get('k')} baseline n={b.get('n')} k={b.get('k')}")
        rep.gate(bool(c.get("balanced", False)), f"{where}.balanced",
                 f"imbalance={c.get('imbalance')} exceeds epsilon")
        rep.gate(not _regressed(c.get("imbalance"), b.get("imbalance"),
                                tol, 0.01),
                 f"{where}.imbalance",
                 _fmt(c.get("imbalance"), b.get("imbalance")))
        rep.gate(not _regressed(c.get("iters"), b.get("iters"), tol, 2.0),
                 f"{where}.iters", _fmt(c.get("iters"), b.get("iters")))
        rep.gate(not _regressed(c.get("time_s"), b.get("time_s"), time_tol),
                 f"{where}.time_s", _fmt(c.get("time_s"), b.get("time_s")),
                 hard=gate_time)


# §5 paper trend: geographer's comm volume must stay <= the Zoltan-style
# geometric baselines', geomean over the mesh zoo (measured ~0.79 vs sfc
# and ~0.86 vs rcb at the quick config — 1.0 is an absolute claim floor,
# not a noise envelope)
TREND_TOOLS = ("sfc", "rcb")
TREND_RATIO_CEIL = 1.0
# the tightened trend: *refined* geographer (the label-propagation
# post-pass) vs the unrefined baselines must beat the raw-geographer
# ratios (0.79 / 0.86 at the quick config) with room to spare — the
# ceilings sit between the measured refined ratios (0.676 vs sfc,
# 0.7375 vs rcb at the quick config) and the raw ones
REFINED_TREND_CEILS = {"sfc": 0.74, "rcb": 0.80}
# refinement must strictly help geographer's comm volume (geomean over
# the zoo, refined/unrefined < 1.0 — the ISSUE 8 acceptance claim)
REFINED_GAIN_CEIL = 1.0


def compare_experiments(base, cur, tol: float, rep: Report):
    for fld in ("n", "k", "quick", "eval_devices", "seed", "refiner"):
        rep.gate(base.get(fld) == cur.get(fld),
                 f"experiments.config.{fld}",
                 "incommensurable runs (regenerate baselines with the "
                 "same --quick setting): " + _fmt(cur.get(fld),
                                                  base.get(fld)))
    cur_rows = {(r["family"], r["tool"]): r for r in cur.get("rows", [])}
    for b in base.get("rows", []):
        key = (b["family"], b["tool"])
        where = f"experiments[{b['family']}/{b['tool']}]"
        c = cur_rows.get(key)
        if c is None:
            rep.add(FAIL, where, "cell missing from current run "
                                 "(method x mesh coverage regression)")
            continue
        for met, slack in (("cut", 2.0), ("totalCommVol", 2.0),
                           ("imbalance", 0.01)):
            rep.gate(not _regressed(c.get(met), b.get(met), tol, slack),
                     f"{where}.{met}", _fmt(c.get(met), b.get(met)))
    # refined-row monotonicity within the current run: a refined cell
    # whose cut exceeds its unrefined sibling's is algorithmically
    # impossible (the independent-set rounds only accept positive-gain
    # moves) — seeing one means the refiner or the harness broke
    for r in cur.get("rows", []):
        if not r.get("refined"):
            continue
        sib = cur_rows.get((r["family"], r.get("base_tool")))
        where = f"experiments[{r['family']}/{r['tool']}]"
        if sib is None:
            rep.add(FAIL, where, "refined row has no unrefined sibling "
                                 "(method x mesh coverage regression)")
            continue
        rep.gate(r.get("cut", 0) <= sib.get("cut", 0),
                 f"{where}.cut_monotonic",
                 f"refined cut {r.get('cut')} exceeds the unrefined "
                 f"sibling's {sib.get('cut')} — refinement must never "
                 "increase the cut")
    s = cur.get("summary", {})
    rep.gate(bool(s.get("geographer_all_balanced", False)),
             "experiments.geographer.balanced",
             "a geographer cell exceeded epsilon (see rows[].imbalance)")
    rep.gate(bool(s.get("refined_imbalance_ok", False)),
             "experiments.refined.imbalance",
             "a refined cell's imbalance exceeds max(sibling, epsilon) — "
             "refinement must never worsen balance")
    # the paper's headline trend, gated absolutely
    geo = s.get("geo_over_tool", {})
    for tool in TREND_TOOLS:
        ratio = geo.get(tool, {}).get("totalCommVol")
        rep.gate(ratio is not None and ratio <= TREND_RATIO_CEIL,
                 f"experiments.trend.{tool}",
                 f"geographer/{tool} comm-volume geomean {ratio} above "
                 f"the <= {TREND_RATIO_CEIL} paper-trend ceiling")
    # the tightened refined trend + the refinement-gain claim
    geo_r = s.get("geo_refined_over_tool", {})
    for tool, ceil in REFINED_TREND_CEILS.items():
        ratio = geo_r.get(tool, {}).get("totalCommVol")
        rep.gate(ratio is not None and ratio <= ceil,
                 f"experiments.refined_trend.{tool}",
                 f"refined-geographer/{tool} comm-volume geomean {ratio} "
                 f"above the <= {ceil} tightened ceiling")
    gain = s.get("refined_over_unrefined", {}).get("geographer", {})
    ratio = gain.get("totalCommVol")
    rep.gate(ratio is not None and ratio < REFINED_GAIN_CEIL,
             "experiments.refined_gain.geographer",
             f"refined/unrefined geographer comm-volume geomean {ratio} "
             f"not strictly below {REFINED_GAIN_CEIL} — the refinement "
             "pass stopped paying for itself")


# serving floors: the warm-hit steady state must need >= 3x fewer
# movement iterations than all-cold serving (absolute claim, same-run
# ratio — machine-speed-immune), and with a cache sized to the fleet the
# hit rate is structural ((T-1)/T of requests warm), so 0.7 is a loose
# absolute floor under any benchmarked T >= 4
SERVING_ITERS_FLOOR = 3.0
SERVING_HIT_RATE_FLOOR = 0.7
SERVING_HIT_RATE_SLACK = 0.05      # vs baseline
SERVING_SUMMARY_FIELDS = (
    "iters_ratio", "warm_mean_iters", "cold_mean_iters", "warm_hit_rate",
    "warm_all_balanced", "cold_all_balanced", "problems_per_s", "p50_ms",
    "p99_ms", "measured_steps", "requests_measured", "requests_total")


def compare_serving(base, cur, rep: Report, gate_time: bool,
                    time_tol: float):
    for fld in ("quick", "steps", "slots", "tiers", "workload", "tenants"):
        rep.gate(base.get(fld) == cur.get(fld), f"serving.config.{fld}",
                 "incommensurable runs (regenerate baselines with the "
                 "same --quick setting): " + _fmt(cur.get(fld),
                                                  base.get(fld)))
    s = cur.get("summary", {})
    for fld in SERVING_SUMMARY_FIELDS:
        rep.gate(s.get(fld) is not None, f"serving.summary.{fld}",
                 "schema field missing/null from the serving summary")
    # absolute warm-path acceptance floors — hold regardless of baseline
    rep.gate(s.get("iters_ratio", 0.0) >= SERVING_ITERS_FLOOR,
             "serving.iters_ratio",
             f"cold/warm iteration ratio {s.get('iters_ratio')} below "
             f"the >= {SERVING_ITERS_FLOOR}x claim")
    hit = s.get("warm_hit_rate", 0.0)
    bs = base.get("summary", {})
    rep.gate(hit >= SERVING_HIT_RATE_FLOOR, "serving.warm_hit_rate",
             f"warm-hit rate {hit} below the absolute "
             f">= {SERVING_HIT_RATE_FLOOR} floor")
    if bs.get("warm_hit_rate") is not None:
        rep.gate(hit >= bs["warm_hit_rate"] - SERVING_HIT_RATE_SLACK,
                 "serving.warm_hit_rate_regression",
                 _fmt(hit, bs.get("warm_hit_rate")))
    for mode in ("warm", "cold"):
        rep.gate(bool(s.get(f"{mode}_all_balanced", False)),
                 f"serving.{mode}.balanced",
                 "a request exceeded epsilon (see per_step max_imbalance)")
    # wall-clock envelope: throughput floor + p99 ceiling vs baseline,
    # soft on shared runners unless --gate-time
    tput, btput = s.get("problems_per_s"), bs.get("problems_per_s")
    if btput:
        rep.gate(tput is not None and tput >= btput / (1.0 + time_tol),
                 "serving.problems_per_s",
                 f"throughput floor: {_fmt(tput, btput)}", hard=gate_time)
    rep.gate(not _regressed(s.get("p99_ms"), bs.get("p99_ms"), time_tol),
             "serving.p99_ms", _fmt(s.get("p99_ms"), bs.get("p99_ms")),
             hard=gate_time)


ITERS_RATIO_FLOOR = 3.0        # warm needs >= 3x fewer iterations
MIGRATION_RATIO_CEIL = 0.30    # warm moves <= 30% of cold's weight


def compare_repartition(base, cur, tol: float, rep: Report):
    for fld in ("n", "k", "steps", "workload", "quick"):
        rep.gate(base.get(fld) == cur.get(fld),
                 f"repartition.config.{fld}",
                 "incommensurable runs (regenerate baselines with the "
                 "same --quick setting): " + _fmt(cur.get(fld),
                                                  base.get(fld)))
    s = cur.get("summary", {})
    # absolute acceptance floors — these hold regardless of the baseline
    rep.gate(s.get("iters_ratio", 0.0) >= ITERS_RATIO_FLOOR,
             "repartition.iters_ratio",
             f"cold/warm iteration ratio {s.get('iters_ratio')} below "
             f"the >= {ITERS_RATIO_FLOOR}x claim")
    rep.gate(s.get("migration_ratio", 1.0) <= MIGRATION_RATIO_CEIL,
             "repartition.migration_ratio",
             f"warm/cold migration ratio {s.get('migration_ratio')} above "
             f"the <= {MIGRATION_RATIO_CEIL} claim")
    for mode in ("warm", "cold"):
        rep.gate(bool(s.get(f"{mode}_all_balanced", False)),
                 f"repartition.{mode}.balanced",
                 "a step exceeded epsilon (see per_step imbalance)")
    # relative regression vs baseline for the warm run's two headline
    # metrics (iters get an absolute slack of 1 movement iteration,
    # migration fraction one of 0.01 — both are small-integer/epsilon
    # scaled quantities, not pure ratios)
    bs = base.get("summary", {})
    rep.gate(not _regressed(s.get("warm_mean_iters"),
                            bs.get("warm_mean_iters"), tol, 1.0),
             "repartition.warm_mean_iters",
             _fmt(s.get("warm_mean_iters"), bs.get("warm_mean_iters")))
    rep.gate(not _regressed(s.get("warm_mean_migration_fraction"),
                            bs.get("warm_mean_migration_fraction"),
                            tol, 0.01),
             "repartition.warm_mean_migration_fraction",
             _fmt(s.get("warm_mean_migration_fraction"),
                  bs.get("warm_mean_migration_fraction")))


COMPARATORS = {
    "BENCH_quality.json":
        lambda b, c, a, r: compare_quality(b, c, a.tolerance, r),
    "BENCH_scaling.json":
        lambda b, c, a, r: compare_scaling(b, c, a.tolerance, r,
                                           a.gate_time, a.time_tolerance),
    "BENCH_repartition.json":
        lambda b, c, a, r: compare_repartition(b, c, a.tolerance, r),
    "BENCH_serving.json":
        lambda b, c, a, r: compare_serving(b, c, r, a.gate_time,
                                           a.time_tolerance),
    "BENCH_experiments.json":
        lambda b, c, a, r: compare_experiments(b, c, a.tolerance, r),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >tolerance regression vs checked-in baselines")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory holding baseline BENCH_*.json")
    ap.add_argument("--current", default=".",
                    help="directory holding freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed relative regression (default 0.10)")
    ap.add_argument("--gate-time", action="store_true",
                    help="treat wall-clock regressions as failures")
    ap.add_argument("--time-tolerance", type=float, default=1.0,
                    help="allowed relative wall-clock regression "
                         "(default 1.0 = 2x)")
    ap.add_argument("--files", default=None,
                    help="comma-separated BENCH_*.json basenames to "
                         "compare (default: every baseline present) — "
                         "lets a CI job gate one file, e.g. "
                         "--files BENCH_experiments.json")
    args = ap.parse_args(argv)

    baselines = sorted(glob.glob(os.path.join(args.baseline, "BENCH_*.json")))
    if args.files:
        wanted = {f.strip() for f in args.files.split(",") if f.strip()}
        missing = wanted - {os.path.basename(b) for b in baselines}
        if missing:
            print(f"error: no baseline for {sorted(missing)} under "
                  f"{args.baseline!r}", file=sys.stderr)
            return 2
        baselines = [b for b in baselines
                     if os.path.basename(b) in wanted]
    if not baselines:
        print(f"error: no BENCH_*.json baselines under {args.baseline!r}",
              file=sys.stderr)
        return 2

    rep = Report()
    for bpath in baselines:
        name = os.path.basename(bpath)
        cpath = os.path.join(args.current, name)
        if not os.path.exists(cpath):
            rep.add(FAIL, name, f"current file {cpath} missing "
                                "(run benchmarks with --json)")
            continue
        comparator = COMPARATORS.get(name)
        if comparator is None:
            print(f"[bench-compare] {name}: no comparator, "
                  "checked existence only")
            continue
        comparator(_load(bpath), _load(cpath), args, rep)

    for severity, where, msg in rep.rows:
        print(f"[{severity}] {where}: {msg}")
    n_fail, n_warn = len(rep.failures), len(rep.rows) - len(rep.failures)
    print(f"[bench-compare] {len(baselines)} baseline file(s), "
          f"{n_fail} failure(s), {n_warn} warning(s), "
          f"tolerance={args.tolerance:.0%}")
    return 1 if rep.failures else 0


if __name__ == "__main__":
    sys.exit(main())
