"""Docs checker (the CI ``docs`` job): markdown link check + executable
code blocks, so examples in docs can't rot.

Usage::

    PYTHONPATH=src python tools/check_docs.py [--root .] [files...]

Checks, over README.md, DESIGN.md, ROADMAP.md and docs/*.md by default:

* **links** — every relative markdown link ``[text](target)`` must point
  at an existing file (anchors are stripped; ``http(s)://`` / ``mailto:``
  targets are skipped — CI shouldn't flake on the network).
* **python code blocks** — every fenced ```` ```python ```` block must at
  least *compile*; blocks containing ``>>>`` doctest prompts are executed
  through :mod:`doctest` and their outputs must match. Blocks tagged
  ```` ```python no-run ```` are compile-checked only (for illustrative
  fragments with undefined names).

Exit code 0 when everything passes, 1 otherwise (one line per failure).
"""
from __future__ import annotations

import argparse
import doctest
import glob
import os
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```python([^\n]*)\n(.*?)^```", re.M | re.S)
SKIP_SCHEMES = ("http://", "https://", "mailto:")

DEFAULT_FILES = ("README.md", "DESIGN.md", "ROADMAP.md", "docs/*.md")


def check_links(path: str, text: str) -> list[str]:
    errors = []
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES):
            continue
        file_part = target.split("#", 1)[0]
        if not file_part:               # pure in-page anchor
            continue
        resolved = os.path.normpath(os.path.join(base, file_part))
        if not os.path.exists(resolved):
            errors.append(f"{path}: broken link -> {target}")
    return errors


def check_code_blocks(path: str, text: str) -> list[str]:
    errors = []
    runner = doctest.DocTestRunner(optionflags=doctest.ELLIPSIS
                                   | doctest.NORMALIZE_WHITESPACE)
    parser = doctest.DocTestParser()
    for i, m in enumerate(FENCE_RE.finditer(text)):
        tag, block = m.group(1).strip(), m.group(2)
        name = f"{path}:block{i}"
        if ">>>" in block:
            # a doctest transcript: sources are validated (and run) by the
            # doctest machinery, not by a whole-block compile()
            if tag == "no-run":
                for ex in parser.get_examples(block, name):
                    try:
                        compile(ex.source, name, "exec")
                    except SyntaxError as e:
                        errors.append(f"{name}: syntax error: {e}")
                continue
            test = parser.get_doctest(block, {}, name, path, 0)
            out = []
            runner.run(test, out=out.append)
            if runner.failures:
                errors.append(f"{name}: doctest failed\n" + "".join(out))
                runner = doctest.DocTestRunner(
                    optionflags=doctest.ELLIPSIS
                    | doctest.NORMALIZE_WHITESPACE)
        else:
            try:
                compile(block, name, "exec")
            except SyntaxError as e:
                errors.append(f"{name}: syntax error: {e}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="markdown link + code-block checker")
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: README/DESIGN/ROADMAP "
                         "+ docs/*.md)")
    ap.add_argument("--root", default=".",
                    help="repo root the default globs resolve against")
    args = ap.parse_args(argv)

    patterns = args.files or [os.path.join(args.root, p)
                              for p in DEFAULT_FILES]
    files = sorted({f for p in patterns for f in glob.glob(p)})
    if not files:
        print("error: no markdown files found", file=sys.stderr)
        return 2

    errors = []
    n_blocks = 0
    for path in files:
        with open(path) as f:
            text = f.read()
        errors += check_links(path, text)
        errors += check_code_blocks(path, text)
        n_blocks += len(FENCE_RE.findall(text))
    for e in errors:
        print(f"[FAIL] {e}")
    print(f"[check-docs] {len(files)} file(s), {n_blocks} python "
          f"block(s), {len(errors)} failure(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
