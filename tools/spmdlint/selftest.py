"""``python -m tools.spmdlint --self-test`` — per-rule fixture suite.

Each rule ships one positive fixture it must flag and one negative
fixture it must pass, plus a waiver-suppression check. CI runs this in
the lint job so a rule regression (a detector silently going blind, or
a new false positive) fails the build even before the tree-wide pass.
The same fixtures back tests/test_spmdlint.py.
"""
from __future__ import annotations

from .engine import lint_source
from .waivers import Config, Waiver

# (rule, should_flag, source) — fixture sources are tiny but shaped like
# the real call sites the rule exists for.
FIXTURES: list[tuple[str, bool, str]] = [
    ("SPMD001", True, """
import jax
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    def local(x):
        return jax.lax.all_gather(x, "shard")
    return shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)
"""),
    ("SPMD001", False, """
import jax
from jax.experimental.shard_map import shard_map

def build(mesh, spec):
    def local(x):
        return jax.lax.psum(x, "shard")
    return shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)
"""),
    ("SPMD002", True, """
import jax

def local(x):
    return jax.lax.psum(x, "shards")
"""),
    ("SPMD002", False, """
import jax

def local(x, axis):
    return jax.lax.psum(x, "shard") + jax.lax.pmax(x, axis)
"""),
    ("SPMD003", True, """
import jax

def local(x, axis):  # spmdlint: psum-budget=2
    return jax.lax.psum(x, axis)
"""),
    ("SPMD003", False, """
import jax

def local(x, axis):  # spmdlint: psum-budget=3
    def helper(v):
        return jax.lax.psum(v, axis)
    return helper(x) + helper(x * 2) + jax.lax.psum(x, axis)
"""),
    ("TRC001", True, """
import jax

@jax.jit
def f(x):
    n = int(x)
    return n + 1
"""),
    ("TRC001", False, """
import jax

@jax.jit
def f(x):
    n = int(x.shape[0])      # shape metadata is static under tracing
    try:
        m = int(x)           # guarded concretization (warm-up pattern)
    except jax.errors.TracerIntegerConversionError:
        m = 0
    return n + m
"""),
    ("TRC002", True, """
import functools
import jax
import numpy as np

@functools.partial(jax.jit, static_argnames=("cfg",))
def f(x, cfg):
    return np.sum(x)
"""),
    ("TRC002", False, """
import functools
import jax
import numpy as np

@functools.partial(jax.jit, static_argnames=("cfg",))
def f(x, cfg):
    scale = np.float32(cfg.scale)     # static config -> numpy is fine
    return x * scale
"""),
    ("TRC003", True, """
import jax

def run(xs):
    def body(carry, x):
        if x > 0:
            carry = carry + x
        return carry, x
    return jax.lax.scan(body, 0, xs)
"""),
    ("TRC003", False, """
import functools
import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnames=("cfg",))
def f(x, cfg):
    if cfg.warmup:                      # static argname -> host branch ok
        x = x * 2
    return jnp.where(x > 0, x, 0.0)     # traced select, not Python if
"""),
    ("KER001", True, """
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kernel(x_ref, o_ref):
    o_ref[...] = jnp.sort(x_ref[...])   # no Mosaic lowering for sort

def run(x, out_shape):
    if x.shape[0] % 8:
        raise ValueError("bad tile")
    return pl.pallas_call(_kernel, out_shape=out_shape)(x)
"""),
    ("KER001", False, """
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _kernel(x_ref, o_ref):
    o_ref[...] = jnp.maximum(x_ref[...], 0.0).astype(o_ref.dtype)

def run(x, out_shape):
    if x.shape[0] % 8:
        raise ValueError("bad tile")
    return pl.pallas_call(_kernel, out_shape=out_shape)(x)
"""),
    ("KER002", True, """
from jax.experimental.pallas import tpu as pltpu

def _kernel(hbm, buf, sem):
    pltpu.make_async_copy(hbm, buf, sem).start()
"""),
    ("KER002", False, """
from jax.experimental.pallas import tpu as pltpu

def _kernel(hbm, buf, sem):
    def dma(slot):
        return pltpu.make_async_copy(hbm, buf.at[slot], sem)
    dma(0).start()
    dma(0).wait()
"""),
    ("KER003", True, """
from jax.experimental import pallas as pl

def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def run(x, out_shape):
    return pl.pallas_call(_kernel, out_shape=out_shape)(x)
"""),
    ("KER003", False, """
from jax.experimental import pallas as pl

def _check_tiling(n, block):
    if n % block:
        raise ValueError(f"{n} not a multiple of {block}")

def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def run(x, out_shape, block):
    _check_tiling(x.shape[0], block)
    return pl.pallas_call(_kernel, out_shape=out_shape)(x)
"""),
    ("REG001", True, """
from repro.kernels.ops import register_assign_backend

@register_assign_backend("mine")
def backend(points, centers, influence, **kw):
    return None
"""),
    ("REG001", False, """
from repro.kernels.ops import register_assign_backend
from repro.partition.refine import register_refiner

@register_assign_backend("mine", supports_moments=False)
def backend(points, centers, influence, **kw):
    return None

@register_refiner("noop", aliases=("n",), short="no")
def noop(problem, labels, **kw):
    return labels, {}
"""),
]

#: the positive fixture a waiver must be able to silence
WAIVER_FIXTURE = FIXTURES[0][2]


def run_self_test(verbose: bool = True) -> int:
    failures = []
    for rule, should_flag, source in FIXTURES:
        diags = lint_source(f"<fixture:{rule}>", source)
        hits = [d for d in diags if d.rule == rule and d.waived_by is None]
        others = [d for d in diags if d.rule != rule]
        kind = "positive" if should_flag else "negative"
        if should_flag and not hits:
            failures.append(f"{rule} {kind}: expected a finding, got none")
        elif not should_flag and hits:
            failures.append(
                f"{rule} {kind}: false positive(s): "
                + "; ".join(d.format() for d in hits))
        if others:
            failures.append(
                f"{rule} {kind}: unrelated finding(s) leaked in: "
                + "; ".join(d.format() for d in others))

    config = Config(waivers=[Waiver(
        rule="SPMD001", path="<fixture:waiver>", symbol="build.local",
        reason="self-test")])
    waived = lint_source("<fixture:waiver>", WAIVER_FIXTURE, config)
    if any(d.waived_by is None for d in waived):
        failures.append("waiver suppression: finding survived a matching "
                        "waiver")
    if not any(d.waived_by for d in waived):
        failures.append("waiver suppression: expected a waived finding")

    if verbose:
        n = len(FIXTURES) + 1
        if failures:
            for f in failures:
                print(f"FAIL {f}")
            print(f"spmdlint self-test: {len(failures)} failure(s) / "
                  f"{n} checks")
        else:
            print(f"spmdlint self-test: {n} checks passed")
    return 1 if failures else 0
