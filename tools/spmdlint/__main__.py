"""``python -m tools.spmdlint src tests benchmarks tools``."""
import sys

from .engine import main

sys.exit(main())
