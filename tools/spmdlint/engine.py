"""File walking, rule dispatch, waiver application, CLI entry point."""
from __future__ import annotations

import argparse
import os
import sys

from . import rules_kernel, rules_registry, rules_spmd, rules_trace
from .astutil import ModuleInfo
from .diagnostics import Diagnostic
from .waivers import Config, load_config

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache",
              "node_modules", ".venv", "venv"}


def iter_py_files(paths: list[str]):
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def lint_source(path: str, source: str,
                config: Config | None = None) -> list[Diagnostic]:
    """Lint one in-memory module; returns diagnostics with ``waived_by``
    filled in for waived findings (callers filter on it)."""
    config = config or Config(waivers=[])
    try:
        mod = ModuleInfo(path, source)
    except SyntaxError as e:
        return [Diagnostic(rule="E999", path=path, line=e.lineno or 1,
                           col=(e.offset or 1) - 1,
                           message=f"syntax error: {e.msg}")]
    axes = (config.axes if config.axes is not None
            else rules_spmd.DEFAULT_AXES)
    diags: list[Diagnostic] = []
    diags.extend(rules_spmd.check(mod, allowed_axes=axes))
    diags.extend(rules_trace.check(mod))
    diags.extend(rules_kernel.check(mod))
    diags.extend(rules_registry.check(mod))
    diags.sort(key=lambda d: (d.line, d.col, d.rule))
    return [_apply_waivers(d, config) for d in diags]


def _apply_waivers(diag: Diagnostic, config: Config) -> Diagnostic:
    for waiver in config.waivers:
        if waiver.matches(diag):
            return Diagnostic(rule=diag.rule, path=diag.path,
                              line=diag.line, col=diag.col,
                              message=diag.message, symbol=diag.symbol,
                              waived_by=waiver.reason or "waived")
    return diag


def lint_paths(paths: list[str],
               config: Config | None = None) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        diags.extend(lint_source(path, source, config))
    return diags


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.spmdlint",
        description="repo-specific SPMD/trace-safety/kernel/registry "
                    "static analysis (rule catalog: DESIGN.md §12)")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to lint")
    parser.add_argument("--waivers", default="spmdlint.toml",
                        help="waiver file (default: ./spmdlint.toml)")
    parser.add_argument("--no-waivers", action="store_true",
                        help="report waived findings as failures too")
    parser.add_argument("--show-waived", action="store_true",
                        help="also print waived findings")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--self-test", action="store_true",
                        help="run the built-in per-rule fixture suite")
    args = parser.parse_args(argv)

    if args.list_rules:
        from . import RULES
        for rule, text in sorted(RULES.items()):
            print(f"{rule}  {text}")
        return 0
    if args.self_test:
        from .selftest import run_self_test
        return run_self_test()
    if not args.paths:
        parser.error("no paths given (or use --list-rules / --self-test)")

    config = load_config(None if args.no_waivers else args.waivers)
    diags = lint_paths(args.paths, config)
    active = [d for d in diags if d.waived_by is None]
    waived = [d for d in diags if d.waived_by is not None]
    for d in active:
        print(d.format())
    if args.show_waived:
        for d in waived:
            print(d.format())
    unused = [w for w in config.waivers
              if not any(w.matches(d) for d in diags)]
    for w in unused:
        print(f"note: unused waiver {w.rule} {w.path}"
              f"{':' + w.symbol if w.symbol else ''}", file=sys.stderr)
    print(f"spmdlint: {len(active)} finding(s), {len(waived)} waived, "
          f"{sum(1 for _ in iter_py_files(args.paths))} file(s)")
    return 1 if active else 0
