"""REG001 — registry call sites must declare capability kwargs explicitly.

The engine dispatches on registry capabilities (``supports_moments``
gates the fused assign+reduce path, ``supports_devices`` /
``supports_warm_start`` gate the sharded and repartition front doors,
``short`` names refiners in composed method strings). A registration
relying on implicit defaults reads as "unknown capability" in review and
silently loses the capability when the default changes — every call site
states its contract.
"""
from __future__ import annotations

from .astutil import ModuleInfo, call_tail
from .diagnostics import Diagnostic

#: registrar name -> kwargs every call site must pass explicitly
REQUIRED = {
    "register_assign_backend": ("supports_moments",),
    "register_algorithm": ("supports_devices", "supports_warm_start"),
    "register_refiner": ("short",),
}


def check(mod: ModuleInfo) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for call in mod.walk_calls(mod.tree):
        tail = call_tail(call)
        required = REQUIRED.get(tail or "")
        if required is None:
            continue
        if not call.args and not call.keywords:
            continue  # zero-arg call: not a registration site
        passed = {kw.arg for kw in call.keywords}
        if any(kw.arg is None for kw in call.keywords):
            continue  # **kwargs splat: capabilities forwarded verbatim
        missing = [k for k in required if k not in passed]
        if missing:
            out.append(Diagnostic(
                rule="REG001", path=mod.path, line=call.lineno,
                col=call.col_offset,
                message=f"{tail}(...) must declare "
                        f"{', '.join(missing)} explicitly (capability "
                        "kwargs are part of the registration contract)",
                symbol=mod.symbol_at(call)))
    return out
