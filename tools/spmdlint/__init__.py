"""spmdlint — repo-specific static analysis for the SPMD discipline.

The paper's scalability argument (§4.1) rests on a communication
discipline — *global vector sums only* — plus trace-safety and Pallas
lowering constraints that DESIGN.md states in prose. This package
machine-checks them:

========  ==================================================================
rule id   meaning
========  ==================================================================
SPMD001   forbidden collective (``all_gather``/``all_to_all``/``ppermute``/
          ``pshuffle``/``pswapaxes``) — only ``psum``/``pmin``/``pmax``
          reductions are sanctioned inside SPMD bodies
SPMD002   axis-name string literal not in the declared axis universe
          (``dist.rules``; configurable via ``[spmd] axes`` in
          spmdlint.toml)
SPMD003   ``# spmdlint: psum-budget=N`` assertion failed: the function's
          statically counted psum call sites (direct + via local helpers)
          differ from the declared per-round budget
TRC001    ``int()``/``float()``/``bool()``/``len()``/``.item()``/
          ``.tolist()`` on a traced value inside a jitted/shard_mapped/
          scanned body (the TracerIntegerConversionError class of bug)
TRC002    ``np.*`` call on a traced value inside a traced body
TRC003    Python ``if``/``while`` on a traced expression inside a traced
          body (host control flow on device data)
KER001    op outside the Mosaic-lowerable allowlist inside a Pallas kernel
          body (reached from ``pl.pallas_call``)
KER002    ``make_async_copy`` without a matching ``.start()``/``.wait()``
          semaphore pair in the same function
KER003    ``pl.pallas_call`` wrapper without a tile-multiple shape check
          (``_check_tiling`` call or explicit ``raise ValueError``)
REG001    registry call site missing explicit capability kwargs
          (``supports_moments`` / ``supports_devices`` +
          ``supports_warm_start`` / ``short``)
========  ==================================================================

Run ``python -m tools.spmdlint src tests benchmarks tools``; sanctioned
exceptions live in ``spmdlint.toml`` (see DESIGN.md §12). The dynamic
companion is :mod:`tools.spmdlint.runtime` — a pytest plugin with a jit
retrace sentinel and an opt-in debug-NaNs + leak-checking mode.
"""
from __future__ import annotations

__version__ = "1.0"

from .diagnostics import Diagnostic  # noqa: F401
from .engine import lint_paths, lint_source, main  # noqa: F401

RULES = {
    "SPMD001": "forbidden collective inside an SPMD body (psum-only "
               "discipline, paper §4.1)",
    "SPMD002": "axis-name literal outside the declared axis universe",
    "SPMD003": "psum-budget assertion failed (# spmdlint: psum-budget=N)",
    "TRC001": "host conversion (int/float/bool/len/.item) of a traced value",
    "TRC002": "np.* call on a traced value inside a traced body",
    "TRC003": "Python if/while on a traced expression",
    "KER001": "op outside the Mosaic-lowerable allowlist in a Pallas kernel",
    "KER002": "make_async_copy without a matching semaphore start/wait pair",
    "KER003": "pallas_call wrapper without a tile-multiple shape check",
    "REG001": "registry call site missing explicit capability kwargs",
}
