"""spmdlint.toml loading + waiver matching.

Waiver entries silence one rule at one site::

    [[waiver]]
    rule = "SPMD001"
    path = "src/repro/core/partitioner.py"
    symbol = "_sfc_redistribute"        # optional; omit = whole file
    reason = "why this site is sanctioned"

``path`` matches by normalized suffix, so waivers keep working whether
the linter is invoked from the repo root or with absolute paths.
``symbol`` matches the diagnostic's in-file qualname exactly or as a
trailing component (``local.body`` matches ``symbol = "body"``). The
optional ``[spmd] axes`` array overrides the declared axis-name universe
for SPMD002.

Python 3.10 has no ``tomllib``; ``_parse_mini_toml`` covers the subset
this file needs (tables, arrays of tables, string/number/bool/array
values, comments).
"""
from __future__ import annotations

import os
from dataclasses import dataclass


@dataclass(frozen=True)
class Waiver:
    rule: str
    path: str
    symbol: str | None = None
    reason: str = ""

    def matches(self, diag) -> bool:
        if self.rule != diag.rule:
            return False
        want = self.path.replace(os.sep, "/").lstrip("./")
        got = diag.path.replace(os.sep, "/")
        if not (got == want or got.endswith("/" + want)):
            return False
        if self.symbol is None:
            return True
        sym = diag.symbol
        return sym == self.symbol or sym.endswith("." + self.symbol)


@dataclass
class Config:
    waivers: list[Waiver]
    axes: frozenset[str] | None = None   # None = rule default
    source: str | None = None


def load_config(path: str | None) -> Config:
    """Load ``spmdlint.toml``; a missing/None path is an empty config."""
    if path is None or not os.path.exists(path):
        return Config(waivers=[], source=None)
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    try:
        import tomllib
        data = tomllib.loads(text)
    except ModuleNotFoundError:
        data = _parse_mini_toml(text)
    waivers = []
    for entry in data.get("waiver", []):
        if "rule" not in entry or "path" not in entry:
            raise ValueError(
                f"{path}: every [[waiver]] needs 'rule' and 'path' keys, "
                f"got {sorted(entry)}")
        waivers.append(Waiver(rule=str(entry["rule"]),
                              path=str(entry["path"]),
                              symbol=entry.get("symbol"),
                              reason=str(entry.get("reason", ""))))
    axes = data.get("spmd", {}).get("axes")
    return Config(waivers=waivers,
                  axes=frozenset(axes) if axes is not None else None,
                  source=path)


def _parse_mini_toml(text: str) -> dict:
    """TOML subset: ``[table]`` / ``[[array-of-tables]]`` headers and
    ``key = value`` lines with string, integer, float, boolean, or flat
    string-array values. Enough for spmdlint.toml on Python < 3.11."""
    root: dict = {}
    current = root
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            root.setdefault(name, []).append(current)
            continue
        if line.startswith("[") and line.endswith("]"):
            name = line[1:-1].strip()
            current = root.setdefault(name, {})
            continue
        if "=" not in line:
            raise ValueError(f"spmdlint.toml:{lineno}: expected key = "
                             f"value, got {raw!r}")
        key, _, rest = line.partition("=")
        current[key.strip()] = _parse_value(rest.strip(), lineno)
    return root


def _parse_value(token: str, lineno: int):
    if token.startswith('"'):
        end = _string_end(token, lineno)
        return token[1:end]
    if token.startswith("["):
        body = token[1:token.rindex("]")].strip()
        if not body:
            return []
        return [_parse_value(item.strip(), lineno)
                for item in _split_array(body)]
    if token in ("true", "false"):
        return token == "true"
    bare = token.split("#", 1)[0].strip()
    try:
        return int(bare)
    except ValueError:
        pass
    try:
        return float(bare)
    except ValueError:
        raise ValueError(f"spmdlint.toml:{lineno}: unsupported value "
                         f"{token!r}") from None


def _string_end(token: str, lineno: int) -> int:
    i = 1
    while i < len(token):
        if token[i] == "\\":
            i += 2
            continue
        if token[i] == '"':
            return i
        i += 1
    raise ValueError(f"spmdlint.toml:{lineno}: unterminated string")


def _split_array(body: str) -> list[str]:
    items, depth, start, in_str = [], 0, 0, False
    for i, ch in enumerate(body):
        if ch == '"' and (i == 0 or body[i - 1] != "\\"):
            in_str = not in_str
        elif not in_str:
            if ch == "[":
                depth += 1
            elif ch == "]":
                depth -= 1
            elif ch == "," and depth == 0:
                items.append(body[start:i])
                start = i + 1
    last = body[start:].strip()
    if last:
        items.append(last)
    return items
