"""TRC001-003 — trace-safety inside jitted / shard_mapped / scanned bodies.

A *traced body* (astutil discovery) gets a forward taint pass: its
parameters are traced values (minus statically-known ``static_argnames``),
taint flows through arithmetic, calls, subscripts and assignments, and is
*cut* by shape-metadata attribute access (``.shape``/``.ndim``/``.dtype``
are static under tracing). Findings:

* TRC001 — ``int()``/``float()``/``bool()``/``len()`` or ``.item()``/
  ``.tolist()`` on a tainted value: concretization, raises
  ``TracerIntegerConversionError``/``ConcretizationTypeError`` at trace
  time (the PR 4 bug class). Conversions inside a ``try`` whose handler
  catches a jax tracer error are *guarded concretizations* (the
  documented ``balanced_kmeans`` warm-up pattern) and are exempt.
* TRC002 — ``np.*``/``numpy.*`` call with a tainted argument: silently
  constant-folds or crashes under trace; use ``jnp``.
* TRC003 — Python ``if``/``while`` on a tainted test: host control flow
  on device data; use ``jnp.where``/``lax.cond``/``lax.while_loop``.
"""
from __future__ import annotations

import ast

from .astutil import FuncInfo, ModuleInfo, dotted_name
from .diagnostics import Diagnostic

_CONVERTERS = {"int", "float", "bool", "len"}
_CONV_METHODS = {"item", "tolist"}
#: attribute reads that are static under tracing — they cut taint
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding",
                 "weak_type"}
_GUARD_MARKERS = ("Tracer", "Concretization", "jax.errors")


def check(mod: ModuleInfo) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for info in mod.functions:
        if info.traced:
            out.extend(_check_body(mod, info))
    return out


def _check_body(mod: ModuleInfo, info: FuncInfo) -> list[Diagnostic]:
    tainted = set(info.params) - info.static_params
    out: list[Diagnostic] = []
    body = info.body_nodes()
    for stmt in body:
        _walk_stmt(mod, info, stmt, tainted, out)
    return out


def _walk_stmt(mod, info, stmt, tainted: set[str], out: list[Diagnostic]):
    if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        return  # nested bodies are analyzed on their own (when traced)
    if isinstance(stmt, (ast.If, ast.While)):
        if _is_tainted(stmt.test, tainted):
            out.append(_diag(mod, stmt, "TRC003",
                             f"Python {type(stmt).__name__.lower()!r} on a "
                             "traced expression; use jnp.where / lax.cond "
                             "/ lax.while_loop", info))
        _scan_expr_tree(mod, info, stmt.test, tainted, out)
        for sub in stmt.body + stmt.orelse:
            _walk_stmt(mod, info, sub, tainted, out)
        return
    if isinstance(stmt, ast.Try):
        guarded = _guards_tracer_errors(stmt)
        for sub in stmt.body:
            _walk_stmt(mod, info, sub, set() if guarded else tainted, out)
        for handler in stmt.handlers:
            for sub in handler.body:
                _walk_stmt(mod, info, sub, tainted, out)
        for sub in stmt.orelse + stmt.finalbody:
            _walk_stmt(mod, info, sub, tainted, out)
        return
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        value = stmt.value
        if value is not None:
            _scan_expr_tree(mod, info, value, tainted, out)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            is_tainted = _is_tainted(value, tainted)
            for tgt in targets:
                for name in _target_names(tgt):
                    if is_tainted:
                        tainted.add(name)
                    else:
                        tainted.discard(name)
        return
    if isinstance(stmt, (ast.For,)):
        _scan_expr_tree(mod, info, stmt.iter, tainted, out)
        if _is_tainted(stmt.iter, tainted):
            for name in _target_names(stmt.target):
                tainted.add(name)
        for sub in stmt.body + stmt.orelse:
            _walk_stmt(mod, info, sub, tainted, out)
        return
    if isinstance(stmt, (ast.With,)):
        for item in stmt.items:
            _scan_expr_tree(mod, info, item.context_expr, tainted, out)
        for sub in stmt.body:
            _walk_stmt(mod, info, sub, tainted, out)
        return
    # generic statement: scan all expressions, skip nested defs
    for child in ast.iter_child_nodes(stmt):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        if isinstance(child, ast.expr):
            _scan_expr_tree(mod, info, child, tainted, out)
        elif isinstance(child, ast.stmt):
            _walk_stmt(mod, info, child, tainted, out)


def _scan_expr_tree(mod, info, expr, tainted, out):
    for node in ast.walk(expr):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        args_tainted = any(_is_tainted(a, tainted) for a in node.args)
        if name in _CONVERTERS and args_tainted:
            out.append(_diag(
                mod, node, "TRC001",
                f"{name}() on a traced value concretizes at trace time"
                + ("; use x.shape[0]" if name == "len" else
                   "; keep it an array or hoist to a static argument"),
                info))
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr in _CONV_METHODS
              and _is_tainted(node.func.value, tainted)):
            out.append(_diag(
                mod, node, "TRC001",
                f".{node.func.attr}() on a traced value concretizes at "
                "trace time", info))
        elif (name and name.split(".", 1)[0] in ("np", "numpy")
              and (args_tainted
                   or any(_is_tainted(kw.value, tainted)
                          for kw in node.keywords))):
            out.append(_diag(
                mod, node, "TRC002",
                f"{name}() on a traced value constant-folds or crashes "
                "under trace; use jnp", info))


def _is_tainted(expr: ast.AST, tainted: set[str]) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Attribute):
        if expr.attr in _STATIC_ATTRS:
            return False
        return _is_tainted(expr.value, tainted)
    if isinstance(expr, ast.Subscript):
        return _is_tainted(expr.value, tainted)
    if isinstance(expr, ast.Call):
        return (any(_is_tainted(a, tainted) for a in expr.args)
                or any(_is_tainted(kw.value, tainted)
                       for kw in expr.keywords))
    if isinstance(expr, ast.BinOp):
        return _is_tainted(expr.left, tainted) or _is_tainted(expr.right,
                                                              tainted)
    if isinstance(expr, ast.UnaryOp):
        return _is_tainted(expr.operand, tainted)
    if isinstance(expr, ast.BoolOp):
        return any(_is_tainted(v, tainted) for v in expr.values)
    if isinstance(expr, ast.Compare):
        # `x is None` / `x is not None` are static structural checks on
        # the python object, never on traced data
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return False
        return (_is_tainted(expr.left, tainted)
                or any(_is_tainted(c, tainted) for c in expr.comparators))
    if isinstance(expr, ast.IfExp):
        return (_is_tainted(expr.body, tainted)
                or _is_tainted(expr.orelse, tainted))
    if isinstance(expr, (ast.Tuple, ast.List)):
        return any(_is_tainted(e, tainted) for e in expr.elts)
    if isinstance(expr, ast.Starred):
        return _is_tainted(expr.value, tainted)
    return False


def _target_names(tgt: ast.AST) -> list[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for elt in tgt.elts:
            out.extend(_target_names(elt))
        return out
    if isinstance(tgt, ast.Starred):
        return _target_names(tgt.value)
    return []


def _guards_tracer_errors(stmt: ast.Try) -> bool:
    for handler in stmt.handlers:
        if handler.type is None:
            continue
        src = ast.unparse(handler.type)
        if any(marker in src for marker in _GUARD_MARKERS):
            return True
    return False


def _diag(mod, node, rule, message, info: FuncInfo) -> Diagnostic:
    return Diagnostic(rule=rule, path=mod.path, line=node.lineno,
                      col=node.col_offset, message=message,
                      symbol=info.qualname)
