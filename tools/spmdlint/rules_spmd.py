"""SPMD001-003 — the paper's §4.1 communication discipline.

* SPMD001: the only sanctioned collectives are the global reductions
  ``psum`` / ``pmin`` / ``pmax`` / ``pmean`` (plus ``axis_index`` /
  ``psum_scatter`` bookkeeping). Any call site of ``all_gather`` /
  ``all_to_all`` / ``ppermute`` / ``pshuffle`` / ``pswapaxes`` is
  flagged wherever it appears — a helper only ever matters once it is
  wired into a shard_map body, and flagging at the definition catches it
  before that wiring lands.
* SPMD002: collectives taking an axis name as a *string literal* must
  use a declared axis (``dist.rules``: ``shard``/``data``/``model``/
  ``pod`` by default; override via ``[spmd] axes`` in spmdlint.toml).
  Axis names passed as variables resolve to the same constants and are
  out of scope here.
* SPMD003: a ``# spmdlint: psum-budget=N`` directive on a ``def`` line
  asserts that function's statically counted psum call sites — direct
  ``lax.psum`` calls plus calls to locally defined helpers, weighted by
  the helper's own count — equal N. This pins the documented per-round
  communication budgets (eval/sharded.py and partition/refine.py: 4
  psums/round) so a refactor that silently adds a collective fails lint.
"""
from __future__ import annotations

import ast

from .astutil import FuncInfo, ModuleInfo, call_tail, dotted_name
from .diagnostics import Diagnostic

FORBIDDEN = {"all_gather", "all_to_all", "ppermute", "pshuffle",
             "pswapaxes"}
#: collectives whose axis argument SPMD002 inspects: tail -> positional
#: index of the axis-name argument
_AXIS_ARG = {"psum": 1, "pmax": 1, "pmin": 1, "pmean": 1, "all_gather": 1,
             "all_to_all": 1, "ppermute": 1, "axis_index": 0,
             "psum_scatter": 1}
DEFAULT_AXES = frozenset({"shard", "data", "model", "pod"})


def check(mod: ModuleInfo, allowed_axes=DEFAULT_AXES) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    for call in mod.walk_calls(mod.tree):
        tail = call_tail(call)
        if tail in FORBIDDEN and _looks_like_lax(call):
            out.append(Diagnostic(
                rule="SPMD001", path=mod.path, line=call.lineno,
                col=call.col_offset,
                message=f"{tail} breaks the psum-only communication "
                        "discipline (paper §4.1); restructure on global "
                        "reductions or add a spmdlint.toml waiver",
                symbol=mod.symbol_at(call)))
        if tail in _AXIS_ARG:
            axis = _axis_literal(call, _AXIS_ARG[tail])
            if axis is not None and axis not in allowed_axes:
                out.append(Diagnostic(
                    rule="SPMD002", path=mod.path, line=call.lineno,
                    col=call.col_offset,
                    message=f"axis name {axis!r} is not a declared mesh "
                            f"axis ({sorted(allowed_axes)}); use the "
                            "dist.rules constants",
                    symbol=mod.symbol_at(call)))
    out.extend(_check_budgets(mod))
    return out


def _looks_like_lax(call: ast.Call) -> bool:
    """True unless the callee is clearly a non-jax namespace (e.g. an
    mpi4py-style ``comm.all_gather``) — bare names and jax/lax dotted
    paths all count."""
    name = dotted_name(call.func)
    if name is None:
        return False
    parts = name.split(".")
    return len(parts) == 1 or parts[0] in ("jax", "lax") or "lax" in parts


def _axis_literal(call: ast.Call, pos: int) -> str | None:
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis"):
            val = kw.value
            return val.value if (isinstance(val, ast.Constant)
                                 and isinstance(val.value, str)) else None
    if len(call.args) > pos:
        val = call.args[pos]
        if isinstance(val, ast.Constant) and isinstance(val.value, str):
            return val.value
    return None


# -- SPMD003: psum budgets ----------------------------------------------

def _check_budgets(mod: ModuleInfo) -> list[Diagnostic]:
    out = []
    for info in mod.functions:
        raw = info.directives.get("psum-budget")
        if raw is None:
            continue
        try:
            declared = int(raw)
        except ValueError:
            out.append(Diagnostic(
                rule="SPMD003", path=mod.path, line=info.node.lineno,
                col=info.node.col_offset,
                message=f"unparseable psum-budget {raw!r} (expected an "
                        "integer)", symbol=info.qualname))
            continue
        counted = _psum_weight(mod, info, set())
        if counted != declared:
            out.append(Diagnostic(
                rule="SPMD003", path=mod.path, line=info.node.lineno,
                col=info.node.col_offset,
                message=f"psum budget mismatch: declared {declared}, "
                        f"counted {counted} call site(s) (direct + via "
                        "local helpers)", symbol=info.qualname))
    return out


def _psum_weight(mod: ModuleInfo, info: FuncInfo,
                 visiting: set[int]) -> int:
    """Static psum call-site count of one function: direct ``psum`` calls
    in its own body (nested defs excluded) plus, per call to a locally
    resolvable function, that helper's own weight."""
    if id(info) in visiting:
        return 0
    visiting.add(id(info))
    total = 0
    for node in mod.own_body_walk(info):
        if not isinstance(node, ast.Call):
            continue
        tail = call_tail(node)
        if tail == "psum":
            total += 1
        elif tail is not None and "." not in (dotted_name(node.func) or "."):
            helper = mod.lookup(tail, info)
            if helper is not None:
                total += _psum_weight(mod, helper, visiting)
    visiting.discard(id(info))
    return total
