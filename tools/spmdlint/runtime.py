"""Runtime companion to the static pass: retrace sentinel + sanitizers.

Static analysis cannot see whether a *steady-state* call path recompiles
— a config object that hashes by identity, a shape that wobbles between
calls, or a fresh lru key per step all type-check fine and then retrace
on every call, which at the paper's scale turns an O(ms) dispatch into
an O(s) compile. This module watches the repo's hot entry points:

* jitted functions (``_cache_size()``): ``core.partitioner._run_jit`` /
  ``_run_warm_jit`` (the ``partition``/``repartition`` front doors) and
  ``partition.batched._bucket_jit`` / ``_batched_jit`` / ``_single_jit``
  (the ``PartitionServer`` bucket dispatch);
* lru-cached shard_map builders (``cache_info().misses``):
  ``partition.distributed._build_runner``, ``eval.sharded
  ._build_metrics_fn``, ``partition.refine._build_lp_runner``.

Use :class:`RetraceSentinel` directly, or as the ``retrace_sentinel``
pytest fixture::

    pytest -p tools.spmdlint.runtime ...

    def test_serving_steady_state(retrace_sentinel):
        server.step(...)                   # warm-up: compiles are fine
        with retrace_sentinel() as s:
            server.step(...)               # steady state
        s.assert_steady()                  # raises RetraceError on growth

The plugin also ships an opt-in sanitizer mode (``--spmdlint-sanitize``
or ``SPMDLINT_SANITIZE=1``): every test runs under
``jax.checking_leaks`` with ``jax_debug_nans`` enabled, surfacing leaked
tracers and silent NaN production at their source instead of three
layers downstream.
"""
from __future__ import annotations

import importlib
import os

#: (label, module, attribute) of every watched hot entry point
HOT_ENTRY_POINTS: tuple[tuple[str, str, str], ...] = (
    ("partition", "repro.core.partitioner", "_run_jit"),
    ("repartition", "repro.core.partitioner", "_run_warm_jit"),
    ("serve.bucket", "repro.partition.batched", "_bucket_jit"),
    ("serve.batched", "repro.partition.batched", "_batched_jit"),
    ("serve.single", "repro.partition.batched", "_single_jit"),
    ("sharded.runner", "repro.partition.distributed", "_build_runner"),
    ("sharded.metrics", "repro.eval.sharded", "_build_metrics_fn"),
    ("refine.runner", "repro.partition.refine", "_build_lp_runner"),
)


class RetraceError(AssertionError):
    """A watched entry point recompiled during a steady-state window."""


def _compile_count(fn) -> int | None:
    """Best-effort compile/trace counter for one entry point: jitted
    functions expose ``_cache_size()``; lru-cached builders expose
    ``cache_info().misses`` (each miss builds + compiles a new runner)."""
    cache_size = getattr(fn, "_cache_size", None)
    if callable(cache_size):
        try:
            return int(cache_size())
        except Exception:
            return None
    cache_info = getattr(fn, "cache_info", None)
    if callable(cache_info):
        return int(cache_info().misses)
    return None


class RetraceSentinel:
    """Snapshot/compare compile counts over the hot entry points.

    Extra callables can be watched with :meth:`track` (used by the
    planted-recompilation acceptance test). Use as a context manager
    around the steady-state window, then :meth:`assert_steady`.
    """

    def __init__(self, extra: dict | None = None):
        self._fns: dict[str, object] = {}
        for label, module, attr in HOT_ENTRY_POINTS:
            try:
                mod = importlib.import_module(module)
            except Exception:
                continue  # optional surface not importable in this env
            fn = getattr(mod, attr, None)
            if fn is not None and _compile_count(fn) is not None:
                self._fns[label] = fn
        for label, fn in (extra or {}).items():
            self.track(label, fn)
        self._baseline: dict[str, int] = {}

    def track(self, label: str, fn) -> None:
        if _compile_count(fn) is None:
            raise TypeError(
                f"{label}: {fn!r} exposes neither _cache_size() (jit) "
                "nor cache_info() (lru builder); nothing to watch")
        self._fns[label] = fn

    def snapshot(self) -> dict[str, int]:
        return {label: _compile_count(fn)
                for label, fn in self._fns.items()}

    def __enter__(self) -> "RetraceSentinel":
        self._baseline = self.snapshot()
        return self

    def __exit__(self, *exc) -> None:
        return None

    def deltas(self) -> dict[str, int]:
        """Compiles since the last ``__enter__`` (only nonzero entries)."""
        now = self.snapshot()
        return {label: now[label] - self._baseline.get(label, now[label])
                for label in now
                if now[label] != self._baseline.get(label, now[label])}

    def assert_steady(self) -> None:
        """Raise :class:`RetraceError` if anything compiled in-window."""
        grew = self.deltas()
        if grew:
            detail = ", ".join(f"{k}: +{v}" for k, v in sorted(grew.items()))
            raise RetraceError(
                f"steady-state retrace detected ({detail}); a static "
                "argument is hashing by identity or a shape/dtype is "
                "wobbling between calls — see tools/spmdlint/runtime.py")


# --------------------------------------------------------------------------
# pytest plugin


def pytest_addoption(parser):
    group = parser.getgroup("spmdlint")
    group.addoption(
        "--spmdlint-sanitize", action="store_true", default=False,
        help="run every test under jax.checking_leaks with "
             "jax_debug_nans enabled (also: SPMDLINT_SANITIZE=1)")


def _sanitize_enabled(config) -> bool:
    return (config.getoption("--spmdlint-sanitize", default=False)
            or os.environ.get("SPMDLINT_SANITIZE", "") == "1")


def pytest_configure(config):
    if _sanitize_enabled(config):
        import jax
        jax.config.update("jax_debug_nans", True)


try:
    import pytest
except ImportError:                                    # pragma: no cover
    pytest = None

if pytest is not None:
    @pytest.fixture
    def retrace_sentinel():
        """Factory for :class:`RetraceSentinel` context managers."""
        return RetraceSentinel

    @pytest.hookimpl(hookwrapper=True)
    def pytest_runtest_call(item):
        if _sanitize_enabled(item.config):
            import jax
            with jax.checking_leaks():
                yield
        else:
            yield
