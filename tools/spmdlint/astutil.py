"""AST plumbing shared by the rule modules.

One :class:`ModuleInfo` per linted file: the parse tree, every function
with its in-file qualname and parent chain, which functions are *traced
bodies* (jit-decorated, or passed to ``shard_map`` / ``lax.scan`` /
``while_loop`` / ``fori_loop`` / ``cond`` / ``switch`` / ``map`` /
``vmap`` / ``pl.pallas_call``) and with which statically-known
``static_argnames``, plus the ``# spmdlint:`` directive comments.

The analysis is deliberately *local*: only functions the module itself
hands to a tracing wrapper are treated as traced, and taint never flows
through closures — that keeps the pass quiet on the large host-side
surface while still covering every SPMD body in the repo (they are all
wrapped where they are defined).
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

# wrappers whose function-valued arguments become traced bodies
_TRACING_WRAPPERS = {
    "shard_map", "scan", "while_loop", "fori_loop", "cond", "switch",
    "map", "vmap", "pmap", "jit", "pallas_call", "checkpoint", "remat",
    "custom_vjp", "custom_jvp", "grad", "value_and_grad",
}

_DIRECTIVE_RE = re.compile(r"#\s*spmdlint:\s*([a-z-]+)\s*=\s*(\S+)")


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_tail(call: ast.Call) -> str | None:
    """Last component of the called dotted name (``jax.lax.psum`` ->
    ``psum``); None for computed callees."""
    name = dotted_name(call.func)
    return name.rsplit(".", 1)[-1] if name else None


@dataclass
class FuncInfo:
    """One function (def or lambda) with its lint-relevant metadata."""
    node: ast.FunctionDef | ast.AsyncFunctionDef | ast.Lambda
    qualname: str
    parent: "FuncInfo | None" = None
    traced: bool = False
    traced_reason: str = ""
    static_params: set[str] = field(default_factory=set)
    directives: dict[str, str] = field(default_factory=dict)

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")

    @property
    def params(self) -> list[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    def body_nodes(self):
        body = self.node.body
        return body if isinstance(body, list) else [body]


class ModuleInfo:
    """Parsed view of one file, shared by all rules."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.functions: list[FuncInfo] = []
        #: node id -> FuncInfo (for wrapper-argument resolution)
        self._by_node: dict[int, FuncInfo] = {}
        #: per-scope simple-name index: scope FuncInfo|None -> {name: info}
        self._scope_defs: dict[int | None, dict[str, FuncInfo]] = {None: {}}
        #: name -> Call node of a ``partial(...)`` it was assigned from
        self._partial_aliases: dict[str, ast.Call] = {}
        #: imported simple name -> source module string ("" for plain
        #: ``import x``; leading dots kept for relative imports)
        self.imports: dict[str, str] = {}
        self._collect_imports()
        self._collect_functions()
        self._attach_directives()
        self._mark_traced()

    # -- construction ---------------------------------------------------

    def _collect_imports(self):
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ImportFrom):
                module = "." * node.level + (node.module or "")
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = module
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    name = alias.asname or alias.name.split(".", 1)[0]
                    self.imports[name] = alias.name

    def _collect_functions(self):
        def walk(node: ast.AST, parent: FuncInfo | None, prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    info = FuncInfo(child, qual, parent)
                    self._register(info, parent)
                    walk(child, info, qual + ".")
                elif isinstance(child, ast.Lambda):
                    info = FuncInfo(child, f"{prefix}<lambda>", parent)
                    self._register(info, parent)
                    walk(child, info, f"{prefix}<lambda>.")
                elif isinstance(child, ast.Assign) and parent is not None:
                    # `kernel = functools.partial(_body, ...)` aliasing,
                    # later resolved when `kernel` reaches pallas_call
                    if (isinstance(child.value, ast.Call)
                            and call_tail(child.value) == "partial"):
                        for tgt in child.targets:
                            if isinstance(tgt, ast.Name):
                                self._partial_aliases[tgt.id] = child.value
                    walk(child, parent, prefix)
                elif isinstance(child, ast.ClassDef):
                    walk(child, parent, f"{prefix}{child.name}.")
                else:
                    walk(child, parent, prefix)

        walk(self.tree, None, "")

    def _register(self, info: FuncInfo, parent: FuncInfo | None):
        self.functions.append(info)
        self._by_node[id(info.node)] = info
        key = id(parent) if parent is not None else None
        self._scope_defs.setdefault(key, {})[info.name] = info

    def _attach_directives(self):
        """``# spmdlint: key=value`` comments attach to the function whose
        ``def`` line carries them, else to the innermost function spanning
        the comment's line. Real COMMENT tokens only — a directive-shaped
        substring inside a string literal is not a directive."""
        import io
        import tokenize
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.source).readline))
        except (tokenize.TokenError, IndentationError):
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            lineno = tok.start[0]
            m = _DIRECTIVE_RE.search(tok.string)
            if not m:
                continue
            target = None
            for info in self.functions:
                node = info.node
                if getattr(node, "lineno", None) == lineno:
                    target = info
                    break
                end = getattr(node, "end_lineno", node.lineno)
                if node.lineno <= lineno <= end:
                    if target is None or node.lineno > target.node.lineno:
                        target = info
            if target is not None:
                target.directives[m.group(1)] = m.group(2)

    # -- traced-body discovery ------------------------------------------

    def _mark_traced(self):
        for info in self.functions:
            if not isinstance(info.node, ast.Lambda):
                self._mark_if_jit_decorated(info)
        for call in self.walk_calls(self.tree):
            tail = call_tail(call)
            if tail not in _TRACING_WRAPPERS:
                continue
            scope = self.enclosing(call)
            reason = tail
            static = self._static_argnames(call) if tail == "jit" else set()
            for arg in call.args:
                for fn in self._resolve_function_args(arg, scope):
                    if not fn.traced:
                        fn.traced = True
                        fn.traced_reason = reason
                        fn.static_params |= static
                if tail == "pallas_call":
                    break  # only the first positional arg is the kernel

    def _mark_if_jit_decorated(self, info: FuncInfo):
        for deco in getattr(info.node, "decorator_list", []):
            name = dotted_name(deco)
            if name and name.rsplit(".", 1)[-1] == "jit":
                info.traced, info.traced_reason = True, "jit"
                return
            if isinstance(deco, ast.Call):
                tail = call_tail(deco)
                if tail == "jit":
                    info.traced, info.traced_reason = True, "jit"
                    info.static_params |= self._static_argnames(deco)
                    return
                if tail == "partial" and deco.args:
                    inner = dotted_name(deco.args[0])
                    if inner and inner.rsplit(".", 1)[-1] == "jit":
                        info.traced, info.traced_reason = True, "jit"
                        info.static_params |= self._static_argnames(deco)
                        return

    @staticmethod
    def _static_argnames(call: ast.Call) -> set[str]:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                try:
                    val = ast.literal_eval(kw.value)
                except ValueError:
                    return set()
                if isinstance(val, str):
                    return {val}
                return set(val)
        return set()

    def _resolve_function_args(self, arg: ast.AST,
                               scope: FuncInfo | None) -> list[FuncInfo]:
        """Function bodies an argument expression may refer to: inline
        lambdas, names of locally/module-defined functions, ``partial``
        wrappers (inline or via a local alias), and list/tuple literals
        of those (``lax.switch`` branches)."""
        if isinstance(arg, ast.Lambda):
            info = self._by_node.get(id(arg))
            return [info] if info else []
        if isinstance(arg, (ast.List, ast.Tuple)):
            out = []
            for elt in arg.elts:
                out.extend(self._resolve_function_args(elt, scope))
            return out
        if isinstance(arg, ast.Call) and call_tail(arg) == "partial":
            return (self._resolve_function_args(arg.args[0], scope)
                    if arg.args else [])
        if isinstance(arg, ast.Name):
            if arg.id in self._partial_aliases:
                inner = self._partial_aliases[arg.id]
                if inner.args:
                    return self._resolve_function_args(inner.args[0], scope)
            fn = self.lookup(arg.id, scope)
            return [fn] if fn else []
        return []

    # -- queries ---------------------------------------------------------

    def lookup(self, name: str, scope: FuncInfo | None) -> FuncInfo | None:
        """Resolve a simple name to a function defined in ``scope`` or any
        enclosing scope (lexical)."""
        while True:
            found = self._scope_defs.get(
                id(scope) if scope is not None else None, {}).get(name)
            if found is not None:
                return found
            if scope is None:
                return None
            scope = scope.parent

    def enclosing(self, node: ast.AST) -> FuncInfo | None:
        """Innermost function whose span contains ``node`` (by position)."""
        lineno = getattr(node, "lineno", None)
        if lineno is None:
            return None
        best = None
        for info in self.functions:
            n = info.node
            end = getattr(n, "end_lineno", n.lineno)
            if n.lineno <= lineno <= end:
                if best is None or n.lineno >= best.node.lineno:
                    best = info
        return best

    def symbol_at(self, node: ast.AST) -> str:
        info = self.enclosing(node)
        return info.qualname if info else "<module>"

    @staticmethod
    def walk_calls(root: ast.AST):
        for node in ast.walk(root):
            if isinstance(node, ast.Call):
                yield node

    def own_body_walk(self, info: FuncInfo):
        """Walk a function's AST *excluding* nested function subtrees."""
        stack = [n for n in info.body_nodes()
                 if not isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef, ast.Lambda))]
        while stack:
            node = stack.pop()
            yield node
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Lambda)):
                    continue
                stack.append(child)
