"""Diagnostic record + formatting shared by every rule module."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Diagnostic:
    """One finding.

    Attributes:
        rule: rule id (e.g. ``"SPMD001"``).
        path: file the finding is in (as given to the engine).
        line: 1-based line number.
        col: 0-based column offset.
        message: human-readable explanation.
        symbol: dotted in-file qualname of the enclosing function (or
            ``"<module>"``) — the key waivers match against.
    """
    rule: str
    path: str
    line: int
    col: int
    message: str
    symbol: str = "<module>"
    waived_by: str | None = field(default=None, compare=False)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col + 1}"
        tag = " (waived)" if self.waived_by else ""
        return f"{loc}: {self.rule} {self.message} [{self.symbol}]{tag}"
