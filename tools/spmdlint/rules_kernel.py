"""KER001-003 — Pallas kernel discipline (DESIGN.md §4b/§10, PR 6).

* KER001: kernel bodies (the function handed to ``pl.pallas_call``, plus
  every same-module helper reachable from it) may only call ops from a
  Mosaic-lowerable allowlist — ``jnp``/``jax.lax``/``jax.nn`` elementwise
  + reduction + iota + dot ops, ``pl.*`` primitives, ``pltpu.*`` DMA
  plumbing, array methods, local helpers, and static Python builtins.
  ``np.*``, ``print``, I/O, or arbitrary library calls fail lowering on a
  real TPU even when the interpreter leg happily runs them.
* KER002: a function calling ``pltpu.make_async_copy`` must also call
  ``.start()`` and ``.wait()`` (the DMA semaphore pair) — a started copy
  without a wait races the consumer, a wait without a start deadlocks.
* KER003: every function invoking ``pl.pallas_call`` must validate its
  tile-multiple shape contract first — either by calling a
  ``*check_tiling*`` helper or by raising ``ValueError`` itself (the
  PR 6 naming-ValueError contract). A bare ``assert`` vanishes under
  ``python -O`` and reports nothing actionable.
"""
from __future__ import annotations

import ast

from .astutil import FuncInfo, ModuleInfo, call_tail, dotted_name
from .diagnostics import Diagnostic

JNP_ALLOW = {
    "where", "sum", "maximum", "minimum", "full_like", "zeros_like",
    "ones_like", "zeros", "ones", "full", "min", "max", "argmin",
    "argmax", "exp", "tanh", "sqrt", "log", "abs", "square", "isfinite",
    "isnan", "isinf", "clip", "dot", "float32", "bfloat16", "int32",
    "uint32", "bool_", "logical_and", "logical_or", "logical_not",
    "cumsum", "cummax", "reciprocal", "rint", "floor", "ceil", "sign",
    "power", "mod", "broadcast_to", "expand_dims", "squeeze", "swapaxes",
    "einsum", "add", "subtract", "multiply", "divide", "negative",
    "concatenate", "stack",
}
LAX_ALLOW = {
    "broadcasted_iota", "iota", "dot_general", "fori_loop", "cond",
    "select", "select_n", "rsqrt", "exp", "max", "min", "add", "mul",
    "sub", "div", "rem", "convert_element_type", "bitcast_convert_type",
    "erf_inv", "integer_pow", "stop_gradient", "clamp", "reduce_max",
    "reduce_min", "reduce_sum", "while_loop", "associative_scan",
}
NN_ALLOW = {"one_hot", "relu", "softmax", "logsumexp", "sigmoid", "gelu"}
PL_ALLOW = {"when", "program_id", "num_programs", "load", "store", "ds",
            "dslice", "dot", "multiple_of", "max_contiguous", "debug_print"}
METHOD_ALLOW = {
    "astype", "reshape", "sum", "min", "max", "argmin", "argmax", "any",
    "all", "set", "add", "get", "swap", "mul", "start", "wait",
    "squeeze", "transpose", "ravel",
}
BUILTIN_ALLOW = {"range", "len", "min", "max", "abs", "enumerate", "zip",
                 "float", "int", "bool", "isinstance", "getattr",
                 "tuple", "list", "dict", "sorted"}


def check(mod: ModuleInfo) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    kernel_roots = [f for f in mod.functions
                    if f.traced and f.traced_reason == "pallas_call"]
    cluster = _reachable(mod, kernel_roots)
    for info in cluster:
        out.extend(_check_allowlist(mod, info, cluster))
    out.extend(_check_dma_pairing(mod))
    for info in mod.functions:
        out.extend(_check_tiling_contract(mod, info))
    return out


def _reachable(mod: ModuleInfo, roots: list[FuncInfo]) -> list[FuncInfo]:
    """Kernel bodies plus same-module functions they (transitively) call."""
    seen: dict[int, FuncInfo] = {}
    stack = list(roots)
    while stack:
        info = stack.pop()
        if id(info) in seen:
            continue
        seen[id(info)] = info
        for node in mod.own_body_walk(info):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Name):
                helper = mod.lookup(node.func.id, info)
                if helper is not None:
                    stack.append(helper)
    return list(seen.values())


def _check_allowlist(mod: ModuleInfo, info: FuncInfo,
                     cluster: list[FuncInfo]) -> list[Diagnostic]:
    cluster_ids = {id(f) for f in cluster}
    out = []
    for node in mod.own_body_walk(info):
        if not isinstance(node, ast.Call):
            continue
        verdict = _call_allowed(mod, info, node, cluster_ids)
        if verdict is not None:
            out.append(Diagnostic(
                rule="KER001", path=mod.path, line=node.lineno,
                col=node.col_offset, message=verdict,
                symbol=info.qualname))
    return out


def _call_allowed(mod, info, node: ast.Call,
                  cluster_ids: set[int]) -> str | None:
    """None when allowed, else the diagnostic message."""
    name = dotted_name(node.func)
    if name is None:
        # method chain on a computed value (e.g. ``x.astype(f32).sum()``
        # or ``ref.at[...].set(v)``): judge by the method name alone
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            return (None if attr in METHOD_ALLOW else
                    f"method .{attr}() is not on the kernel allowlist")
        return None
    parts = name.split(".")
    root, tail = parts[0], parts[-1]
    if root in ("np", "numpy"):
        return (f"{name}() inside a Pallas kernel body — numpy does not "
                "lower to Mosaic; use jnp")
    if root == "jnp":
        return (None if tail in JNP_ALLOW else
                f"jnp.{tail} is not on the Mosaic-lowerable allowlist")
    if root == "jax" or root == "lax":
        ns = parts[1] if root == "jax" and len(parts) > 2 else root
        if ns == "lax":
            return (None if tail in LAX_ALLOW else
                    f"lax.{tail} is not on the Mosaic-lowerable allowlist")
        if ns == "nn":
            return (None if tail in NN_ALLOW else
                    f"jax.nn.{tail} is not on the Mosaic-lowerable "
                    "allowlist")
        return f"{name}() is not on the kernel allowlist"
    if root == "pl":
        return (None if tail in PL_ALLOW else
                f"pl.{tail} is not allowed inside a kernel body")
    if root == "pltpu":
        return None          # DMA/semaphore plumbing is kernel-internal
    if len(parts) == 1:
        if tail in BUILTIN_ALLOW:
            return None
        helper = mod.lookup(tail, info)
        if helper is not None and id(helper) in cluster_ids:
            return None
        # helpers imported from sibling kernel modules are linted where
        # they are defined (they sit in that module's kernel cluster)
        src = mod.imports.get(tail)
        if src is not None and (src.startswith(".")
                                or src.startswith("repro")):
            return None
        return (f"{tail}() is neither a Mosaic-lowerable op, a static "
                "builtin, nor a local kernel helper")
    if parts[-2:-1] and node.func and isinstance(node.func, ast.Attribute):
        # dotted method on a named value (``sem.wait()``, ``x.astype()``)
        return (None if tail in METHOD_ALLOW else
                f"method .{tail}() is not on the kernel allowlist")
    return f"{name}() is not on the kernel allowlist"


def _has_method_call(root: ast.AST, attr: str) -> bool:
    return any(isinstance(n, ast.Call)
               and isinstance(n.func, ast.Attribute)
               and n.func.attr == attr
               for n in ast.walk(root))


def _check_dma_pairing(mod: ModuleInfo) -> list[Diagnostic]:
    """Each ``make_async_copy`` site must have SOME enclosing function
    whose subtree calls both ``.start()`` and ``.wait()`` — the copy is
    often built in a tiny ``dma(slot, tile)`` factory while the start and
    wait live in sibling ``pl.when`` branches of the real kernel body."""
    out = []
    for call in mod.walk_calls(mod.tree):
        if call_tail(call) != "make_async_copy":
            continue
        scope = mod.enclosing(call)
        resolved, missing = False, ["start", "wait"]
        probe = scope
        while probe is not None:
            has_start = _has_method_call(probe.node, "start")
            has_wait = _has_method_call(probe.node, "wait")
            if has_start and has_wait:
                resolved = True
                break
            missing = [s for s, ok in (("start", has_start),
                                       ("wait", has_wait)) if not ok]
            probe = probe.parent
        if not resolved:
            out.append(Diagnostic(
                rule="KER002", path=mod.path, line=call.lineno,
                col=call.col_offset,
                message="make_async_copy without a matching semaphore "
                        f"{'/'.join(missing)}() in any enclosing function",
                symbol=scope.qualname if scope else "<module>"))
    return out


def _check_tiling_contract(mod: ModuleInfo,
                           info: FuncInfo) -> list[Diagnostic]:
    if isinstance(info.node, ast.Lambda):
        return []
    calls = [n for n in mod.own_body_walk(info)
             if isinstance(n, ast.Call) and call_tail(n) == "pallas_call"]
    if not calls:
        return []
    has_check = any(
        isinstance(n, ast.Call) and "check_tiling" in (call_tail(n) or "")
        for n in mod.own_body_walk(info))
    raises_value_error = any(
        isinstance(n, ast.Raise) and n.exc is not None
        and "ValueError" in ast.unparse(n.exc)
        for n in mod.own_body_walk(info))
    if has_check or raises_value_error:
        return []
    return [Diagnostic(
        rule="KER003", path=mod.path, line=calls[0].lineno,
        col=calls[0].col_offset,
        message="pallas_call wrapper validates no tile-multiple shapes: "
                "call _check_tiling (or raise a naming ValueError) before "
                "launching the kernel — bare asserts vanish under -O",
        symbol=info.qualname)]
