"""Serving scenario: batched request serving of a small LM.

Trains nothing — initializes a smoke-scale gemma3-style model, admits a
wave of variable-length requests through the batched ServeEngine (static
slots, per-row EOS masking), and reports tokens/sec and per-request
transcripts. The same ServeEngine drives the decode_32k / long_500k
dry-run cells at production scale.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6_3b]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    mesh = make_host_mesh()
    rules = resolve_rules(mesh, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(1)
    shape = lambda n: ((n,) if cfg.input_mode == "tokens"
                       else (n, cfg.n_codebooks))
    reqs = [Request(uid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size,
                        shape(int(rng.integers(4, 12)))).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.n_requests)]

    engine = ServeEngine(cfg, rules, params, batch=args.batch, max_seq=64)
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.uid}: prompt_len={len(r.prompt):2d} -> {r.out}")
    print(f"\n{len(reqs)} requests / {total} new tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s interpret-mode host loop)")


if __name__ == "__main__":
    main()
