"""End-to-end training driver: MoE LM with the paper's balanced-k-means
router, checkpointed + resumable.

Presets:
  cpu-small  (default) — ~8M-param MoE, 300 steps: finishes on this CPU
             container and shows (i) loss well below uniform entropy,
             (ii) the router influence state adapting (paper Eq. 1),
             (iii) dropped-token fraction staying low without aux losses.
  100m       — ~100M-param config (d=512, 12L, 16 experts), the "train a
             ~100M model for a few hundred steps" driver for real
             hardware; identical code path.

    PYTHONPATH=src python examples/train_moe_kmeans.py [--preset 100m]
        [--steps 300] [--ckpt-dir /tmp/moe_ckpt] [--quick]

``--quick`` runs the cpu-small preset for a handful of steps as a smoke
test (exercises the full train loop but skips the learning assertion,
which needs a few hundred steps to hold).
"""
import argparse

import jax
import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models.config import LayerSpec, ModelConfig, MoEConfig
from repro.train import Trainer, TrainerConfig, TrainHParams

PRESETS = {
    "cpu-small": dict(
        cfg=ModelConfig(
            name="moe-8m",
            n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
            d_ff=256, vocab_size=2048,
            moe=MoEConfig(n_experts=8, top_k=2, d_ff=256,
                          capacity_factor=1.25, router="balanced_kmeans"),
            pattern=(LayerSpec("full", "dense"), LayerSpec("full", "moe")),
        ),
        batch=8, seq=128, steps=300),
    "100m": dict(
        cfg=ModelConfig(
            name="moe-100m",
            n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
            d_ff=1408, vocab_size=32_000,
            moe=MoEConfig(n_experts=16, top_k=2, d_ff=1408,
                          capacity_factor=1.25, router="balanced_kmeans"),
            pattern=(LayerSpec("full", "dense"), LayerSpec("full", "moe")),
        ),
        batch=32, seq=1024, steps=300),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="cpu-small")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help="smoke run: cpu-small preset, 5 steps, no "
                         "learning assertion")
    args = ap.parse_args()

    if args.quick:
        args.preset = "cpu-small"
    p = PRESETS[args.preset]
    cfg = p["cfg"]
    steps = args.steps or (5 if args.quick else p["steps"])
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")

    mesh = make_host_mesh()
    rules = resolve_rules(mesh, cfg, "train")
    hp = TrainHParams(microbatches=args.microbatches, lr_peak=3e-3,
                      warmup_steps=max(steps // 20, 5), total_steps=steps)
    tc = TrainerConfig(steps=steps, log_every=max(steps // 30, 1),
                       ckpt_every=max(steps // 3, 1) if args.ckpt_dir else 0,
                       ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, rules, hp, tc)
    data = SyntheticLM(cfg, p["batch"], p["seq"])
    state, history = trainer.fit(iter(data))

    uniform = float(np.log(cfg.vocab_size))
    print(f"\n{'step':>6s} {'loss':>8s} {'drop%':>7s} {'gnorm':>8s}")
    for h in history:
        print(f"{int(h['step']):6d} {h['loss']:8.4f} "
              f"{100*h['moe_dropped_frac']:7.2f} {h['grad_norm']:8.2f}")
    final = history[-1]["loss"]
    print(f"\nuniform-entropy baseline: {uniform:.3f}; final loss {final:.3f}")
    infl = np.asarray(jax.device_get(state["influence"]))
    print(f"router influence range after training: "
          f"[{infl.min():.3f}, {infl.max():.3f}] (adapting => != 1.0)")
    if args.quick:
        print("(--quick: skipping learning assertion — needs a few "
              "hundred steps)")
    else:
        assert final < uniform - 0.5, "model failed to learn"


if __name__ == "__main__":
    main()
