"""Full partitioning scenario: weighted 2.5D climate-style mesh (the
paper's motivating application), all tools, per-phase stats, optional
SPMD distributed run.

    PYTHONPATH=src python examples/partition_mesh.py [--n 30000] [--k 64]
    PYTHONPATH=src python examples/partition_mesh.py --distributed
        (forces 8 host devices; run in a fresh process)
"""
import argparse
import sys
import time

import numpy as np


def single_host(n: int, k: int):
    from repro.core import baselines, meshes, metrics
    from repro.core.balanced_kmeans import BKMConfig
    from repro.core.partitioner import geographer_partition

    mesh = meshes.REGISTRY["climate25d"](n, seed=0)
    print(f"mesh: {mesh.name} n={mesh.n} m={mesh.m} "
          f"(node weights: vertical column depth)")
    tools = {"geographer": lambda: geographer_partition(
        mesh.points, k, weights=mesh.weights,
        cfg=BKMConfig(k=k, epsilon=0.03))}
    for name, fn in baselines.BASELINES.items():
        tools[name] = lambda fn=fn: fn(mesh.points, k, mesh.weights)

    for name, fn in tools.items():
        t0 = time.perf_counter()
        part = fn()
        dt = time.perf_counter() - t0
        ev = metrics.evaluate_partition(mesh, part, k, with_diameter=True)
        print(f"{name:12s} t={dt:6.2f}s cut={ev['cut']:7d} "
              f"maxCV={ev['maxCommVol']:6d} sumCV={ev['totalCommVol']:7d} "
              f"diam={ev['diameter_harmonic_mean']:6.1f} "
              f"imb={ev['imbalance']:.4f}")


def distributed(n: int, k: int, shards: int = 8):
    """The paper's SPMD structure: points sharded, centers replicated,
    psum-only communication. Needs forced host devices -> fresh process."""
    import os
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={shards}"
    import jax
    import jax.numpy as jnp
    from repro.core import meshes
    from repro.core.balanced_kmeans import BKMConfig
    from repro.core.partitioner import make_distributed_partitioner

    mesh_hw = jax.make_mesh(
        (shards,), ("data",),
        axis_types=(jax.sharding.AxisType.Auto,))
    m = meshes.REGISTRY["delaunay2d"](n, seed=0)
    cfg = BKMConfig(k=k, epsilon=0.03)
    run = make_distributed_partitioner(mesh_hw, cfg, "data")
    pts = jnp.asarray(m.points, jnp.float32)
    w = jnp.ones(m.n, jnp.float32)
    t0 = time.perf_counter()
    A, rp, rv, centers, infl, imb, dropped = run(pts, w)
    A.block_until_ready()
    print(f"distributed ({shards} shards): t={time.perf_counter()-t0:.2f}s "
          f"imbalance={float(imb):.4f} redistribution_dropped={int(dropped)}")
    assert float(imb) <= 0.031


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--distributed", action="store_true")
    args = ap.parse_args()
    if args.distributed:
        distributed(min(args.n, 20_000), min(args.k, 16))
    else:
        single_host(args.n, args.k)
