"""Full partitioning scenario through the unified engine: weighted 2.5D
climate-style mesh (the paper's motivating application), every registered
method, hierarchical k = 8 x 8 recursion, an optional SPMD distributed
run, and a dynamic-repartitioning time loop (drifting workload, warm vs
cold restart).

    PYTHONPATH=src python examples/partition_mesh.py [--n 30000] [--k 64]
    PYTHONPATH=src python examples/partition_mesh.py --quick
    PYTHONPATH=src python examples/partition_mesh.py --repartition
    PYTHONPATH=src python examples/partition_mesh.py --distributed
        (forces 8 host devices; run in a fresh process)

The single-host path is three lines of API::

    prob = PartitionProblem.from_mesh(mesh, k=64, epsilon=0.03)
    res  = partition(prob, method="geographer")       # or rcb/rib/sfc/mj
    res  = partition(prob, hierarchy=(8, 8))          # k1 x k2 recursive

``hierarchy=(8, 8)`` cuts 8 coarse blocks with Geographer, then refines
all 8 blocks into 8 sub-blocks each in ONE batched vmap dispatch; block b
owns labels [8b, 8b+8) and the measured global imbalance still respects
``epsilon``.
"""
import argparse
import time

import numpy as np


def single_host(n: int, k: int):
    from repro.core import meshes
    from repro.partition import (PartitionProblem, available_methods,
                                 factor_k, partition)

    mesh = meshes.REGISTRY["climate25d"](n, seed=0)
    print(f"mesh: {mesh.name} n={mesh.n} m={mesh.m} "
          f"(node weights: vertical column depth)")
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)

    for name in available_methods():
        t0 = time.perf_counter()
        res = partition(prob, method=name)
        dt = time.perf_counter() - t0
        ev = res.evaluate(with_diameter=True)
        print(f"{name:12s} t={dt:6.2f}s cut={ev['cut']:7d} "
              f"maxCV={ev['maxCommVol']:6d} sumCV={ev['totalCommVol']:7d} "
              f"diam={ev['diameter_harmonic_mean']:6.1f} "
              f"imb={ev['imbalance']:.4f}")

    # hierarchical k = k1 x k2 (e.g. 8 x 8 = 64 blocks): coarse Geographer
    # + all k1 refinements in one batched vmap dispatch
    k1, k2 = factor_k(k)
    t0 = time.perf_counter()
    res = partition(prob, hierarchy=(k1, k2))
    dt = time.perf_counter() - t0
    ev = res.evaluate(with_diameter=True)
    lvl = res.stats["levels"]
    print(f"{f'hier {k1}x{k2}':12s} t={dt:6.2f}s cut={ev['cut']:7d} "
          f"maxCV={ev['maxCommVol']:6d} sumCV={ev['totalCommVol']:7d} "
          f"diam={ev['diameter_harmonic_mean']:6.1f} "
          f"imb={ev['imbalance']:.4f} "
          f"(coarse imb={lvl[0]['imbalance']:.4f}, "
          f"refine dispatches={lvl[1]['dispatches']})")
    assert ev["imbalance"] <= prob.epsilon + 1e-6
    assert len(np.unique(res.labels)) == k1 * k2


def distributed(n: int, k: int, shards: int = 8):
    """The paper's SPMD structure through the engine front door
    (``devices=P``): points sharded round-robin, centers replicated,
    psum-only communication. Needs forced host devices -> fresh process."""
    from repro.envflags import force_virtual_devices
    force_virtual_devices(shards, override=True)
    from repro.core import meshes
    from repro.partition import PartitionProblem, partition

    m = meshes.REGISTRY["delaunay2d"](n, seed=0)
    prob = PartitionProblem.from_mesh(m, k, epsilon=0.03)
    ref = partition(prob, method="geographer")     # single-device reference
    for d in (1, shards):
        t0 = time.perf_counter()
        res = partition(prob, method="geographer", devices=d)
        dt = time.perf_counter() - t0
        agree = float(np.mean(res.labels == ref.labels))
        print(f"devices={d}: t={dt:.2f}s imbalance={res.imbalance():.4f} "
              f"label agreement vs single-device={agree:.4f}")
        assert res.imbalance() <= prob.epsilon + 1e-6


def dynamic(n: int, k: int, steps: int = 6):
    """Time loop: a drifting-hotspot load over a fixed mesh, repartitioned
    every step — warm-started Geographer vs a cold restart, reporting the
    migration each would cost (the dynamic repartitioning story,
    DESIGN.md §8)."""
    from repro.core import meshes
    from repro.core.timeseries import simulate_loadbalance
    from repro.partition import PartitionProblem

    mesh = meshes.REGISTRY["delaunay2d"](n, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
    workload = meshes.WORKLOADS["drifting_hotspot"]()
    print(f"mesh: {mesh.name} n={mesh.n} k={k} "
          f"workload={type(workload).__name__} T={steps}")
    for mode in ("warm", "cold"):
        sim = simulate_loadbalance(prob, workload, steps, mode=mode)
        s = sim["summary"]
        print(f"{mode:5s}: mean iters={s['mean_iters']:.2f} "
              f"mean migration={s['mean_migration_fraction']:.4f} "
              f"max imbalance={s['max_imbalance']:.4f} "
              f"(all balanced: {s['all_balanced']})")
        assert s["all_balanced"]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=30_000)
    ap.add_argument("--k", type=int, default=64)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--repartition", action="store_true",
                    help="dynamic repartitioning time loop")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run of every section")
    args = ap.parse_args()
    if args.quick:
        args.n, args.k = min(args.n, 4_000), min(args.k, 16)
    if args.distributed:
        distributed(min(args.n, 20_000), min(args.k, 16))
    elif args.repartition:
        dynamic(args.n, min(args.k, 16), steps=4 if args.quick else 6)
    else:
        single_host(args.n, args.k)
        dynamic(min(args.n, 8_000), min(args.k, 16),
                steps=3 if args.quick else 6)
