"""Quickstart: the unified engine in four calls — partition a 2D mesh
with Geographer (balanced k-means), compare against recursive coordinate
bisection, then track a drifting load with a warm-started repartition.

    PYTHONPATH=src python examples/quickstart.py [--quick]
"""
import argparse

import numpy as np

from repro.core import meshes
from repro.partition import PartitionProblem, partition, repartition


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (smaller mesh)")
    args = ap.parse_args()
    n, k = (2_000, 8) if args.quick else (8_000, 16)

    mesh = meshes.REGISTRY["refined2d"](n, seed=0)
    print(f"mesh: {mesh.name}  n={mesh.n}  m={mesh.m}")
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)

    ours = partition(prob, method="geographer", evaluate=True,
                     with_diameter=True)
    iters = int(np.asarray(ours.stats["levels"][0]["iters"]))
    print(f"\nGeographer  (iters={iters}, "
          f"imbalance={ours.stats['final_imbalance']:.4f}):")
    for kk, v in ours.quality.items():
        print(f"  {kk:24s} {v}")

    rcb = partition(prob, method="rcb", evaluate=True, with_diameter=True)
    print("\nRCB:")
    for kk, v in rcb.quality.items():
        print(f"  {kk:24s} {v}")

    dv = ours.quality["totalCommVol"] / max(rcb.quality["totalCommVol"], 1)
    print(f"\ntotal comm volume vs RCB: {dv:.3f}x "
          f"({'better' if dv < 1 else 'worse'})")
    assert ours.quality["imbalance"] <= 0.03 + 1e-6, \
        "balance constraint violated!"

    # the load drifts -> warm-restart from the previous result instead of
    # re-solving from scratch (see docs/api.md "repartition")
    workload = meshes.WORKLOADS["drifting_hotspot"]()
    res = partition(prob.replace(weights=np.asarray(
        workload.weights_at(mesh.points, 0))), method="geographer")
    print("\ndrifting hotspot, warm restarts:")
    steps = 3 if args.quick else 5
    for t in range(1, steps + 1):
        w_t = np.asarray(workload.weights_at(mesh.points, t))
        res = repartition(prob.replace(weights=w_t), res)
        mig = res.stats["migration"]
        print(f"  t={t}: iters={res.stats['iters']} "
              f"migrated={mig['fraction']:.3f} "
              f"imbalance={res.imbalance():.4f}")
        assert res.imbalance() <= 0.03 + 1e-6


if __name__ == "__main__":
    main()
