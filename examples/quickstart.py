"""Quickstart: partition a 2D mesh with Geographer (balanced k-means) and
compare against recursive coordinate bisection.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import baselines, meshes, metrics
from repro.core.balanced_kmeans import BKMConfig
from repro.core.partitioner import geographer_partition


def main():
    k = 16
    mesh = meshes.REGISTRY["refined2d"](8_000, seed=0)
    print(f"mesh: {mesh.name}  n={mesh.n}  m={mesh.m}")

    part, stats = geographer_partition(
        mesh.points, k, cfg=BKMConfig(k=k, epsilon=0.03), return_stats=True)
    ours = metrics.evaluate_partition(mesh, part, k, with_diameter=True)
    print(f"\nGeographer  (iters={int(stats['iters'])}, "
          f"imbalance={float(stats['final_imbalance']):.4f}):")
    for kk, v in ours.items():
        print(f"  {kk:24s} {v}")

    rcb = baselines.rcb(mesh.points, k)
    theirs = metrics.evaluate_partition(mesh, rcb, k, with_diameter=True)
    print("\nRCB:")
    for kk, v in theirs.items():
        print(f"  {kk:24s} {v}")

    dv = ours["totalCommVol"] / max(theirs["totalCommVol"], 1)
    print(f"\ntotal comm volume vs RCB: {dv:.3f}x "
          f"({'better' if dv < 1 else 'worse'})")
    assert ours["imbalance"] <= 0.03 + 1e-6, "balance constraint violated!"


if __name__ == "__main__":
    main()
