"""AdamW with configurable moment dtypes and decoupled weight decay.

Hand-rolled (no optax in this container) but production-shaped:

* moment dtype is configurable per model (bf16 moments for the 400B-class
  archs — halves optimizer HBM, the standard large-scale trick);
* global-norm clipping;
* bias correction in f32 regardless of storage dtype;
* update math runs in f32 and casts back to the param dtype, so a bf16
  parameter store still gets stochastic-free but numerically sane updates.

The optimizer state is a plain pytree (mu, nu mirroring params + scalar
step), so checkpointing / resharding treat it like any other model state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt_state, cfg: AdamWConfig, lr):
    """Returns (new_params, new_opt_state, stats)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_f = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu_f / c1
        vhat = nu_f / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return (new_p,
            {"mu": new_mu, "nu": new_nu, "step": step},
            {"grad_norm": gnorm, "lr": lr})
