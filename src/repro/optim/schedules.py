"""Learning-rate schedules (jittable step -> lr functions)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str = "cosine", peak: float = 3e-4,
                  warmup_steps: int = 100, total_steps: int = 10_000,
                  floor: float = 0.0):
    warmup_steps = max(warmup_steps, 1)

    def cosine(step):
        s = step.astype(jnp.float32)
        warm = peak * s / warmup_steps
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        decay = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup_steps, warm, decay)

    def linear(step):
        s = step.astype(jnp.float32)
        warm = peak * s / warmup_steps
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, peak * (1 - frac) + floor * frac)

    def constant(step):
        s = step.astype(jnp.float32)
        return jnp.where(s < warmup_steps, peak * s / warmup_steps, peak)

    return {"cosine": cosine, "linear": linear, "constant": constant}[kind]
