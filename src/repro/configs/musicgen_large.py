"""musicgen-large [audio]: 48L d2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
Decoder-only over EnCodec tokens [arXiv:2306.05284]. The EnCodec frontend is
a STUB: inputs are the 4 discrete codebook streams (the transformer backbone
consumes summed codebook embeddings; one LM head per codebook). 32 heads ->
head-TP."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    mlp_kind="gelu", rope_theta=1e4,
    input_mode="codebooks", n_codebooks=4,
    pattern=(LayerSpec("full", "dense"),),
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, head_dim=8,
    d_ff=128, vocab_size=64,
    mlp_kind="gelu",
    input_mode="codebooks", n_codebooks=4,
    pattern=(LayerSpec("full", "dense"),),
)

LONG_CONTEXT_OK = False
