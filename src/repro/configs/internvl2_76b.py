"""internvl2-76b [vlm]: 80L d8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
InternViT-6B + LLaMA-class 70B language backbone [arXiv:2404.16821].

Per the assignment, only the transformer BACKBONE is specified; the
InternViT/pixel-shuffle frontend is a STUB — ``input_specs()`` feeds
precomputed patch+text embeddings ([B, S, d_model] bf16), so
``input_mode="embeddings"`` (no input embedding table; LM head to the
128256 text vocab remains). Param check: 80 x (4*8192^2*(72/64) attn +
3*8192*28672 mlp) ~= 70B + 1.05B lm_head (ViT 6B stubbed).
64 heads / 16 -> head-TP."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="internvl2-76b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    mlp_kind="swiglu", rope_theta=1e6,
    input_mode="embeddings",
    pattern=(LayerSpec("full", "dense"),),
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=128,
    mlp_kind="swiglu",
    input_mode="embeddings",
    pattern=(LayerSpec("full", "dense"),),
)

LONG_CONTEXT_OK = False  # pure full attention -> long_500k skipped

# d_model=8192 embeddings-input activations are the largest in the pool;
# 2 grad-accum microbatches halve the live footprint (same step FLOPs)
TRAIN_HPARAMS = {"microbatches": 2}
