"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) d_ff=512 (per
expert) vocab=49155, MoE 40e top-8 on every layer
[hf:ibm-granite/granite-3.0-*-base family].

Param check: 32 x 40 x 3*1536*512 = 3.0B total; top-8 active ~= 0.8B.
24 heads % 16 != 0 -> seq-SP. E=40 % 16 != 0 -> expert weights sharded on
the contracting d_model dim over `model` (psum after expert matmuls; see
dist/rules.py `e_embed`). vocab 49155 padded to 49280 (128 lanes).
Balanced-k-means router: with 40 experts and top-8 this is the densest
routing problem in the pool — the paper's influence balancing (Eq. 1) acts
on realized loads each step."""
from repro.models.config import ModelConfig, LayerSpec, MoEConfig

_MOE = MoEConfig(n_experts=40, top_k=8, d_ff=512,
                 capacity_factor=1.25, router="balanced_kmeans")

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    mlp_kind="swiglu", rope_theta=1e4,
    moe=_MOE,
    pattern=(LayerSpec("full", "moe"),),
)

SMOKE = ModelConfig(
    name="granite-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=32, vocab_size=131,          # odd vocab preserved (padding path)
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=10, top_k=4, d_ff=32, capacity_factor=1.5,
                  router="balanced_kmeans"),
    pattern=(LayerSpec("full", "moe"),),
)

LONG_CONTEXT_OK = False
