"""jamba-1.5-large-398b [hybrid]: 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2; Mamba:attention 7:1 interleave, MoE every other
layer [arXiv:2403.19887].

Pattern period 8: positions 0-7 are mamba except position 4 (attention);
MoE on odd positions. 64 heads divide 16 -> head-TP; mamba d_inner=16384
is channel-TP over model. Optimizer moments in bf16 (400B class)."""
from repro.models.config import ModelConfig, LayerSpec, MoEConfig

_PATTERN = tuple(
    LayerSpec("full" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

_MOE = MoEConfig(n_experts=16, top_k=2, d_ff=24576,
                 capacity_factor=1.25, router="balanced_kmeans")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536,
    mlp_kind="swiglu", rope_theta=1e4,
    moe=_MOE,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    param_dtype="bfloat16",    # 400B class: bf16 weights, f32 update math
    moment_dtype="bfloat16",
    pattern=_PATTERN,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128,
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=128,
                  capacity_factor=1.5, router="balanced_kmeans"),
    mamba_d_state=8, mamba_d_conv=4, mamba_expand=2,
    pattern=_PATTERN,
)

LONG_CONTEXT_OK = True  # 7/8 of layers are SSM; attention is 1/8

# heaviest train cell in the pool (72L hybrid + MoE): 2 grad-accum
# microbatches halve the live activation/dispatch footprint
TRAIN_HPARAMS = {"microbatches": 2, "grad_acc_dtype": "bfloat16"}
