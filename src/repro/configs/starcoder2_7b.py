"""starcoder2-7b [dense]: 32L d4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
GQA + RoPE, non-gated GELU MLP (d_ff = 4d) [arXiv:2402.19173].

36 heads do not divide the model=16 mesh axis -> seq-SP attention
(DESIGN.md §5)."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    mlp_kind="gelu", rope_theta=1e5,
    pattern=(LayerSpec("full", "dense"),),
)

SMOKE = ModelConfig(
    name="starcoder2-smoke",
    n_layers=2, d_model=72, n_heads=9, n_kv_heads=3,   # odd heads preserved
    d_ff=288, vocab_size=128, head_dim=8,
    mlp_kind="gelu",
    pattern=(LayerSpec("full", "dense"),),
)

LONG_CONTEXT_OK = False  # pure full attention -> long_500k skipped
