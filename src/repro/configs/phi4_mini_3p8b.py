"""phi4-mini-3.8b [dense]: 32L d3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
RoPE + SwiGLU + GQA [arXiv:2412.08905]. 24 heads % 16 != 0 -> seq-SP."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200064,
    mlp_kind="swiglu", rope_theta=1e4,
    pattern=(LayerSpec("full", "dense"),),
)

SMOKE = ModelConfig(
    name="phi4-smoke",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=128, vocab_size=160, head_dim=8,
    mlp_kind="swiglu",
    pattern=(LayerSpec("full", "dense"),),
)

LONG_CONTEXT_OK = False
