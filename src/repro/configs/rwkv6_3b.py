"""rwkv6-3b [ssm]: 32L d2560 (attention-free) d_ff=8960 vocab=65536.
"Finch" — data-dependent per-channel decay [arXiv:2404.05892].

Every layer is an RWKV6 time-mix (WKV linear recurrence, head_dim=64 ->
40 heads) followed by an RWKV channel-mix (squared-ReLU, d_ff=8960).
Constant-size recurrent state (H x 64 x 64 per layer) makes decode O(1)
in context length -> the long_500k cell runs natively.

The paper's balanced-k-means router is inapplicable (no MoE); the arch
still uses SFC data-locality batching (DESIGN.md §Arch-applicability)."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="rwkv6-3b",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    rwkv_head_dim=64, rwkv_lora_rank=64,
    pattern=(LayerSpec("rwkv", "dense"),),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=224, vocab_size=128,
    rwkv_head_dim=16, rwkv_lora_rank=8,
    pattern=(LayerSpec("rwkv", "dense"),),
)

LONG_CONTEXT_OK = True  # O(1) state; decode cost independent of context
