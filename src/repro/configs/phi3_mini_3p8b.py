"""phi3-mini-3.8b [dense]: 32L d3072 32H (MHA kv=32) d_ff=8192 vocab=32064.
RoPE + SwiGLU [arXiv:2404.14219]. 32 heads divide 16 -> head-TP."""
from repro.models.config import ModelConfig, LayerSpec

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    mlp_kind="swiglu", rope_theta=1e4,
    pattern=(LayerSpec("full", "dense"),),
)

SMOKE = ModelConfig(
    name="phi3-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=8,
    d_ff=160, vocab_size=96, head_dim=8,
    mlp_kind="swiglu",
    pattern=(LayerSpec("full", "dense"),),
)

LONG_CONTEXT_OK = False
