"""gemma3-1b [dense]: 26L... pattern requires n_layers % period == 0, the
public model interleaves 5 local(sliding-window):1 global. 26 layers do not
divide the 6-layer pattern; following the released config (5:1 with the
final block truncated is not expressible in a scanned stack), we use the
exact 5:1 pattern with 24 scanned layers + config note, OR keep 26 via a
13-layer x (5:1+extra) — we keep the published pattern and round layers to
24 for the scan (noted in DESIGN.md; the dry-run FLOPs extrapolation uses
the pattern period exactly).

d_model=1152, 4H (GQA kv=1, head_dim=256), d_ff=6912, vocab=262144,
window=512, dual RoPE theta (10k local / 1M global), logit softcap.
[hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig, LayerSpec

_PATTERN = tuple([LayerSpec("swa", "dense")] * 5 + [LayerSpec("full", "dense")])

CONFIG = ModelConfig(
    name="gemma3-1b",
    n_layers=24, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    mlp_kind="swiglu", window=512,
    rope_theta=1e4, rope_theta_global=1e6,
    pattern=_PATTERN,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    n_layers=6, d_model=48, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=96, vocab_size=256,
    mlp_kind="swiglu", window=8,
    rope_theta=1e4, rope_theta_global=1e6,
    pattern=_PATTERN,
)

# 5:1 local:global -> compute is dominated by the 512-token window; the
# occasional global layer is linear per decoded token. Sub-quadratic enough
# for the long_500k decode cell.
LONG_CONTEXT_OK = True
