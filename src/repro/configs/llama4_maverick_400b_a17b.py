"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1 + 1 shared expert, interleaved MoE every other
layer, early fusion (text+vision share the token stream; vision frontend is
a STUB) [hf:meta-llama/Llama-4-Scout-17B-16E family].

Param check: 24 MoE layers x 128 experts x 3*5120*8192 = 386B routed params
(+ dense/attn) == the 400B class; top-1 + shared expert ~= 17B active.
40 heads % 16 != 0 -> seq-SP attention; 128 experts / 16 -> EP over model.
Optimizer moments bf16 (400B class). Balanced-k-means router (paper Eq. 1
influence balancing) is the *default* router for this arch."""
from repro.models.config import ModelConfig, LayerSpec, MoEConfig

_PATTERN = (LayerSpec("full", "dense"), LayerSpec("full", "moe"))

_MOE = MoEConfig(n_experts=128, top_k=1, d_ff=8192,
                 capacity_factor=1.25, router="balanced_kmeans",
                 n_shared_experts=1)

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    mlp_kind="swiglu", rope_theta=5e5,
    moe=_MOE,
    param_dtype="bfloat16",    # 400B class: bf16 weights, f32 update math
    moment_dtype="bfloat16",
    pattern=_PATTERN,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    n_layers=4, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=192,
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=8, top_k=1, d_ff=128, capacity_factor=1.5,
                  router="balanced_kmeans", n_shared_experts=1),
    pattern=_PATTERN,
)

LONG_CONTEXT_OK = False  # full attention -> long_500k skipped

# 400B-class: microbatched grad accumulation in bf16 (grads of bf16 params
# are natively bf16; f32 accumulators double their HBM)
TRAIN_HPARAMS = {"microbatches": 2, "grad_acc_dtype": "bfloat16"}
