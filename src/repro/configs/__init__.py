"""Architecture config registry: one module per assigned architecture.

Each module exposes ``CONFIG`` (full ModelConfig, exercised only via the
dry-run), ``SMOKE`` (reduced same-family config for CPU tests) and
optionally ``SHARDING_OVERRIDES`` ({mode: {logical: mesh_axes}}) and
``LONG_CONTEXT_OK`` (bool — whether the arch is sub-quadratic enough for
the long_500k cell).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "starcoder2_7b",
    "phi4_mini_3p8b",
    "phi3_mini_3p8b",
    "gemma3_1b",
    "musicgen_large",
    "jamba_1p5_large_398b",
    "llama4_maverick_400b_a17b",
    "granite_moe_3b_a800m",
    "rwkv6_3b",
    "internvl2_76b",
]

# canonical CLI ids (--arch <id>)
ALIASES = {
    "starcoder2-7b": "starcoder2_7b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "gemma3-1b": "gemma3_1b",
    "musicgen-large": "musicgen_large",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "rwkv6-3b": "rwkv6_3b",
    "internvl2-76b": "internvl2_76b",
}


def get(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str, smoke: bool = False):
    mod = get(name)
    return mod.SMOKE if smoke else mod.CONFIG


def long_context_ok(name: str) -> bool:
    return getattr(get(name), "LONG_CONTEXT_OK", False)


def sharding_overrides(name: str, mode: str) -> dict:
    ov = getattr(get(name), "SHARDING_OVERRIDES", {})
    return dict(ov.get("all", {}), **ov.get(mode, {}))
