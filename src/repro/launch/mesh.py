"""Production mesh factory.

A *function*, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices via
XLA_FLAGS before any jax import; tests and benches see 1 device).

Single pod: 16 x 16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model); the ``pod``
axis carries data parallelism + the second FSDP level across pods (DCN in
real deployments), ``model`` stays intra-pod (ICI).
"""
from __future__ import annotations

import jax


def make_compat_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types across jax versions:
    jax.sharding.AxisType landed after 0.4.x (where Auto is the only
    behavior), so the kwarg is passed only when it exists. Use this for
    every mesh in the repo so the compat rule lives in one place."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(at.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over available (CPU) devices for tests/examples."""
    return make_compat_mesh((data, model), ("data", "model"))
