"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Initializes (or restores) parameters for the smoke config, admits a batch
of synthetic requests and decodes them through the batched ServeEngine.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=True)
    if cfg.input_mode == "embeddings":
        raise SystemExit("VLM stub serves via precomputed embeddings; "
                         "use a token arch for this driver")
    mesh = make_host_mesh()
    rules = resolve_rules(mesh, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, rules, params, batch=args.batch,
                         max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    shape = ((args.prompt_len,) if cfg.input_mode == "tokens"
             else (args.prompt_len, cfg.n_codebooks))
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, shape)
                    .astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run(reqs)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in reqs)
    for r in reqs[:3]:
        print(f"req {r.uid}: {r.out[:10]} ...")
    print(f"{len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s host-loop)")


if __name__ == "__main__":
    main()
