"""Roofline terms from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh) cell, in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = wire_bytes_per_device / ICI_bw_per_chip

``cost_analysis()`` of the partitioned module is per-device, so dividing
by per-chip peaks is exactly the spec's HLO/(chips x peak) with both sides
divided by `chips`.

Collective wire bytes are NOT in cost_analysis; we parse the
post-optimization HLO and apply ring-algorithm wire accounting per op:

    all-gather         result_bytes * (G-1)/G
    all-reduce         2 * result_bytes * (G-1)/G     (reduce-scatter + AG)
    reduce-scatter     operand_bytes * (G-1)/G
    all-to-all         operand_bytes * (G-1)/G
    collective-permute operand_bytes

where G is the replica-group size parsed from the op. This is the
per-device traffic crossing its ICI links under ring schedules.

MODEL_FLOPS (the useful-work yardstick):

    train:    6 * N_active * tokens  + 3 * attn_fwd
    prefill:  2 * N_active * tokens  +     attn_fwd
    decode:   2 * N_active * batch   +     attn_decode
    attn_fwd = 4 * H*hd * L_attn * tokens * avg_ctx   (causal: avg_ctx=S/2,
               swa: min(window, S/2)); ssm/rwkv state terms added analog.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (bottleneck link accounting)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(?P<rtype>\([^)]*\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        size = _DTYPE_BYTES.get(dt)
        if size is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * size
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)   # [n_groups, group_size]<=[N]
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-device wire bytes by collective kind (+ op counts)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        rbytes = _shape_bytes(m.group("rtype"))
        g = _group_size(line, n_devices)
        ring = (g - 1) / g if g > 1 else 0.0
        if op == "all-gather":
            wire = rbytes * ring
        elif op == "all-reduce":
            wire = 2.0 * rbytes * ring
        elif op == "reduce-scatter":
            wire = rbytes * (g - 1)            # operand = result * G
        elif op == "all-to-all":
            wire = rbytes * ring
        else:                                   # collective-permute
            wire = rbytes
        out[op] += wire
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# model FLOPs (useful-work yardstick)
# ---------------------------------------------------------------------------

def _attn_layer_counts(cfg):
    full = sum(1 for s in cfg.pattern if s.attn == "full") * cfg.n_repeats
    swa = sum(1 for s in cfg.pattern if s.attn == "swa") * cfg.n_repeats
    mamba = sum(1 for s in cfg.pattern if s.attn == "mamba") * cfg.n_repeats
    rwkv = sum(1 for s in cfg.pattern if s.attn == "rwkv") * cfg.n_repeats
    return full, swa, mamba, rwkv


def model_flops(cfg, mode: str, batch: int, seq: int) -> float:
    """Analytic useful FLOPs for one step of this cell."""
    n_act = cfg.active_param_count()
    # the input embedding table is a gather, not a matmul — exclude it
    # from the 2N/6N term (the LM head stays: it is a real matmul)
    if cfg.input_mode == "tokens":
        n_act -= cfg.vocab_padded * cfg.d_model
    elif cfg.input_mode == "codebooks":
        n_act -= cfg.n_codebooks * cfg.vocab_padded * cfg.d_model
    full, swa, mamba, rwkv = _attn_layer_counts(cfg)
    hhd = cfg.n_heads * cfg.hd
    di, ds = cfg.mamba_expand * cfg.d_model, cfg.mamba_d_state

    if mode in ("decode", "long_decode"):
        toks = batch
        ctx_full, ctx_swa = seq, min(cfg.window, seq)
    else:
        toks = batch * seq
        ctx_full, ctx_swa = seq / 2.0, min(cfg.window, seq / 2.0)

    attn_fwd = 4.0 * hhd * toks * (full * ctx_full + swa * ctx_swa)
    ssm_fwd = toks * (mamba * 12.0 * di * ds + rwkv * 6.0 *
                      cfg.d_model * cfg.rwkv_head_dim)
    if mode == "train":
        return 6.0 * n_act * toks + 3.0 * (attn_fwd + ssm_fwd)
    return 2.0 * n_act * toks + attn_fwd + ssm_fwd


def three_terms(flops_per_dev: float, bytes_per_dev: float,
                wire_bytes_per_dev: float) -> dict:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = wire_bytes_per_dev / ICI_BW
    bound = max(compute, memory, collective)
    name = ("compute" if bound == compute else
            "memory" if bound == memory else "collective")
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "bound_s": bound,
            "bottleneck": name}


def summarize(cfg, mode, batch, seq, n_devices,
              flops_per_dev, bytes_per_dev, wire_per_dev) -> dict:
    terms = three_terms(flops_per_dev, bytes_per_dev, wire_per_dev)
    mf = model_flops(cfg, mode, batch, seq)
    mf_per_dev = mf / n_devices
    useful_s = mf_per_dev / PEAK_FLOPS
    terms.update({
        "model_flops": mf,
        "hlo_flops_per_dev": flops_per_dev,
        "hlo_bytes_per_dev": bytes_per_dev,
        "wire_bytes_per_dev": wire_per_dev,
        "useful_ratio": mf_per_dev / max(flops_per_dev, 1.0),
        "roofline_frac": useful_s / max(terms["bound_s"], 1e-30),
    })
    return terms
