import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY inside this module's process;
# tests/benches import nothing from here and see 1 device.
#
# LICM would hoist whole-stack f32 converts of bf16 parameters out of the
# scan-over-layers while loop (the CPU backend lowers bf16 dots via f32
# converts; TPU MXUs consume bf16 natively, so the hoisted stacks are a
# pure CPU-lowering artifact that inflates the memory fit-check by tens of
# GB). Disable the motion passes for faithful TPU-side accounting.
os.environ["XLA_FLAGS"] += (
    " --xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this module:

1. compiles the FULL-depth scanned program on the requested mesh —
   ``memory_analysis()`` is the HBM fit-check and the compile itself proves
   the sharding is coherent (no GSPMD errors, all collectives lowered);
2. (single-pod only) compiles python-unrolled programs at depth = 1x and
   2x the layer pattern period and extrapolates FLOPs / bytes / collective
   wire bytes exactly to the full depth:
       f(L) = f(g) + (L/g - 1) * (f(2g) - f(g))
   — necessary because ``cost_analysis()`` counts a ``lax.scan`` body once
   (verified), and sufficient because cost is affine in the repeat count;
3. derives the three roofline terms (launch/roofline.py) and writes one
   JSON record per cell.

Usage:
    python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k \
        --mesh single --out results/dryrun/sc2_train_single.json
    python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun
"""
import argparse
import json
import time
import traceback
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist.rules import resolve_rules, param_shardings
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, batch_logical_specs, input_specs
from repro.models import model as M
from repro.serve.engine import make_serve_step
from repro.train.step import (TrainHParams, abstract_train_state,
                              make_train_step, train_state_logical_specs)

HBM_PER_CHIP = 16 * 1024 ** 3      # v5e


def build_cell(arch: str, shape: str, multi_pod: bool,
               n_layers: int | None = None, unroll: bool = False,
               hp: TrainHParams | None = None, overrides: dict | None = None,
               cfg_overrides: dict | None = None):
    """Lower one cell. Returns (lowered, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = replace(cfg, **cfg_overrides)
    if n_layers is not None:
        cfg = replace(cfg, n_layers=n_layers)
    cell = SHAPES[shape]
    ov = dict(configs.sharding_overrides(arch, cell.mode))
    if overrides:
        ov.update(overrides)
    rules = resolve_rules(mesh, cfg, cell.mode, batch_size=cell.batch,
                          overrides=ov)
    batch = input_specs(cfg, cell)
    bshard = {k: rules.sharding(v)
              for k, v in batch_logical_specs(cfg, cell).items()}
    meta = {"arch": arch, "shape": shape, "mode": cell.mode,
            "batch": cell.batch, "seq": cell.seq,
            "mesh": "multi" if multi_pod else "single",
            "n_devices": mesh.devices.size, "n_layers": cfg.n_layers}

    if cell.mode == "train":
        if hp is None:
            arch_hp = dict(getattr(configs.get(arch), "TRAIN_HPARAMS", {}))
            hp = TrainHParams(remat=True, **arch_hp)
        hp = replace(hp, unroll=unroll)
        state = abstract_train_state(cfg, hp)
        sshard = param_shardings(rules, train_state_logical_specs(cfg, hp))
        fn = make_train_step(cfg, rules, hp)
        lowered = jax.jit(fn, in_shardings=(sshard, bshard),
                          donate_argnums=(0,)).lower(state, batch)
    elif cell.mode == "prefill":
        params = M.abstract_params(cfg)
        psh = param_shardings(rules, M.param_logical_specs(cfg))

        def fn(p, b):
            return M.prefill(p, b, cfg, rules, unroll=unroll)
        lowered = jax.jit(fn, in_shardings=(psh, bshard)).lower(params, batch)
    else:                                   # decode / long_decode
        params = M.abstract_params(cfg)
        psh = param_shardings(rules, M.param_logical_specs(cfg))
        cache = jax.eval_shape(
            lambda: M.init_cache(cfg, cell.batch, cell.seq, rules))
        csh = param_shardings(rules, M.cache_logical_specs(cfg))
        key = "embeddings" if cfg.input_mode == "embeddings" else "tokens"
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        pos_sh = NamedSharding(rules.mesh, P())
        fn = make_serve_step(cfg, rules, unroll=unroll)
        lowered = jax.jit(fn, in_shardings=(psh, csh, bshard[key], pos_sh),
                          donate_argnums=(1,)).lower(
                              params, cache, batch[key], pos_spec)
    return lowered, meta, cfg


def memory_info(compiled) -> dict:
    """Per-device memory accounting.

    The CPU backend's ``temp_size_in_bytes`` is the *sum* of temp buffers
    (its thunk runtime reports no liveness-based reuse), while the TPU
    BufferAssignment reuses dead buffers — so we also compute a liveness
    peak over the scheduled HLO (launch/hlo_mem.py). Both are upper
    bounds on the deployment peak; the fit-check uses the tighter one.
    """
    from repro.launch.hlo_mem import peak_temp_bytes
    ma = compiled.memory_analysis()
    fields = ["argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"]
    out = {f: int(getattr(ma, f, -1)) for f in fields}
    try:
        out["peak_temp_estimate"] = int(peak_temp_bytes(compiled.as_text()))
    except Exception:
        out["peak_temp_estimate"] = out["temp_size_in_bytes"]
    tight_temp = min(out["temp_size_in_bytes"], out["peak_temp_estimate"])
    live = out["argument_size_in_bytes"] + tight_temp \
        - max(out["alias_size_in_bytes"], 0)
    out["live_bytes"] = live
    out["fits_hbm_16g"] = bool(live >= 0 and live <= HBM_PER_CHIP)
    return out


def cost_info(lowered, compiled, n_devices: int) -> dict:
    ca = compiled.cost_analysis()
    coll = RL.parse_collectives(compiled.as_text(), n_devices)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": coll}


def _extrap(v1: float, v2: float, reps: int) -> float:
    return v1 + (reps - 1) * (v2 - v1)


def run_cell(arch: str, shape: str, mesh_kind: str,
             do_roofline: bool = True, hp: TrainHParams | None = None,
             overrides: dict | None = None, tag: str = "",
             cfg_overrides: dict | None = None) -> dict:
    multi = mesh_kind == "multi"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "tag": tag, "ok": False}
    if SHAPES[shape].mode == "long_decode" and not configs.long_context_ok(arch):
        rec.update(ok=True, skipped=True,
                   reason="pure full attention: long_500k skipped per "
                          "assignment (see DESIGN.md Arch-applicability)")
        return rec
    t0 = time.perf_counter()
    lowered, meta, cfg = build_cell(arch, shape, multi, hp=hp,
                                    overrides=overrides,
                                    cfg_overrides=cfg_overrides)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    rec.update(meta)
    rec["memory"] = memory_info(compiled)
    rec["compile_s"] = {"lower": t1 - t0, "compile": t2 - t1}
    print(f"[{arch} x {shape} x {mesh_kind}] compiled "
          f"({t2 - t1:.1f}s); memory_analysis:")
    print("  " + json.dumps(rec["memory"]))
    full_ca = compiled.cost_analysis()
    rec["scanned_cost"] = {"flops": float(full_ca.get("flops", 0.0)),
                           "bytes": float(full_ca.get("bytes accessed", 0.0))}

    if do_roofline and not multi:
        period = cfg.period
        cell = SHAPES[shape]
        infos = []
        for mult in (1, 2):
            lo, me, _ = build_cell(arch, shape, multi,
                                   n_layers=mult * period, unroll=True,
                                   hp=hp, overrides=overrides,
                                   cfg_overrides=cfg_overrides)
            co = lo.compile()
            infos.append(cost_info(lo, co, me["n_devices"]))
        reps = cfg.n_layers // period
        flops = _extrap(infos[0]["flops"], infos[1]["flops"], reps)
        nbytes = _extrap(infos[0]["bytes"], infos[1]["bytes"], reps)
        wire = {k: _extrap(infos[0]["wire"][k], infos[1]["wire"][k], reps)
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute", "total")}
        counts = {k: [infos[0]["wire"]["counts"][k],
                      infos[1]["wire"]["counts"][k]]
                  for k in infos[0]["wire"]["counts"]}
        rec["unrolled_cost"] = {"g": infos[0], "2g": infos[1]}
        rec["cost"] = {"flops_per_dev": flops, "bytes_per_dev": nbytes,
                       "wire_per_dev": wire, "collective_counts_g_2g": counts}
        rec["roofline"] = RL.summarize(
            cfg, cell.mode, cell.batch, cell.seq, meta["n_devices"],
            flops, nbytes, wire["total"])
        print("  cost_analysis (extrapolated to full depth): "
              f"flops/dev={flops:.3e} bytes/dev={nbytes:.3e} "
              f"wire/dev={wire['total']:.3e}")
        print("  roofline: " + json.dumps(
            {k: (f"{v:.4e}" if isinstance(v, float) else v)
             for k, v in rec["roofline"].items()}))
    rec["ok"] = True
    return rec


def cell_list():
    cells = []
    for arch in configs.ARCHS:
        for shape in SHAPES:
            cells.append((arch, shape))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = cell_list() if args.all else [(args.arch, args.shape)]
    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            out = args.out or os.path.join(
                args.out_dir, f"{configs.ALIASES.get(arch, arch)}"
                f"__{shape}__{mk}.json")
            try:
                rec = run_cell(arch, shape, mk,
                               do_roofline=not args.no_roofline)
            except Exception as e:               # record, keep sweeping
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "ok": False, "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(f"[{arch} x {shape} x {mk}] FAILED: {e!r}")
            with open(out, "w") as f:
                json.dump(rec, f, indent=1)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
