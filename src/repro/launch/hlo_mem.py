"""Peak-memory estimation from post-optimization HLO text.

The CPU backend's ``memory_analysis().temp_size_in_bytes`` is the SUM of
all temporary buffers — its thunk runtime does not report liveness-based
reuse — so a long chunked loop looks like it allocates every chunk at
once. The TPU compiler's BufferAssignment reuses dead buffers, so the
real deployment peak is the *liveness* peak, not the sum.

This module replays buffer liveness over the printed (scheduled) HLO:

* each instruction's output buffer goes live at its definition and dies
  at its last use (aliasing ops — tuple/get-tuple-element/bitcast/
  parameter — contribute zero);
* fusions count only their root output (internal ops live in scratch);
* ``while``/``conditional``/``call`` bodies are analyzed recursively and
  their peak is charged while the caller instruction runs.

The result is an *estimate* (we don't re-run the scheduler), but it is
(a) an upper bound under the printed order, and (b) stable across the
before/after comparisons the perf loop makes. Validated against
constructed sequential/parallel programs in tests/test_hlo_mem.py.
"""
from __future__ import annotations

import re

from .roofline import _DTYPE_BYTES

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_ALIAS_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
              "after-all", "add-dependency", "partition-id", "replica-id",
              "optimization-barrier",
              # while carries alias their init buffers (counted at def)
              "while"}
_CALLED_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)="
    r"(%?[\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        size = _DTYPE_BYTES.get(dt)
        if size is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * size
    return total


def _split_computations(hlo: str) -> dict:
    comps: dict[str, list[str]] = {}
    cur: list[str] | None = None
    name = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{"
                         r"\s*$", stripped) or \
                re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\{\s*$", stripped)
            if m and ("{" in stripped):
                name = m.group(1)
                cur = []
                comps[name] = cur
                if "ENTRY" in stripped:
                    comps["__entry__"] = cur
        else:
            if stripped == "}":
                cur = None
            else:
                cur.append(stripped)
    return comps


_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+((?:\([^=]*?\))|(?:\S+))\s+"
    r"([\w\-]+)\(")


def _parse_instr(line: str):
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, type_str, op = m.groups()
    paren = line[m.end() - 1:]
    # operand section: up to the matching close paren of the op call
    depth = 0
    end = 0
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = _OPERAND_RE.findall(paren[:end + 1])
    rest = paren[end + 1:]
    return name, type_str, op, operands, rest


def _comp_peak(name: str, comps: dict, memo: dict) -> int:
    if name in memo:
        return memo[name]
    memo[name] = 0                       # cycle guard
    lines = comps.get(name, [])
    instrs = []
    for ln in lines:
        p = _parse_instr(ln)
        if p:
            instrs.append(p)
    size = {}
    extra = {}
    last_use: dict[str, int] = {}
    for idx, (iname, type_str, op, operands, rest) in enumerate(instrs):
        size[iname] = 0 if op in _ALIAS_OPS else _type_bytes(type_str)
        ex = 0
        if op != "fusion":               # fusion internals live in scratch
            for cm in _CALLED_RE.findall(rest):
                cm = cm.lstrip("%")
                if cm in comps:
                    ex += _comp_peak(cm, comps, memo)
        mb = _BRANCHES_RE.search(rest)
        if mb:
            for cm in _OPERAND_RE.findall(mb.group(1)):
                if cm in comps:
                    ex = max(ex, _comp_peak(cm, comps, memo))
        extra[idx] = ex
        for opnd in operands:
            if opnd in size:
                last_use[opnd] = idx
    live = 0
    peak = 0
    for idx, (iname, *_rest) in enumerate(instrs):
        live += size[iname]
        peak = max(peak, live + extra[idx])
        for opnd, lu in list(last_use.items()):
            if lu == idx and opnd != iname:
                live -= size[opnd]
                last_use.pop(opnd)
    memo[name] = peak
    return peak


def peak_temp_bytes(hlo_text: str) -> int:
    """Liveness-peak estimate of temp bytes for the entry computation."""
    comps = _split_computations(hlo_text)
    if "__entry__" not in comps:
        return 0
    memo: dict[str, int] = {}
    # entry shares the line list object with its named key; find that name
    entry_name = next(k for k, v in comps.items()
                      if v is comps["__entry__"] and k != "__entry__")
    return _comp_peak(entry_name, comps, memo)
