"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the reduced (smoke) config by default so the driver is exercisable on
CPU; ``--full`` selects the production config (requires a real mesh of
adequate size). Checkpoints/resume via repro.ckpt; see examples/ for
ready-made scenarios.
"""
from __future__ import annotations

import argparse
import json

from repro import configs
from repro.data.pipeline import SyntheticLM
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.train import Trainer, TrainerConfig, TrainHParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="production config (default: smoke config)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=not args.full)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    rules = resolve_rules(mesh, cfg, "train", batch_size=args.batch,
                          overrides=configs.sharding_overrides(
                              args.arch, "train"))
    hp = TrainHParams(microbatches=args.microbatches,
                      lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps,
                      grad_compress=args.grad_compress)
    tc = TrainerConfig(steps=args.steps, log_every=args.log_every,
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, rules, hp, tc)
    data = SyntheticLM(cfg, args.batch, args.seq)
    _, history = trainer.fit(iter(data))
    print(json.dumps(history[-3:], indent=1))
    print(f"final loss: {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
