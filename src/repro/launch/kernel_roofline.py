"""Analytical + measured roofline for the partition assign kernel.

``launch/roofline.py`` models the transformer stack from compiled dry-run
artifacts; this module models the *partition hot loop* — the fused
assign+reduce sweep (kernels/assign_kernel.py and friends) — analytically
from its shape, so predicted-vs-measured utilization can be tracked as a
gated benchmark record (``BENCH_scaling.json`` → ``roofline``, gate
``compare_roofline`` in tools/bench_compare.py).

Two cost terms per (n, d, k, block_p, block_c) sweep:

* **distance block** — the ``[BP, BC]`` effective-distance tile per
  (point-tile × center-tile) grid step: a ``2*BP*BC*d``-FLOP MXU matmul
  plus an O(BP*BC) epilogue (norm adds, influence scale, running
  argmin/min/second update, modeled at ``EPILOGUE_FLOPS_PER_CELL``).
  Pruned tiles (``prune_frac``, measured by ``stats["tiles_pruned_frac"]``)
  skip both.
* **moment block** — the fused ``[d+2, K]`` accumulator: one
  ``2*BP*(d+2)*K`` one-hot matmul per point tile.

HBM traffic model: the point array streams exactly once (``4*n*d`` bytes
— the double-buffered DMA hides but does not reduce it), the center block
(``4*(d+1)*K``) is re-fetched per point tile, outputs are
``12*n`` bytes (idx/best/second) plus the ``4*(d+2)*K`` moment block.
``precision="bf16"`` halves the *MXU time* of the distance matmul
(operands are cast in-VMEM; HBM traffic is unchanged).

The ``jnp`` backend (CPU hosts, the container benchmark) is the same
arithmetic but a different memory model: the dense ``[chunk, k]``
effective-distance scratch is materialized and re-traversed by the
min/mask/second epilogue (``JNP_SCRATCH_PASSES`` round trips), which is
why the adaptive ``default_chunk`` (keep ``chunk*k*4`` cache-resident)
wins on bandwidth-bound hosts; together with the argmin-free epilogue
(kernels/ops.py ``_chunk_assign``) that measured ~1.5x over the PR 4
fused hot loop at n=2^20 k=64.

Arithmetic intensity AI = FLOPs / HBM bytes; predicted time =
max(FLOPs/peak, bytes/bw); utilization = predicted / measured (1.0 =
running at the roofline). Peaks are per-platform table entries
(``PLATFORMS``), deliberately coarse — utilization is tracked for
*regressions*, not absolute truth.
"""
from __future__ import annotations

import math

EPILOGUE_FLOPS_PER_CELL = 6.0   # norms add, scale, compare/select chain
JNP_SCRATCH_PASSES = 4.0        # eff write + argmin + mask + second-min


# Per-platform peaks. FLOP/s by distance-matmul precision; bytes/s HBM
# (or DRAM). TPU numbers per chip (v5e: 197 TF bf16 / 819 GB/s, f32 at
# half MXU rate); cpu_host is a single container-class x86 core (AVX2 FMA
# ~1e11 f32 FLOP/s, ~2e10 B/s DRAM; bf16 has no native support); gpu_a100
# per device for the Mosaic-GPU target.
PLATFORMS = {
    "tpu_v5e": {"peak_flops": {"f32": 98.5e12, "bf16": 197e12},
                "hbm_bw": 819e9},
    "tpu_v4": {"peak_flops": {"f32": 137.5e12, "bf16": 275e12},
               "hbm_bw": 1.2e12},
    "gpu_a100": {"peak_flops": {"f32": 19.5e12, "bf16": 312e12},
                 "hbm_bw": 1.555e12},
    "cpu_host": {"peak_flops": {"f32": 1.0e11, "bf16": 1.0e11},
                 "hbm_bw": 2.0e10},
}


def detect_platform() -> str:
    """Map the current jax backend to a PLATFORMS key."""
    import jax
    backend = jax.default_backend()
    if backend == "tpu":
        kind = jax.devices()[0].device_kind.lower()
        return "tpu_v4" if "v4" in kind else "tpu_v5e"
    if backend == "gpu":
        return "gpu_a100"
    return "cpu_host"


def _pad(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def assign_intensity(n: int, d: int, k: int, *, block_p: int = 1024,
                     block_c: int = 128, fused: bool = True,
                     prune_frac: float = 0.0,
                     backend: str = "pallas") -> dict:
    """FLOPs, HBM bytes and arithmetic intensity of one assign(+reduce)
    sweep, split into the distance and moment blocks. ``backend``
    selects the memory model ("pallas"/"triton" tiled kernels vs the
    dense-scratch "jnp" path); FLOPs are backend-invariant."""
    n_pad = _pad(n, block_p)
    k_pad = _pad(k, block_c)
    n_pt = n_pad // block_p
    n_ct = k_pad // block_c
    live_tiles = n_pt * n_ct * max(1.0 - prune_frac, 0.0)

    dist_flops = live_tiles * block_p * block_c * (
        2.0 * d + EPILOGUE_FLOPS_PER_CELL)
    mom_flops = n_pt * 2.0 * block_p * (d + 2) * k_pad if fused else 0.0

    bytes_points = 4.0 * n_pad * d          # streamed exactly once
    bytes_outputs = 12.0 * n_pad            # idx + best + second
    if backend == "jnp":
        # chunked dense path: the [chunk, k] scratch is written and then
        # re-traversed by the epilogue; when it exceeds cache this is
        # real DRAM traffic (the term the adaptive default_chunk shrinks)
        bytes_centers = 4.0 * (d + 1) * k   # fetched once, cache-resident
        bytes_scratch = JNP_SCRATCH_PASSES * 4.0 * n_pad * k
    else:
        # tiled kernels: centers + inv2 re-fetched per point tile
        bytes_centers = n_pt * 4.0 * (d + 1) * k_pad
        bytes_scratch = 0.0
    bytes_moments = 4.0 * (d + 2) * k_pad if fused else 0.0

    dist_bytes = bytes_points + bytes_centers + bytes_outputs + bytes_scratch
    mom_bytes = bytes_moments

    def block(flops, hbm_bytes):
        return {"flops": flops, "hbm_bytes": hbm_bytes,
                "ai": flops / max(hbm_bytes, 1.0)}

    out = {"distance": block(dist_flops, dist_bytes),
           "moments": block(mom_flops, mom_bytes),
           "total": block(dist_flops + mom_flops, dist_bytes + mom_bytes)}
    return out


def predict(n: int, d: int, k: int, *, platform: str | None = None,
            precision: str = "f32", block_p: int = 1024,
            block_c: int = 128, fused: bool = True,
            prune_frac: float = 0.0, backend: str = "pallas") -> dict:
    """Roofline prediction for one sweep: per-block AI, compute/memory
    times against the platform peaks, and the binding term."""
    if platform is None:
        platform = detect_platform()
    peaks = PLATFORMS[platform]
    peak_flops = peaks["peak_flops"][precision]
    bw = peaks["hbm_bw"]
    intensity = assign_intensity(n, d, k, block_p=block_p, block_c=block_c,
                                 fused=fused, prune_frac=prune_frac,
                                 backend=backend)
    total = intensity["total"]
    # bf16 only accelerates the distance matmul; the moment accumulation
    # and epilogue stay f32 — model the compute term per block
    dist_peak = peak_flops
    other_peak = peaks["peak_flops"]["f32"]
    compute_s = (intensity["distance"]["flops"] / dist_peak
                 + intensity["moments"]["flops"] / other_peak)
    memory_s = total["hbm_bytes"] / bw
    bound_s = max(compute_s, memory_s)
    return {
        "platform": platform, "precision": precision, "backend": backend,
        "n": n, "d": d, "k": k, "block_p": block_p, "block_c": block_c,
        "fused": fused, "prune_frac": prune_frac,
        "distance": intensity["distance"], "moments": intensity["moments"],
        "total_flops": total["flops"], "total_hbm_bytes": total["hbm_bytes"],
        "ai": total["ai"],
        "compute_s": compute_s, "memory_s": memory_s, "bound_s": bound_s,
        "bottleneck": "compute" if compute_s >= memory_s else "memory",
    }


def utilization(predicted_bound_s: float, measured_s: float) -> float:
    """Fraction of the roofline achieved (1.0 = at the bound)."""
    if not (measured_s > 0.0) or not math.isfinite(measured_s):
        return 0.0
    return predicted_bound_s / measured_s


def kernel_roofline_record(n: int, d: int, k: int, *,
                           measured_s: float | None = None,
                           platform: str | None = None,
                           precision: str = "f32", block_p: int = 1024,
                           block_c: int = 128, fused: bool = True,
                           prune_frac: float = 0.0,
                           backend: str = "pallas") -> dict:
    """The ``roofline`` record for ``BENCH_scaling.json`` (schema in
    docs/benchmarks.md): the prediction plus measured wall time and
    achieved utilization, ready for ``compare_roofline`` gating."""
    rec = predict(n, d, k, platform=platform, precision=precision,
                  block_p=block_p, block_c=block_c, fused=fused,
                  prune_frac=prune_frac, backend=backend)
    rec["measured_s"] = measured_s
    rec["utilization"] = (None if measured_s is None
                          else utilization(rec["bound_s"], measured_s))
    return rec
