"""Assigned input-shape cells and abstract input specs.

Every (arch x shape) dry-run cell lowers one of three step functions:

* ``train_4k``    -> train_step   (tokens+labels, global_batch=256, S=4096)
* ``prefill_32k`` -> prefill      (forward + cache emit, B=32, S=32768)
* ``decode_32k``  -> serve_step   (one token, B=128, KV cache of 32768)
* ``long_500k``   -> serve_step   (one token, B=1, context 524288;
                                   sub-quadratic archs only)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — shardable,
no allocation — per the modality of the arch (tokens / EnCodec codebooks /
precomputed patch embeddings for the VLM stub).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    batch: int
    mode: str                     # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "long_decode"),
}


def _tok_spec(cfg, B, S):
    if cfg.input_mode == "codebooks":
        return jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), jnp.int32)
    if cfg.input_mode == "embeddings":
        return jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.act_dtype)
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def _label_spec(cfg, B, S):
    if cfg.input_mode == "codebooks":
        return jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def input_specs(cfg, cell: ShapeCell) -> dict:
    """Abstract batch for the cell's step function (no device allocation)."""
    B, S = cell.batch, cell.seq
    key = "embeddings" if cfg.input_mode == "embeddings" else "tokens"
    if cell.mode == "train":
        return {key: _tok_spec(cfg, B, S), "labels": _label_spec(cfg, B, S)}
    if cell.mode == "prefill":
        return {key: _tok_spec(cfg, B, S)}
    # decode cells: one new token; the *cache* (built separately) carries S
    return {key: _tok_spec(cfg, B, 1)}


def batch_logical_specs(cfg, cell: ShapeCell) -> dict:
    """Logical axes for the batch pytree (resolved via dist rules)."""
    tok = (("act_batch", None, None) if cfg.input_mode in
           ("codebooks", "embeddings") else ("act_batch", None))
    lab = (("act_batch", None, None) if cfg.input_mode == "codebooks"
           else ("act_batch", None))
    key = "embeddings" if cfg.input_mode == "embeddings" else "tokens"
    if cell.mode == "train":
        return {key: tok, "labels": lab}
    return {key: tok}
