"""Space-filling curve (Hilbert) indices, 2D and 3D.

The paper bootstraps Geographer by globally sorting points along a Hilbert
curve and placing the k initial centers at equal intervals along the curve
(Algorithm 2, lines 4-7).

Two implementations are provided:

* ``hilbert_index_np`` — host-side numpy, 64-bit keys (21 bits/dim in 3D,
  31 bits/dim in 2D). Used by the data pipeline and benchmarks.
* ``hilbert_index_jnp`` — in-graph jax version with 30-bit keys (15 bits/dim
  in 2D, 10 bits/dim in 3D) that fit int32. Used inside jitted partitioning
  steps and by the distributed partitioner.

Both use Skilling's transpose algorithm ("Programming the Hilbert curve",
AIP 2004), which is branch-free over the point axis and therefore
vectorizes cleanly on both numpy and the TPU VPU.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _axes_to_transpose_np(X: np.ndarray, bits: int) -> np.ndarray:
    """Skilling inverse-undo + Gray encode. X: [n, d] uint64, returns [n, d]."""
    X = X.copy()
    n, d = X.shape
    M = np.uint64(1) << np.uint64(bits - 1)
    # Inverse undo excess work
    Q = M
    while Q > np.uint64(1):
        Pm = Q - np.uint64(1)
        for i in range(d):
            flag = (X[:, i] & Q) != 0
            # where flag: invert low bits of X[:,0]
            X[:, 0] = np.where(flag, X[:, 0] ^ Pm, X[:, 0])
            # else: exchange low bits of X[:,0] and X[:,i]
            t = np.where(~flag, (X[:, 0] ^ X[:, i]) & Pm, np.uint64(0))
            X[:, 0] ^= t
            X[:, i] ^= t
        Q >>= np.uint64(1)
    # Gray encode
    for i in range(1, d):
        X[:, i] ^= X[:, i - 1]
    t = np.zeros(n, dtype=np.uint64)
    Q = M
    while Q > np.uint64(1):
        flag = (X[:, d - 1] & Q) != 0
        t = np.where(flag, t ^ (Q - np.uint64(1)), t)
        Q >>= np.uint64(1)
    for i in range(d):
        X[:, i] ^= t
    return X


def _interleave_np(X: np.ndarray, bits: int) -> np.ndarray:
    """Bit-interleave the transposed form into a single key. X: [n, d]."""
    n, d = X.shape
    key = np.zeros(n, dtype=np.uint64)
    for b in range(bits - 1, -1, -1):
        for i in range(d):
            key = (key << np.uint64(1)) | ((X[:, i] >> np.uint64(b)) & np.uint64(1))
    return key


def quantize_np(points: np.ndarray, bits: int) -> np.ndarray:
    """Scale float coords in a bounding box to integer grid [0, 2^bits)."""
    lo = points.min(axis=0)
    hi = points.max(axis=0)
    span = np.maximum(hi - lo, 1e-30)
    scaled = (points - lo) / span
    q = np.minimum((scaled * (2 ** bits)).astype(np.uint64), np.uint64(2 ** bits - 1))
    return q


def hilbert_index_np(points: np.ndarray, bits: int | None = None) -> np.ndarray:
    """Hilbert key per point. points: [n, d] float, d in {2, 3}."""
    d = points.shape[1]
    if bits is None:
        bits = 31 if d == 2 else 21
    assert bits * d <= 63, "key must fit int64"
    q = quantize_np(np.asarray(points, dtype=np.float64), bits)
    t = _axes_to_transpose_np(q, bits)
    return _interleave_np(t, bits)


# --------------------------------------------------------------------------
# jax version (int32 keys; 15 bits/dim 2D, 10 bits/dim 3D)
# --------------------------------------------------------------------------

def _axes_to_transpose_jnp(X: jnp.ndarray, bits: int) -> jnp.ndarray:
    """X: [n, d] int32 -> transposed Hilbert form [n, d]. Unrolled over bits
    (bits <= 15) so the graph is straight-line; vectorized over points."""
    n, d = X.shape
    cols = [X[:, i] for i in range(d)]
    Q = 1 << (bits - 1)
    while Q > 1:
        Pm = Q - 1
        for i in range(d):
            flag = (cols[i] & Q) != 0
            inv = jnp.where(flag, cols[0] ^ Pm, cols[0])
            t = jnp.where(flag, 0, (cols[0] ^ cols[i]) & Pm)
            cols[0] = inv ^ t
            cols[i] = jnp.where(flag, cols[i], cols[i] ^ t)
        Q >>= 1
    for i in range(1, d):
        cols[i] = cols[i] ^ cols[i - 1]
    t = jnp.zeros(n, dtype=X.dtype)
    Q = 1 << (bits - 1)
    while Q > 1:
        flag = (cols[d - 1] & Q) != 0
        t = jnp.where(flag, t ^ (Q - 1), t)
        Q >>= 1
    return jnp.stack([c ^ t for c in cols], axis=1)


def hilbert_index_jnp(points: jnp.ndarray, bits: int | None = None,
                      lo: jnp.ndarray | None = None,
                      hi: jnp.ndarray | None = None) -> jnp.ndarray:
    """In-graph Hilbert key, int32. points: [n, d] float32.

    ``lo``/``hi`` allow passing a *global* bounding box (psum'd beforehand)
    so shards quantize consistently.
    """
    d = points.shape[1]
    if bits is None:
        bits = 15 if d == 2 else 10
    assert bits * d <= 31
    if lo is None:
        lo = jnp.min(points, axis=0)
    if hi is None:
        hi = jnp.max(points, axis=0)
    span = jnp.maximum(hi - lo, 1e-30)
    scaled = (points - lo) / span
    q = jnp.clip((scaled * (2 ** bits)).astype(jnp.int32), 0, 2 ** bits - 1)
    t = _axes_to_transpose_jnp(q, bits)
    key = jnp.zeros(points.shape[0], dtype=jnp.int32)
    for b in range(bits - 1, -1, -1):
        for i in range(d):
            key = (key << 1) | ((t[:, i] >> b) & 1)
    return key


def sfc_initial_centers_sharded(points: jnp.ndarray, weights: jnp.ndarray,
                                k: int, axis_name: str,
                                n_buckets: int = 1024) -> jnp.ndarray:
    """Distributed SFC bootstrap (paper Alg. 2 lines 4-7 under SPMD).

    Runs inside ``shard_map`` with ``points``/``weights`` holding one
    shard. Three steps, all O(1)-sized communication (independent of n):

    1. per-shard Hilbert keys against the *global* bounding box
       (pmin/pmax so every shard quantizes identically);
    2. a psum'd weighted key histogram whose prefix sums locate the k
       global weighted-quantile splitter keys — the static-shape analogue
       of the paper's distributed prefix sum over the sorted curve;
    3. for each splitter, the actual point with the globally nearest key
       (pmin over per-shard minima, lowest shard id breaking ties, winner
       coordinates broadcast with one psum).

    Returns [k, d] centers, replicated across shards. Zero-weight padded
    slots (which replicate real points) contribute nothing to the
    histogram and only valid coordinates to step 3.
    """
    d = points.shape[1]
    bits = 15 if d == 2 else 10
    total_bits = bits * d
    shift = max(total_bits - int(np.log2(n_buckets)), 0)
    lo = jax.lax.pmin(jnp.min(points, axis=0), axis_name)
    hi = jax.lax.pmax(jnp.max(points, axis=0), axis_name)
    keys = hilbert_index_jnp(points, bits=bits, lo=lo, hi=hi)

    bucket = (keys >> shift).astype(jnp.int32)
    hist = jax.ops.segment_sum(weights, bucket, num_segments=n_buckets)
    hist = jax.lax.psum(hist, axis_name)
    cum = jnp.cumsum(hist)
    total = jnp.maximum(cum[-1], 1e-12)
    targets = (jnp.arange(k, dtype=cum.dtype) + 0.5) * (total / k)
    b = jnp.clip(jnp.searchsorted(cum, targets), 0, n_buckets - 1)
    prev = jnp.where(b > 0, cum[jnp.maximum(b - 1, 0)], 0.0)
    frac = jnp.clip((targets - prev) / jnp.maximum(hist[b], 1e-12), 0.0, 1.0)
    splitters = (b.astype(jnp.float32) + frac) * float(2 ** shift)  # [k]

    # nearest real point to each splitter key (global argmin, ties -> the
    # lowest shard id, then the shard-local argmin)
    kd = jnp.abs(keys.astype(jnp.float32)[None, :] - splitters[:, None])
    loc = jnp.argmin(kd, axis=1)                          # [k] local best
    loc_d = jnp.take_along_axis(kd, loc[:, None], axis=1)[:, 0]
    best_d = jax.lax.pmin(loc_d, axis_name)
    me = jax.lax.axis_index(axis_name)
    n_shards = jax.lax.psum(1, axis_name)
    cand = jnp.where(loc_d <= best_d, me, n_shards)
    winner = jax.lax.pmin(cand, axis_name)
    mine = (winner == me)[:, None]
    contrib = jnp.where(mine, points[loc], 0.0)
    return jax.lax.psum(contrib, axis_name)


def sfc_order(points: np.ndarray) -> np.ndarray:
    """Stable Hilbert-curve sort order of ``points`` (host-side). Shared by
    the SFC baseline partitioner, initial-center placement, and the
    hierarchical engine's per-block center seeding."""
    return np.argsort(hilbert_index_np(points), kind="stable")


def sfc_initial_centers(points: np.ndarray, k: int,
                        weights: np.ndarray | None = None) -> np.ndarray:
    """Paper Alg. 2 line 7: centers at sorted positions i*n/k + n/2k.

    With node weights, strides are taken in cumulative-weight space so each
    center seeds a block of roughly equal weight.
    """
    order = sfc_order(points)
    n = points.shape[0]
    if weights is None:
        idx = (np.arange(k) * n) // k + n // (2 * k)
        return points[order[np.minimum(idx, n - 1)]]
    w = np.asarray(weights, dtype=np.float64)[order]
    cw = np.cumsum(w)
    total = cw[-1]
    targets = (np.arange(k) + 0.5) * (total / k)
    pos = np.searchsorted(cw, targets)
    return points[order[np.minimum(pos, n - 1)]]
