"""Mesh / point-set generators mirroring the paper's benchmark families.

The paper evaluates on: 2D adaptively-refined triangular meshes (hugetric/
hugetrace/hugebubbles), 2D FEM meshes, random geometric graphs (rgg_n),
2D/3D Delaunay triangulations, and 2.5D weighted climate meshes (fesom).

scipy is unavailable in this container, so instead of true Delaunay we build
k-nearest / radius graphs on the same point distributions via uniform-grid
hashing — these have the same local, planar-ish structure that geometric
partitioners exploit, and all graph metrics remain well-defined.

Graphs are returned in CSR form: (indptr [n+1], indices [nnz]) int64 numpy.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Mesh:
    """A geometric graph: points + CSR adjacency + optional node weights."""
    points: np.ndarray          # [n, d] float64
    indptr: np.ndarray          # [n+1] int64
    indices: np.ndarray         # [nnz] int64
    weights: np.ndarray | None = None   # [n] float64 (2.5D meshes)
    name: str = "mesh"

    @property
    def n(self) -> int:
        return self.points.shape[0]

    @property
    def m(self) -> int:
        return self.indices.shape[0] // 2

    @property
    def dim(self) -> int:
        return self.points.shape[1]


def _dedup_sym_edges(n: int, rows: np.ndarray, cols: np.ndarray):
    """Symmetrize + dedup an edge list, drop self loops, return CSR."""
    mask = rows != cols
    rows, cols = rows[mask], cols[mask]
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    key = r * np.int64(n) + c
    _, uniq = np.unique(key, return_index=True)
    r, c = r[uniq], c[uniq]
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, r + 1, 1)
    np.cumsum(indptr, out=indptr)
    return indptr, c.astype(np.int64)


def grid_triangulation(nx: int, ny: int, jitter: float = 0.0,
                       seed: int = 0) -> Mesh:
    """Structured triangular mesh on an nx x ny grid (FEM-mesh analogue)."""
    rng = np.random.default_rng(seed)
    xs, ys = np.meshgrid(np.arange(nx, dtype=np.float64),
                         np.arange(ny, dtype=np.float64), indexing="ij")
    pts = np.stack([xs.ravel(), ys.ravel()], axis=1)
    if jitter > 0:
        pts += rng.uniform(-jitter, jitter, pts.shape)
    idx = np.arange(nx * ny).reshape(nx, ny)
    e = []
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))     # right
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))     # up
    e.append(np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1))  # diag
    edges = np.concatenate(e, axis=0)
    indptr, indices = _dedup_sym_edges(nx * ny, edges[:, 0], edges[:, 1])
    return Mesh(pts, indptr, indices, name=f"tri{nx}x{ny}")


def _grid_hash_neighbors(pts: np.ndarray, radius: float):
    """All pairs within ``radius`` via uniform-grid hashing. Returns edge list."""
    n, d = pts.shape
    lo = pts.min(axis=0)
    cell = radius
    coords = np.floor((pts - lo) / cell).astype(np.int64)
    ncell = coords.max(axis=0) + 1
    # linear cell ids
    mult = np.ones(d, dtype=np.int64)
    for i in range(d - 1, 0, -1):
        mult[i - 1] = mult[i] * ncell[i]
    cid = coords @ mult
    order = np.argsort(cid, kind="stable")
    sorted_cid = cid[order]
    starts = np.searchsorted(sorted_cid, np.arange(int(ncell.prod()) + 1))
    # neighbor cell offsets
    offsets = np.array(np.meshgrid(*([[-1, 0, 1]] * d), indexing="ij")
                       ).reshape(d, -1).T
    rows_all, cols_all = [], []
    r2 = radius * radius
    for off in offsets:
        nb = coords + off
        valid = np.all((nb >= 0) & (nb < ncell), axis=1)
        nb_cid = nb @ mult
        s = starts[np.where(valid, nb_cid, 0)]
        t = starts[np.where(valid, nb_cid + 1, 0)]
        maxlen = int((t - s).max(initial=0))
        if maxlen == 0:
            continue
        # expand candidate lists per point, chunked to bound memory
        pidx = np.where(valid & (t > s))[0]
        for chunk in np.array_split(pidx, max(1, len(pidx) // 200_000)):
            if len(chunk) == 0:
                continue
            cs, ct = s[chunk], t[chunk]
            L = ct - cs
            maxL = int(L.max())
            grid_idx = cs[:, None] + np.arange(maxL)[None, :]
            ok = np.arange(maxL)[None, :] < L[:, None]
            cand = order[np.minimum(grid_idx, len(order) - 1)]
            src = np.broadcast_to(chunk[:, None], cand.shape)
            src, cand = src[ok], cand[ok]
            dd = ((pts[src] - pts[cand]) ** 2).sum(axis=1)
            keep = (dd <= r2) & (src < cand)
            rows_all.append(src[keep])
            cols_all.append(cand[keep])
    if not rows_all:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    return np.concatenate(rows_all), np.concatenate(cols_all)


def random_geometric_graph(n: int, dim: int = 2, avg_deg: float = 8.0,
                           seed: int = 0) -> Mesh:
    """rgg_n analogue: uniform points, edges within radius chosen for avg_deg."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 1.0, (n, dim))
    if dim == 2:
        radius = np.sqrt(avg_deg / (np.pi * n))
    else:
        radius = (avg_deg / (4.0 / 3.0 * np.pi * n)) ** (1.0 / 3.0)
    rows, cols = _grid_hash_neighbors(pts, radius)
    indptr, indices = _dedup_sym_edges(n, rows, cols)
    return Mesh(pts, indptr, indices, name=f"rgg{n}_{dim}d")


def knn_mesh(pts: np.ndarray, k: int = 6, name: str = "knn") -> Mesh:
    """k-nearest-neighbor graph (Delaunay-mesh proxy) via grid hashing."""
    n, d = pts.shape
    # choose a radius giving ~4k candidates on average, then take k nearest
    vol = np.prod(pts.max(0) - pts.min(0) + 1e-12)
    density = n / vol
    if d == 2:
        radius = np.sqrt(4.0 * k / (np.pi * density))
    else:
        radius = (4.0 * k / (4.0 / 3.0 * np.pi * density)) ** (1.0 / 3.0)
    rows, cols = _grid_hash_neighbors(pts, radius)
    # keep k nearest per node from the candidate set (both directions)
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    dd = ((pts[r] - pts[c]) ** 2).sum(axis=1)
    order = np.lexsort((dd, r))
    r, c, dd = r[order], c[order], dd[order]
    starts = np.searchsorted(r, np.arange(n + 1))
    # rank of each candidate within its (distance-sorted) row; keep the k
    # nearest — vectorized, identical to slicing each row's first k
    keep = (np.arange(len(r)) - starts[r]) < k
    indptr, indices = _dedup_sym_edges(n, r[keep], c[keep])
    return Mesh(pts, indptr, indices, name=name)


def refined_mesh(n: int, seed: int = 0, dim: int = 2) -> Mesh:
    """Adaptively-refined mesh analogue (hugetric-like): point density is
    concentrated near a curved feature, graph is kNN."""
    rng = np.random.default_rng(seed)
    n_feat = n // 2
    # feature: a circle arc (2D) / spherical shell (3D)
    u = rng.uniform(0, 2 * np.pi, n_feat)
    rad = 0.3 + rng.normal(0, 0.02, n_feat)
    if dim == 2:
        feat = np.stack([0.5 + rad * np.cos(u), 0.5 + rad * np.sin(u)], 1)
    else:
        v = np.arccos(rng.uniform(-1, 1, n_feat))
        feat = np.stack([0.5 + rad * np.sin(v) * np.cos(u),
                         0.5 + rad * np.sin(v) * np.sin(u),
                         0.5 + rad * np.cos(v)], 1)
    bulk = rng.uniform(0, 1, (n - n_feat, dim))
    pts = np.concatenate([feat, bulk], axis=0)
    return knn_mesh(pts, k=6, name=f"refined{n}_{dim}d")


def stretched_grid(n: int, aspect: float = 6.0, jitter: float = 0.2,
                   seed: int = 0) -> Mesh:
    """Anisotropic stretched grid: a square triangulated grid whose x
    coordinates are scaled by ``aspect`` — isotropic topology, strongly
    anisotropic geometry. The stress case for geometric partitioners:
    compact-in-space blocks are elongated-in-graph, so axis-aligned cuts
    (RCB/MJ) and locality-preserving curves behave very differently here
    than on isotropic meshes."""
    side = max(int(np.sqrt(n)), 2)
    base = grid_triangulation(side, side, jitter=jitter, seed=seed)
    pts = base.points * np.array([aspect, 1.0])
    return Mesh(pts, base.indptr, base.indices,
                name=f"aniso{side * side}_a{aspect:g}")


def powerlaw_rgg(n: int, dim: int = 2, alpha: float = 2.0,
                 w_cap: float = 100.0, seed: int = 0) -> Mesh:
    """Random geometric graph with power-law node weights: Pareto(alpha)
    draws (clipped at ``w_cap`` so no single node exceeds a feasible block
    share) model particle-in-cell / n-body loads where a few cells carry
    most of the work. Weighted comm-volume balance is the §5 regime the
    2.5D climate mesh probes gently; this one probes it hard."""
    mesh = random_geometric_graph(n, dim, seed=seed)
    rng = np.random.default_rng(seed + 0x9E37)
    mesh.weights = np.minimum(rng.pareto(alpha, n) + 1.0, w_cap)
    mesh.name = f"rggpow{n}_{dim}d"
    return mesh


def climate_mesh_25d(n: int, seed: int = 0) -> Mesh:
    """2.5D weighted mesh analogue (fesom-like): 2D points with node weights
    representing vertical column depth; weight varies smoothly with a few
    deep basins."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, 2))
    mesh = knn_mesh(pts, k=6, name=f"climate{n}")
    centers = rng.uniform(0.2, 0.8, (3, 2))
    w = np.ones(n)
    for c in centers:
        d2 = ((pts - c) ** 2).sum(axis=1)
        w += 40.0 * np.exp(-d2 / 0.02)
    mesh.weights = w
    return mesh


# ---------------------------------------------------------------------------
# Time-evolving workloads (dynamic repartitioning, DESIGN.md §8)
#
# Real simulations (AMR, moving meshes, particle codes) shift their load
# distribution every few timesteps. These generators model that as a
# time-dependent node-weight field over a FIXED point set: w(t) =
# workload.weights_at(points, t). They are written in jax.numpy with a
# (possibly traced) step index t, so the same generator drives both the
# host-side repartition loop and the fully jitted lax.scan driver in
# ``core.timeseries`` — and they are frozen/hashable so they can be static
# jit arguments.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DriftingHotspot:
    """A Gaussian load hotspot whose center drifts linearly with time —
    the canonical "feature moving through the mesh" workload (e.g. a shock
    front or a tracked storm). ``w = base + amplitude *
    exp(-|x - c(t)|^2 / (2 sigma^2))`` with ``c(t) = start + t*velocity``.
    """
    amplitude: float = 8.0
    sigma: float = 0.14          # sqrt(0.02): matches the 2.5D climate mesh
    start: tuple = (0.25, 0.25)
    velocity: tuple = (0.01, 0.008)
    base: float = 1.0

    def weights_at(self, points, t):
        """[n] weights at step ``t`` (int/float, may be a jax tracer)."""
        import jax.numpy as jnp
        c = jnp.asarray(self.start) + t * jnp.asarray(self.velocity)
        d2 = jnp.sum((points[:, :len(self.start)] - c) ** 2, axis=1)
        return self.base + self.amplitude * jnp.exp(
            -d2 / (2.0 * self.sigma ** 2))


@dataclass(frozen=True)
class RotatingWave:
    """An angular density wave rotating around a fixed pivot — load
    oscillates smoothly through every block in turn (e.g. day/night
    heating in a climate mesh): ``w = base + amplitude * (1 + cos(lobes *
    theta(x) - omega * t)) / 2``.
    """
    amplitude: float = 6.0
    lobes: int = 2
    omega: float = 0.35          # radians per step
    center: tuple = (0.5, 0.5)
    base: float = 1.0

    def weights_at(self, points, t):
        """[n] weights at step ``t`` (int/float, may be a jax tracer)."""
        import jax.numpy as jnp
        c = jnp.asarray(self.center)
        theta = jnp.arctan2(points[:, 1] - c[1], points[:, 0] - c[0])
        phase = jnp.cos(self.lobes * theta - self.omega * t)
        return self.base + self.amplitude * 0.5 * (1.0 + phase)


@dataclass(frozen=True)
class MovingRefinement:
    """AMR-style local refinement: node weights are *multiplied* by
    ``factor`` inside a disc of ``radius`` around a moving refinement
    center — the discontinuous analogue of the hotspot (cells inside the
    refined region carry factor-times the work).
    """
    factor: float = 8.0
    radius: float = 0.18
    start: tuple = (0.3, 0.3)
    velocity: tuple = (0.012, 0.009)
    base: float = 1.0

    def weights_at(self, points, t):
        """[n] weights at step ``t`` (int/float, may be a jax tracer)."""
        import jax.numpy as jnp
        c = jnp.asarray(self.start) + t * jnp.asarray(self.velocity)
        d2 = jnp.sum((points[:, :len(self.start)] - c) ** 2, axis=1)
        return self.base * jnp.where(d2 < self.radius ** 2,
                                     self.factor, 1.0)


WORKLOADS = {
    "drifting_hotspot": DriftingHotspot,
    "rotating_wave": RotatingWave,
    "amr_refine": MovingRefinement,
}


REGISTRY = {
    "tri": lambda n, seed=0: grid_triangulation(int(np.sqrt(n)), int(np.sqrt(n)), jitter=0.2, seed=seed),
    "rgg2d": lambda n, seed=0: random_geometric_graph(n, 2, seed=seed),
    "rgg3d": lambda n, seed=0: random_geometric_graph(n, 3, seed=seed),
    "delaunay2d": lambda n, seed=0: knn_mesh(np.random.default_rng(seed).uniform(0, 1, (n, 2)), 6, f"delaunay{n}_2d"),
    "delaunay3d": lambda n, seed=0: knn_mesh(np.random.default_rng(seed).uniform(0, 1, (n, 3)), 6, f"delaunay{n}_3d"),
    "refined2d": lambda n, seed=0: refined_mesh(n, seed, 2),
    "refined3d": lambda n, seed=0: refined_mesh(n, seed, 3),
    "aniso": lambda n, seed=0: stretched_grid(n, seed=seed),
    "rggpow": lambda n, seed=0: powerlaw_rgg(n, 2, seed=seed),
    "climate25d": lambda n, seed=0: climate_mesh_25d(n, seed),
}
