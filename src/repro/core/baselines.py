"""Geometric partitioning baselines the paper compares against (Section 3.1).

* RCB  — recursive coordinate bisection (Berger & Bokhari): split on the
         widest coordinate at the weighted median, recurse. Supports any k
         via proportional splits.
* RIB  — recursive inertial bisection: like RCB but split along the
         principal inertia axis (PCA direction) of the local point set.
* SFC  — Hilbert space-filling-curve partition (zoltanSFC analogue): sort by
         Hilbert key, cut into k contiguous equal-weight chunks.
* MJ   — MultiJagged-lite (Deveci et al.): one-shot multisection: factor k
         into per-dimension counts, cut each dimension at weight quantiles.

All baselines respect node weights and produce near-perfect balance (they
cut at weighted quantiles), mirroring the Zoltan implementations' behavior.

These are the *implementations*; the preferred entry point is the unified
engine (``repro.partition.partition(problem, method="rcb" | "rib" |
"sfc" | "multijagged")``), which wraps them behind the common
PartitionProblem/PartitionResult types. The ``BASELINES`` dict below is
kept for existing callers.
"""
from __future__ import annotations

import numpy as np

from .sfc import sfc_order


def _weighted_quantile_split(vals: np.ndarray, w: np.ndarray, frac: float) -> float:
    order = np.argsort(vals, kind="stable")
    cw = np.cumsum(w[order])
    total = cw[-1]
    pos = np.searchsorted(cw, frac * total)
    pos = min(pos, len(order) - 1)
    return vals[order[pos]]


def rcb(points: np.ndarray, k: int, weights: np.ndarray | None = None,
        axis_fn=None) -> np.ndarray:
    """Recursive bisection; ``axis_fn(points)`` picks the split direction
    (returns a unit vector). Default: widest coordinate axis."""
    n, d = points.shape
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    part = np.zeros(n, dtype=np.int64)

    def recurse(idx: np.ndarray, lo_blk: int, hi_blk: int):
        nblk = hi_blk - lo_blk
        if nblk <= 1 or idx.size == 0:
            part[idx] = lo_blk
            return
        k_left = nblk // 2
        frac = k_left / nblk
        pts = points[idx]
        if axis_fn is None:
            spans = pts.max(axis=0) - pts.min(axis=0)
            direction = np.zeros(d)
            direction[np.argmax(spans)] = 1.0
        else:
            direction = axis_fn(pts, w[idx])
        proj = pts @ direction
        # weighted median split with deterministic tie-break by index
        order = np.argsort(proj, kind="stable")
        cw = np.cumsum(w[idx][order])
        pos = int(np.searchsorted(cw, frac * cw[-1]))
        pos = min(max(pos, 1), idx.size - 1) if idx.size > 1 else 0
        left = idx[order[:pos]]
        right = idx[order[pos:]]
        recurse(left, lo_blk, lo_blk + k_left)
        recurse(right, lo_blk + k_left, hi_blk)

    recurse(np.arange(n), 0, k)
    return part


def _inertial_axis(pts: np.ndarray, w: np.ndarray) -> np.ndarray:
    mu = np.average(pts, axis=0, weights=w)
    x = (pts - mu) * np.sqrt(w)[:, None]
    cov = x.T @ x
    vals, vecs = np.linalg.eigh(cov)
    return vecs[:, -1]


def rib(points: np.ndarray, k: int, weights: np.ndarray | None = None) -> np.ndarray:
    """Recursive inertial bisection."""
    return rcb(points, k, weights, axis_fn=_inertial_axis)


def sfc_partition(points: np.ndarray, k: int,
                  weights: np.ndarray | None = None) -> np.ndarray:
    """Hilbert-curve chunking (zoltanSFC / ParMetis-SFC analogue)."""
    n = points.shape[0]
    order = sfc_order(points)
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    cw = np.cumsum(w[order])
    total = cw[-1]
    # block of point at cumulative weight c: floor(c / (total/k))
    blk = np.minimum((cw * k / total).astype(np.int64), k - 1)
    part = np.zeros(n, dtype=np.int64)
    part[order] = blk
    return part


def multijagged(points: np.ndarray, k: int,
                weights: np.ndarray | None = None) -> np.ndarray:
    """MultiJagged-lite: factor k = k1*k2(*k3), cut dim 0 into k1 weighted
    quantile slabs, each slab into k2 (then k3) — one-shot multisection."""
    n, d = points.shape
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    # factor k into d roughly-equal factors
    factors = []
    rem = k
    for i in range(d - 1):
        f = int(round(rem ** (1.0 / (d - i))))
        f = max(1, min(f, rem))
        while rem % f != 0:
            f -= 1
        factors.append(f)
        rem //= f
    factors.append(rem)

    part = np.zeros(n, dtype=np.int64)

    def cut(idx: np.ndarray, dim: int, blk_base: int):
        if dim == d - 1 or factors[dim] * 0 + dim == d - 1:
            pass
        f = factors[dim]
        vals = points[idx, dim]
        order = np.argsort(vals, kind="stable")
        cw = np.cumsum(w[idx][order])
        total = cw[-1]
        slab = np.minimum((cw * f / total).astype(np.int64), f - 1)
        stride = int(np.prod(factors[dim + 1:])) if dim + 1 < d else 1
        for s in range(f):
            sub = idx[order[slab == s]]
            if dim + 1 < d:
                cut(sub, dim + 1, blk_base + s * stride)
            else:
                part[sub] = blk_base + s

    cut(np.arange(n), 0, 0)
    return part


BASELINES = {
    "rcb": rcb,
    "rib": rib,
    "hsfc": sfc_partition,
    "mj": multijagged,
}
