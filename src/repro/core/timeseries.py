"""Dynamic load-balancing simulation: perturb → repartition → measure.

The paper solves the cold-start problem; real simulations (AMR, moving
meshes, particle codes) re-balance every few timesteps. This module drives
that loop over the time-evolving workloads of ``core.meshes``
(``WORKLOADS``: drifting Gaussian hotspot, rotating density wave,
AMR-style moving refinement) and reports, per step, the metrics a dynamic
load balancer lives by: movement-iteration count, migration volume /
fraction, retained fraction, and imbalance (DESIGN.md §8).

Two drivers, same semantics:

* ``simulate_loadbalance`` — host loop through the engine front doors
  (``partition`` / ``repartition``): works with every registry method,
  warm or cold mode, and ``devices=P``.
* ``simulate_loadbalance_scan`` — ONE jitted ``lax.scan`` over all T
  steps for the warm geographer path: the whole perturb → warm-restart →
  migration-metrics pipeline is in-graph (weights are regenerated from
  the traced step index, migration is computed with the in-graph metrics)
  so T repartition steps cost one dispatch. Bit-for-bit equal to the host
  loop's warm path on the permuted point order (tested in
  tests/test_repartition.py).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import metrics
from .balanced_kmeans import BKMConfig, balanced_kmeans


def simulate_loadbalance(problem, workload, steps: int = 8, *,
                         method: str = "geographer", mode: str = "warm",
                         devices: int | None = None, **opts) -> dict:
    """Alternate perturb → repartition for ``steps`` steps on the host.

    Step 0 is always a cold ``partition()`` under ``workload.weights_at(
    points, 0)``; steps 1..T then re-weight the problem and call
    ``repartition`` against the previous result — warm-started
    (``mode="warm"``) or cold + relabel-matched (``mode="cold"``, the
    fair restart baseline).

    Args:
        problem: a ``partition.PartitionProblem``; its weights are
            replaced by the workload's per-step field (the problem's own
            weights are ignored).
        workload: an object with ``weights_at(points, t) -> [n]`` (see
            ``core.meshes.WORKLOADS``).
        steps: number of repartition steps T (>= 1).
        method: registry method for every step.
        mode: "warm" or "cold".
        devices: optional shard count for the multi-device path.
        **opts: forwarded to ``partition`` / ``repartition``.

    Returns:
        dict with ``"per_step"`` (list of per-step records: step, iters,
        imbalance, balanced, migration_volume, migration_fraction,
        retained_fraction, time_s — plus cut/comm-volume when
        ``evaluate=True`` is passed through and the problem carries a
        graph), ``"summary"`` (means + maxima across steps) and the run
        config. The final ``PartitionResult`` rides at ``"final_result"``
        (not JSON-serializable; drop it before dumping).
    """
    from repro.partition import partition
    from repro.partition.repartition import repartition

    if mode not in ("warm", "cold"):
        raise ValueError(f"mode must be 'warm' or 'cold', got {mode!r}")
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")

    pts = np.asarray(problem.points)
    w0 = np.asarray(workload.weights_at(pts, 0))
    prev = partition(problem.replace(weights=w0), method=method,
                     devices=devices, **opts)
    records = []
    for t in range(1, steps + 1):
        w_t = np.asarray(workload.weights_at(pts, t))
        prob_t = problem.replace(weights=w_t)
        t0 = time.perf_counter()
        res = repartition(prob_t, prev, method=method, devices=devices,
                          warm=(True if mode == "warm" else False), **opts)
        dt = time.perf_counter() - t0
        imb = res.imbalance()
        mig = res.stats["migration"]
        rec = {
            "step": t,
            "iters": res.stats.get("iters"),
            "imbalance": imb,
            "balanced": bool(imb <= problem.epsilon + 1e-6),
            "migration_volume": mig["volume"],
            "migration_fraction": mig["fraction"],
            "retained_fraction": mig["retained_fraction"],
            "time_s": dt,
        }
        if res.quality:        # per-step cut/comm volume via evaluate=True
            rec.update({k: v for k, v in res.quality.items()
                        if k not in rec})
        records.append(rec)
        prev = res
    iters = [r["iters"] for r in records if r["iters"] is not None]
    summary = {
        "mean_iters": float(np.mean(iters)) if iters else None,
        "mean_migration_fraction": float(
            np.mean([r["migration_fraction"] for r in records])),
        "mean_migration_volume": float(
            np.mean([r["migration_volume"] for r in records])),
        "max_imbalance": float(max(r["imbalance"] for r in records)),
        "all_balanced": bool(all(r["balanced"] for r in records)),
        "total_time_s": float(sum(r["time_s"] for r in records)),
    }
    return {"mode": mode, "method": method, "devices": devices,
            "steps": steps, "n": problem.n, "k": problem.k,
            "epsilon": problem.epsilon,
            "workload": type(workload).__name__,
            "per_step": records, "summary": summary,
            "final_result": prev}


def simulate_loadbalance_scan(points, centers0, influence0, labels0,
                              workload, steps: int, cfg: BKMConfig):
    """T warm-started repartition steps as ONE jitted ``lax.scan``.

    The carry is the warm-start state (centers, influence, labels); each
    scan step regenerates the weights from the traced step index,
    warm-restarts balanced k-means, and computes the migration metrics
    in-graph — no host round-trips between steps.

    Args:
        points: [n, d] — pass the PERMUTED points (the same permutation
            the host path derives from the problem seed) for bit-for-bit
            agreement with ``repartition``'s single-device warm path.
        centers0: [k, d] initial (cold-start) centers.
        influence0: [k] initial influence.
        labels0: [n] int32 initial labels (in the same permuted order).
        workload: a frozen workload dataclass from ``core.meshes`` (static
            jit argument — must be hashable).
        steps: number of scan steps T (static).
        cfg: BKMConfig with ``warmup=False`` (enforced; warm starts never
            sample).

    Returns:
        (final_carry, per_step) where final_carry = (centers [k, d],
        influence [k], labels [n]) after step T and per_step is a dict of
        [T]-shaped arrays: "iters", "imbalance", "migration_volume",
        "migration_fraction", "retained_fraction", "balance_retries".
    """
    if cfg.warmup:
        import dataclasses
        cfg = dataclasses.replace(cfg, warmup=False)
    return _scan_run(jnp.asarray(points, cfg.dtype), centers0, influence0,
                     labels0, workload, steps, cfg)


@functools.partial(jax.jit, static_argnames=("workload", "steps", "cfg"))
def _scan_run(points, centers0, influence0, labels0, workload, steps, cfg):
    # mirror repartition()'s balance-retry loop (DESIGN.md §8): a solve
    # whose final balance pass ends above epsilon is re-warmed from its
    # own output state, at most MAX_BALANCE_RETRIES times — the in-graph
    # twin of the host loop, so host and scan stay step-for-step equal
    # even on instances where the influence adaptation oscillates
    from repro.partition.repartition import MAX_BALANCE_RETRIES
    eps_bar = jnp.asarray(cfg.epsilon + 1e-6, cfg.dtype)

    def step(carry, t):
        centers, infl, prev_labels = carry
        w_t = workload.weights_at(points, t).astype(cfg.dtype)

        def retry_cond(state):
            attempt, _, _, _, _, imb = state
            return (attempt < MAX_BALANCE_RETRIES + 1) & (
                (attempt == 0) | (imb > eps_bar))

        def retry_body(state):
            attempt, c, i_, prev_lab, total, _ = state
            A, c2, i2, stats = balanced_kmeans(
                points, cfg, w_t, c, influence0=i_,
                warm_start=True, prev_assignment=prev_lab)
            return (attempt + 1, c2, i2, A, total + stats["iters"],
                    stats["final_imbalance"])

        init = (jnp.int32(0), centers, infl, prev_labels,
                jnp.int32(0), jnp.asarray(jnp.inf, cfg.dtype))
        attempt, centers, infl, A, total_iters, imb = jax.lax.while_loop(
            retry_cond, retry_body, init)
        frac = metrics.migration_fraction(prev_labels, A, w_t)
        rec = {"iters": total_iters,       # cumulative, like the host path
               "imbalance": imb,
               "migration_volume": metrics.migration_volume(
                   prev_labels, A, w_t),
               "migration_fraction": frac,
               "retained_fraction": 1.0 - frac,
               "balance_retries": attempt - 1}
        return (centers, infl, A), rec

    ts = jnp.arange(1, steps + 1, dtype=cfg.dtype)
    return jax.lax.scan(step, (jnp.asarray(centers0, cfg.dtype),
                               jnp.asarray(influence0, cfg.dtype),
                               jnp.asarray(labels0, jnp.int32)), ts)
