"""Partition quality metrics (paper Section 2) + migration metrics for
dynamic repartitioning (DESIGN.md §8).

* edge cut          — #edges with endpoints in different blocks
* comm volume       — per block V_i: sum over v in V_i of the number of
                      *other* blocks containing a neighbor of v; we report
                      max and total over blocks (maxCommVol / sum CommVol)
* boundary nodes    — #vertices with at least one neighbor in another
                      block (the halo senders; comm volume counts copies)
* imbalance         — max block weight / (total/k) - 1 (same target for
                      unit and weighted inputs, matching the solvers)
* diameter          — per-block graph diameter lower bound via a few rounds
                      of double-sweep BFS (iFUB-style, paper §5.2.4)
* migration volume / fraction / retained fraction
                    — weight that changes blocks between two consecutive
                      partitions of the same point set (the cost a dynamic
                      repartitioner minimizes)

Graph metrics operate on CSR numpy graphs (see meshes.Mesh). The migration
metrics are *in-graph*: they dispatch to jax.numpy whenever any input is a
jax array (so they compose with jit / shard_map in the sharded path and in
``core.timeseries.simulate_loadbalance_scan``) and to numpy (exact float64)
on host arrays.
"""
from __future__ import annotations

import types

import numpy as np


def _array_ns(*arrays):
    """numpy for host arrays, jax.numpy when any input is a jax array or
    tracer — keeps the migration metrics exact on the host AND traceable
    in-graph with one implementation."""
    import jax
    if any(isinstance(a, jax.Array) for a in arrays):
        import jax.numpy as jnp
        return jnp
    return np


def imbalance(part: np.ndarray, k: int, weights: np.ndarray | None = None) -> float:
    """``max block weight / (total weight / k) - 1`` (paper §2).

    The unit-weight and weighted branches use the same ``total/k`` target
    (no ceil), so ``imbalance(part, k)`` equals
    ``imbalance(part, k, np.ones(n))`` exactly and both match the balance
    bar the solvers optimize against."""
    if weights is None:
        sizes = np.bincount(part, minlength=k).astype(np.float64)
        target = part.shape[0] / k
    else:
        sizes = np.bincount(part, weights=weights, minlength=k)
        target = weights.sum() / k
    return float(sizes.max() / target - 1.0)


def block_sizes(part: np.ndarray, k: int, weights: np.ndarray | None = None) -> np.ndarray:
    if weights is None:
        return np.bincount(part, minlength=k).astype(np.float64)
    return np.bincount(part, weights=weights, minlength=k)


def migration_volume(prev: np.ndarray, new: np.ndarray,
                     weights: np.ndarray | None = None):
    """Total weight that changed blocks between two partitions.

    ``sum_{v: prev(v) != new(v)} w(v)`` — the amount of simulation data a
    dynamic load balancer would have to move. Unit weights when ``weights``
    is None.

    Args:
        prev: [n] previous block ids.
        new:  [n] new block ids (same point order).
        weights: [n] nonneg node weights, or None.

    Returns:
        Scalar (float64 numpy scalar on host inputs, a traced jax scalar
        in-graph).
    """
    xp = _array_ns(prev, new, weights)
    moved = xp.asarray(prev) != xp.asarray(new)
    if weights is None:
        return xp.sum(moved.astype(xp.float32 if xp is not np
                                   else np.float64))
    return xp.sum(xp.where(moved, xp.asarray(weights), 0.0))


def migration_fraction(prev: np.ndarray, new: np.ndarray,
                       weights: np.ndarray | None = None):
    """``migration_volume / total_weight`` in [0, 1] — the fraction of the
    workload that moves. Args/Returns as ``migration_volume``."""
    xp = _array_ns(prev, new, weights)
    total = (xp.asarray(prev).shape[0] if weights is None
             else xp.sum(xp.asarray(weights)))
    return migration_volume(prev, new, weights) / xp.maximum(total, 1e-12)


def retained_fraction(prev: np.ndarray, new: np.ndarray,
                      weights: np.ndarray | None = None):
    """``1 - migration_fraction``: the fraction of weight that stays in
    its block across a repartition step. Args/Returns as
    ``migration_volume``."""
    return 1.0 - migration_fraction(prev, new, weights)


def batch_imbalance(labels, k: int, weights):
    """Per-slot imbalance on a padded slot batch (the serving layer's
    metric): ``max_b W_b / (W/k) - 1`` for every slot independently.

    Padded entries carry weight 0 (the engine-wide padding discipline:
    replicated real points, zero weight) so they drop out of both the
    block weights and the per-slot total exactly.

    Args:
        labels:  [S, cap] block ids in [0, k) (padding rows may repeat
            real labels — their zero weight silences them).
        k:       number of blocks (shared by every slot in the bucket).
        weights: [S, cap] nonneg node weights, 0 on padded entries.

    Returns:
        [S] per-slot imbalance (numpy on host inputs, traced in-graph).
    """
    xp = _array_ns(labels, weights)
    if xp is np:
        lab = np.asarray(labels)
        w = np.asarray(weights, np.float64)
        out = np.empty(lab.shape[0])
        for s in range(lab.shape[0]):
            sizes = np.bincount(lab[s], weights=w[s], minlength=k)
            out[s] = sizes.max() / max(w[s].sum() / k, 1e-12) - 1.0
        return out
    import jax
    import jax.numpy as jnp

    def one(lab, w):
        sizes = jnp.zeros(k, w.dtype).at[lab].add(w)
        target = jnp.sum(w) / k
        return jnp.max(sizes) / jnp.maximum(target, 1e-12) - 1.0

    return jax.vmap(one)(xp.asarray(labels), xp.asarray(weights))


def batch_migration_fraction(prev, new, weights):
    """Per-slot migration fraction on a padded slot batch: the fraction
    of each slot's weight that changed blocks between ``prev`` and
    ``new``. Padded entries (weight 0) drop out exactly.

    Args:
        prev:    [S, cap] previous block ids.
        new:     [S, cap] new block ids (same padded point order).
        weights: [S, cap] nonneg node weights, 0 on padded entries.

    Returns:
        [S] per-slot fraction in [0, 1] (numpy on host, traced in-graph).
    """
    xp = _array_ns(prev, new, weights)
    prev, new = xp.asarray(prev), xp.asarray(new)
    w = xp.asarray(weights)
    moved = xp.sum(xp.where(prev != new, w, 0.0), axis=1)
    return moved / xp.maximum(xp.sum(w, axis=1), 1e-12)


# fixed-point scale for quantize_weights: the total quantized weight fits
# int32 (jax x32 mode) with headroom, so integer sums of quantized
# weights are EXACT on host numpy and under any psum order alike — the
# same "integer counts commute" discipline the sharded metrics rely on
WEIGHT_QUANT_TOTAL = (1 << 30) - 1


def quantize_weights(weights: np.ndarray | None, n: int) -> np.ndarray:
    """[n] int64 fixed-point node weights for exact integer balance
    arithmetic (the refinement budget protocol, DESIGN.md §11).

    Unit weights (``weights is None``) map to exactly 1 per node — no
    quantization error at all. Float weights are scaled so the total is
    ~``WEIGHT_QUANT_TOTAL`` (fits int32) and rounded to nearest; each
    node's error is <= 0.5 units, which the budget margin in
    ``partition.refine`` absorbs.

    Args:
        weights: [n] nonneg float node weights, or None.
        n: point count (fixes the unit-weight output length).

    Returns:
        [n] int64 quantized weights, every entry >= 0.

    Raises:
        ValueError: negative weights or an all-zero total.
    """
    if weights is None:
        return np.ones(n, np.int64)
    w = np.asarray(weights, np.float64)
    if w.shape != (n,):
        raise ValueError(f"weights must be [{n}], got {w.shape}")
    if np.any(w < 0):
        raise ValueError("weights must be nonnegative")
    total = float(w.sum())
    if total <= 0:
        raise ValueError("total weight must be positive")
    return np.round(w * (WEIGHT_QUANT_TOTAL / total)).astype(np.int64)


def edge_cut(part: np.ndarray, indptr: np.ndarray, indices: np.ndarray) -> int:
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    return int((part[src] != part[indices]).sum() // 2)


def _distinct_remote_pairs(part: np.ndarray, indptr: np.ndarray,
                           indices: np.ndarray) -> tuple[np.ndarray, int]:
    """Distinct (vertex, remote block) adjacency pairs, vectorized.

    Unique-per-row formulation: expand the CSR rows to a directed edge
    list, keep the cut edges, lexsort by (vertex, neighbor block) and drop
    adjacent duplicates — no per-node Python loop and no ``v * k + block``
    key that could overflow. Returns ``(v, n_pairs)`` where ``v`` holds
    the source vertex of each distinct pair (``comm_volume`` bins them by
    block; ``boundary_nodes`` only needs which vertices appear)."""
    n = len(indptr) - 1
    src = np.repeat(np.arange(n), np.diff(indptr))
    nb_block = part[indices]
    remote = nb_block != part[src]
    v, b = src[remote], nb_block[remote]
    order = np.lexsort((b, v))
    v, b = v[order], b[order]
    first = np.ones(v.shape[0], dtype=bool)
    first[1:] = (v[1:] != v[:-1]) | (b[1:] != b[:-1])
    return v[first], int(first.sum())


def comm_volume(part: np.ndarray, indptr: np.ndarray, indices: np.ndarray,
                k: int) -> tuple[int, int, np.ndarray]:
    """Returns (max_comm, total_comm, per_block_comm).

    comm(V_i) = sum_{v in V_i} #{distinct blocks j != part(v) adjacent to v}.
    """
    v, _ = _distinct_remote_pairs(part, indptr, indices)
    per_block = np.bincount(part[v], minlength=k)
    return int(per_block.max(initial=0)), int(per_block.sum()), per_block


def boundary_nodes(part: np.ndarray, indptr: np.ndarray, indices: np.ndarray,
                   k: int) -> tuple[int, np.ndarray]:
    """Returns (total, per_block) boundary-vertex counts.

    A vertex is a boundary node when at least one neighbor lives in a
    different block — exactly the vertices whose data a parallel solver
    ships every halo exchange (the comm volume counts how many *copies*
    go out; this counts the senders)."""
    v, _ = _distinct_remote_pairs(part, indptr, indices)
    boundary = np.unique(v)
    per_block = np.bincount(part[boundary], minlength=k)
    return int(per_block.sum()), per_block


def _bfs_ecc(indptr: np.ndarray, indices: np.ndarray, sub: np.ndarray,
             start: int) -> tuple[int, int, int]:
    """BFS inside vertex subset ``sub`` (bool mask). Returns
    (ecc, farthest, n_reached) — the reach count doubles as the
    connectivity check, so no separate sweep is needed."""
    n = len(indptr) - 1
    dist = np.full(n, -1, dtype=np.int64)
    dist[start] = 0
    frontier = np.array([start], dtype=np.int64)
    d = 0
    last = start
    reached = 1
    while frontier.size:
        nxt = []
        for u in frontier:
            nbrs = indices[indptr[u]:indptr[u + 1]]
            nbrs = nbrs[sub[nbrs] & (dist[nbrs] < 0)]
            dist[nbrs] = d + 1
            nxt.append(nbrs)
        frontier = np.concatenate(nxt) if nxt else np.zeros(0, np.int64)
        if frontier.size:
            d += 1
            last = int(frontier[-1])
            reached += frontier.size
    return d, last, reached


def block_diameters(part: np.ndarray, indptr: np.ndarray, indices: np.ndarray,
                    k: int, rounds: int = 3) -> np.ndarray:
    """Double-sweep BFS lower bound on each block's diameter.

    Disconnected blocks get +inf (paper aggregates with harmonic mean to
    absorb these). Exactly ``rounds`` BFS sweeps per block: the first
    sweep (from the block's first member) supplies the eccentricity, the
    double-sweep restart vertex AND the reach count for the connectivity
    verdict in one O(V+E) pass."""
    n = len(indptr) - 1
    diams = np.zeros(k, dtype=np.float64)
    for b in range(k):
        members = np.where(part == b)[0]
        if members.size == 0:
            continue
        sub = np.zeros(n, dtype=bool)
        sub[members] = True
        start = int(members[0])
        best, cur, reached = _bfs_ecc(indptr, indices, sub, start)
        for _ in range(rounds - 1):
            ecc, far, _ = _bfs_ecc(indptr, indices, sub, cur)
            best = max(best, ecc)
            cur = far
        diams[b] = best if reached == members.size else np.inf
    return diams


def harmonic_mean(x: np.ndarray) -> float:
    x = np.asarray(x, dtype=np.float64)
    x = x[x > 0]
    if x.size == 0:
        return 0.0
    return float(x.size / np.sum(1.0 / x))


def evaluate_problem(problem, labels: np.ndarray,
                     with_diameter: bool = False) -> dict:
    """Metric set for a ``partition.PartitionProblem`` (duck-typed: needs
    .k/.weights and optionally .indptr/.indices). Graph metrics are
    included only when the problem carries a CSR adjacency — geometric
    problems without a graph still get balance metrics."""
    labels = np.asarray(labels)
    out = {
        "imbalance": imbalance(labels, problem.k, problem.weights),
        "n_blocks_used": int(len(np.unique(labels))),
    }
    if getattr(problem, "indptr", None) is not None:
        # one O(m log m) distinct-pair pass feeds both volume metrics
        v, _ = _distinct_remote_pairs(labels, problem.indptr,
                                      problem.indices)
        per_block = np.bincount(labels[v], minlength=problem.k)
        out["cut"] = edge_cut(labels, problem.indptr, problem.indices)
        out["maxCommVol"] = int(per_block.max(initial=0))
        out["totalCommVol"] = int(per_block.sum())
        out["boundaryNodes"] = int(np.unique(v).size)
        if with_diameter:
            d = block_diameters(labels, problem.indptr, problem.indices,
                                problem.k)
            out["diameter_harmonic_mean"] = harmonic_mean(d[np.isfinite(d)])
            out["n_disconnected"] = int(np.sum(~np.isfinite(d)))
    return out


def evaluate_partition(mesh, part: np.ndarray, k: int,
                       with_diameter: bool = False) -> dict:
    """Metric set for a ``meshes.Mesh`` + label array (legacy signature;
    delegates to ``evaluate_problem`` — a Mesh duck-types everything but
    ``k``)."""
    shim = types.SimpleNamespace(k=k, weights=mesh.weights,
                                 indptr=mesh.indptr, indices=mesh.indices)
    return evaluate_problem(shim, part, with_diameter=with_diameter)
