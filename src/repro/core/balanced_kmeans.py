"""Weighted balanced k-means (paper Section 4) — fully jittable core.

Faithful to Algorithms 1 + 2 with the following TPU/JAX adaptations
(recorded in DESIGN.md §4):

* Effective distances are computed in *squared* space:
  minimizing dist/influence is equivalent to minimizing sqdist/influence².
  Bounds (ub/lb) are kept in true effective-distance space (a sqrt of the
  per-point best/second values only, never of the full n×k matrix).
* The paper's per-point Hamerly skip (`if ub < lb`) is a scalar-CPU
  optimization; the vectorized path uses it for assignment semantics and to
  report the skip statistic, while the Pallas kernel path uses *tile-level*
  pruning for real savings (kernels/assign_kernel.py).
* Two sign typos in the paper are corrected (both confirmed against
  Hamerly 2010 and the paper's own derivations):
    - Eq. (1): ``influence /= gamma^(1/d)`` must be ``influence *=
      gamma^(1/d)`` so that oversized clusters (gamma < 1) *lose* influence
      and the derived new size equals gamma * size_old = target.
    - Eqs. (4)/(5): bound *relaxation* must widen the bounds:
      ``ub += delta/influence`` and ``lb -= max_c delta(c)/influence(c)``.
* Sampled warm-up (paper §4.5 "random initialization") is implemented with
  a traced sample length and weight masking so shapes stay static.
* The hot loop is a **fused assign+reduce**: each balance iteration's
  backend sweep also returns the per-cluster weighted moments (sizes,
  coordinate sums, radius sums), so the n×d point array is streamed
  exactly once per iteration — the movement phase's former three
  ``segment_sum`` passes collapsed into the assignment call
  (``assign_reduce``; DESIGN.md §4b). Backends without moment support
  fall back to a separate ``segment_moments`` sweep with the identical
  reduction structure, keeping fused and unfused results bit-for-bit
  equal on the ``jnp`` backend.

The same code runs single-device or under ``shard_map`` (pass ``axis_name``)
— cluster centers and influence are replicated, points are sharded, and the
only communication is global vector sums (paper §4.1), exactly the psums
emitted here. The multi-device driver is ``repro.partition.distributed``
(``partition(problem, method="geographer", devices=P)``), which pads each
shard to a static per-device shape and plumbs ``axis_name`` through this
module end-to-end; DESIGN.md §3b documents the layout.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class BKMConfig:
    k: int
    epsilon: float = 0.03          # max imbalance (paper uses 0.03/0.05)
    max_iter: int = 30             # center-movement iterations (Alg. 2)
    max_balance_iter: int = 12     # balance iterations per movement (Alg. 1)
    influence_clip: float = 0.05   # max 5% influence change per step (paper)
    d_eff: int | None = None       # dimension in Eq. (1); default spatial d
    erosion: bool = True           # Eqs. (2)-(3)
    delta_tol: float = 5e-4        # movement threshold x bbox diagonal
    warmup: bool = True            # sampled warm-up rounds
    warmup_start: int = 100
    backend: str = "auto"          # kernels.ops assign backend
    use_kernel: bool = False       # deprecated: alias for backend="pallas"
    fused: bool | None = None      # fused assign+reduce; None = auto
    block_p: int = 1024            # kernel point-tile
    block_c: int = 128             # kernel center-tile
    assign_chunk: int | None = None  # jnp path point chunk; None = adaptive
    assign_precision: str = "f32"  # distance matmul: "f32" | "bf16"
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.use_kernel:
            warnings.warn(
                "BKMConfig.use_kernel is deprecated; pass "
                "backend='pallas' instead", DeprecationWarning, stacklevel=3)
        if self.max_balance_iter < 1:
            # the movement moments ride out of the last balance iteration,
            # so the balance loop must run at least once
            raise ValueError("max_balance_iter must be >= 1")
        from repro.kernels.assign_kernel import PRECISIONS
        if self.assign_precision not in PRECISIONS:
            raise ValueError(
                f"assign_precision must be one of {PRECISIONS}, got "
                f"{self.assign_precision!r}")

    @property
    def assign_backend(self) -> str:
        """Effective backend name (folds the deprecated use_kernel flag)."""
        return "pallas" if self.use_kernel else self.backend


def _reduce(x, axis_name, op="sum"):
    # axis_name may be a single mesh axis or a tuple of axes (the 2-D
    # hierarchical mesh reduces over ("coarse", "refine") — jax sums over
    # the flattened product, bit-identical to the 1-D mesh of the same
    # device order)
    if axis_name is None:
        return x
    if op == "sum":
        return jax.lax.psum(x, axis_name)
    if op == "max":
        return jax.lax.pmax(x, axis_name)
    if op == "min":
        return jax.lax.pmin(x, axis_name)
    raise ValueError(op)


def assign_effective(points, centers, influence, chunk=None, backend="auto",
                     block_p=1024, block_c=128, precision="f32"):
    """Returns (assignment [n] int32, best_eff [n], second_eff [n]) where
    best/second are *true* effective distances dist/influence.

    ``backend`` selects the squared-distance argmin implementation from the
    ``kernels.ops`` registry ("jnp", "pallas", "triton", or "auto")."""
    from repro.kernels.ops import assign_backend
    fn = assign_backend(backend)
    idx, b, s = fn(points, centers, influence, chunk=chunk,
                   block_p=block_p, block_c=block_c, precision=precision)
    # second can be +inf when k == 1; keep bounds finite
    return idx, jnp.sqrt(b), jnp.sqrt(jnp.where(jnp.isfinite(s), s, b))


def assign_reduce(points, weights, centers, influence, cfg):
    """One hot-loop sweep: assignment + per-cluster weighted moments.

    When the resolved backend supports the fused contract (and
    ``cfg.fused`` is not False) the moments come out of the *same* pass
    over the points as the assignment; otherwise the backend call is
    followed by a ``kernels.ops.segment_moments`` sweep that shares the
    fused path's reduction structure, so both modes return bit-identical
    results for the ``jnp`` backend.

    Returns ``(idx, best_eff, second_eff, csum, cw, rad2raw)`` with
    best/second as *true* effective distances (sqrt'd, like
    ``assign_effective``) and the moments as LOCAL (not psum'd) sums:
    ``csum[c] = sum w*p``, ``cw[c] = sum w``, ``rad2raw[c] = sum
    w*best_eff_sq`` (multiply by ``influence[c]^2`` for true distances).
    """
    from repro.kernels.ops import (assign_backend, backend_supports_moments,
                                   segment_moments)
    fused = cfg.fused
    if fused is None:
        fused = backend_supports_moments(cfg.assign_backend)
    elif fused and not backend_supports_moments(cfg.assign_backend):
        raise ValueError(
            f"fused=True but assign backend {cfg.assign_backend!r} does "
            "not support return_moments; register it with "
            "supports_moments=True or pass fused=False/None")
    fn = assign_backend(cfg.assign_backend)
    if fused:
        idx, b, s, csum, cw, rad2 = fn(
            points, centers, influence, chunk=cfg.assign_chunk,
            block_p=cfg.block_p, block_c=cfg.block_c,
            weights=weights, return_moments=True,
            precision=cfg.assign_precision)
    else:
        idx, b, s = fn(points, centers, influence, chunk=cfg.assign_chunk,
                       block_p=cfg.block_p, block_c=cfg.block_c,
                       precision=cfg.assign_precision)
        csum, cw, rad2 = segment_moments(points, weights, idx, b, cfg.k,
                                         chunk=cfg.assign_chunk)
    return (idx, jnp.sqrt(b), jnp.sqrt(jnp.where(jnp.isfinite(s), s, b)),
            csum, cw, rad2)


def adapt_influence(influence, sizes, target, d_eff, clip):
    """Paper Eq. (1), sign-corrected; oversized clusters lose influence."""
    gamma = target / jnp.maximum(sizes, 1e-12)
    factor = jnp.clip(gamma ** (1.0 / d_eff), 1.0 - clip, 1.0 + clip)
    return influence * factor, factor


def erode_influence(influence, delta, beta):
    """Paper Eqs. (2)-(3): sigmoid regression of influence toward 1."""
    alpha = 2.0 / (1.0 + jnp.exp(-delta / jnp.maximum(beta, 1e-12))) - 1.0
    return jnp.exp((1.0 - alpha) * jnp.log(jnp.maximum(influence, 1e-12)))


def assign_and_balance(points, w_eff, centers, influence, A_old, ub, lb, cfg,
                       target_weight, axis_name=None, valid=None,
                       n_valid=None):
    """Algorithm 1. Returns (A, influence, ub, lb, sizes, csum, rad2sum,
    stats).

    ``w_eff`` already includes the warm-up sample mask. ``target_weight`` is
    the global per-cluster target (psum'd by the caller). ``valid`` marks
    real (non-padded) points and ``n_valid`` their global count — only for
    the skip statistic, so padding and shard count don't distort it.

    Every balance iteration is ONE fused assign+reduce sweep
    (``assign_reduce``): the per-cluster sizes come out of the same pass
    as the assignment, and the movement-phase moments (``csum`` weighted
    coordinate sums, ``rad2sum`` weighted true-distance² sums — both
    LOCAL, the caller psums them) ride out of the final iteration for
    free instead of costing three extra sweeps over the points. The
    Hamerly ``skip`` stays a statistic + bound-retention device: sound
    bounds make the argmin *unique* whenever ``ub < lb`` fires (strict
    inequality against every other center), so the freshly computed
    ``idx`` already equals the retained assignment and the fused moments
    over ``idx`` are exactly the moments of the returned labels.
    """
    d_eff = cfg.d_eff or points.shape[1]
    k, d = cfg.k, points.shape[1]

    def body(carry):
        i, A, ub_c, lb_c, infl, _, _, _, _, skips = carry
        idx, best, second, csum, cw, rad2raw = assign_reduce(
            points, w_eff, centers, infl, cfg)
        skip = ub_c < lb_c                       # Hamerly test (sound bounds)
        skip_stat = skip if valid is None else (skip & valid)
        A_new = idx
        ub_n = jnp.where(skip, ub_c, best)
        lb_n = jnp.where(skip, lb_c, second)
        sizes = _reduce(cw, axis_name)           # == segment_sum(w_eff, A)
        # true-distance² radius numerator: eff² scales back by infl[A]²,
        # which is invariant under the later influence rescaling
        rad2sum = rad2raw * (infl * infl)
        imb = jnp.max(sizes) / target_weight - 1.0
        done = imb <= cfg.epsilon
        infl_new, factor = adapt_influence(infl, sizes, target_weight,
                                           d_eff, cfg.influence_clip)
        infl_new = jnp.where(done, infl, infl_new)
        # Bound relaxation for the influence change: effdist scales exactly
        # by I_old/I_new per cluster (movement delta is zero inside Alg. 1).
        ratio = infl / infl_new                  # = 1/factor
        ub_n = ub_n * jnp.where(done, 1.0, ratio[A_new])
        lb_n = lb_n * jnp.where(done, 1.0, jnp.min(ratio))
        skips = skips + jnp.sum(skip_stat.astype(jnp.float32))
        return (i + 1, A_new, ub_n, lb_n, infl_new, sizes, csum, rad2sum,
                done, skips)

    def cond(carry):
        i, *_, done, _ = carry
        return (i < cfg.max_balance_iter) & (~done)

    init = (jnp.int32(0), A_old, ub, lb, influence,
            jnp.zeros(k, cfg.dtype), jnp.zeros((k, d), cfg.dtype),
            jnp.zeros(k, cfg.dtype), jnp.bool_(False), jnp.float32(0.0))
    (i, A, ub, lb, infl, sizes, csum, rad2sum, done,
     skips) = jax.lax.while_loop(cond, body, init)
    # under shard_map, report the *global* skip rate (psum'd numerator over
    # the true global point count) so the statistic is invariant to both
    # the shard count and the per-shard padding
    skips = _reduce(skips, axis_name)
    if n_valid is None:
        n_valid = points.shape[0] * (1 if axis_name is None
                                     else jax.lax.psum(1, axis_name))
    stats = {"balance_iters": i, "balanced": done,
             "skip_fraction": skips / (jnp.maximum(i, 1) * n_valid)}
    return A, infl, ub, lb, sizes, csum, rad2sum, stats


def balanced_kmeans(points, cfg: BKMConfig, weights=None, centers0=None,
                    axis_name=None, n_global=None, target_weight=None,
                    influence0=None, warm_start=False,
                    prev_assignment=None):
    """Algorithm 2 (minus the SFC sort, done by the caller/partitioner).

    ``points`` are the (local shard of) points, *already permuted randomly*
    if warm-up is enabled. ``centers0`` must be identical on all shards.
    ``axis_name`` is a mesh axis name or a tuple of axis names (the 2-D
    hierarchical mesh passes ``("coarse", "refine")``; every reduction
    then psums over the flattened axis product).
    ``target_weight`` overrides the per-cluster balance target (default
    total_weight / k); the hierarchical engine passes the *global* target
    here so every refinement subproblem balances against the same bar and
    the composed partition keeps global imbalance <= epsilon.

    ``warm_start=True`` resumes from a previous run's ``(centers0,
    influence0)`` state (dynamic repartitioning, DESIGN.md §8): the sampled
    warm-up is skipped, and a *convergence pre-pass* assigns every point
    under the previous state, seeds the Hamerly bounds with the exact
    best/second distances, and measures the candidate center movement
    ``delta0``. When the previous state is still a fixed point (``delta0``
    below the movement threshold) the movement loop never runs
    (``stats["iters"] == 0``) and the final balance pass re-emits the
    previous assignment unchanged — an unchanged problem migrates zero
    weight. ``influence0`` (default all-ones) must be replicated across
    shards exactly like ``centers0``.

    ``prev_assignment`` (warm only, [n] int32 in the same point order)
    enables *no-op detection*: when the pre-pass assignment equals the
    previous assignment AND the previous partition is still balanced under
    the new weights, the solve is skipped outright — labels, cut and comm
    volume are bit-identical to the previous step, so re-optimizing could
    only churn data for marginal objective gain. This is what makes
    ``repartition`` on an unchanged problem a strict fixed point even when
    the underlying k-means never reached its (rarely attainable) movement
    threshold.

    Returns (assignment, centers, influence, stats).
    """
    n, d = points.shape
    k = cfg.k
    dtype = cfg.dtype
    points = points.astype(dtype)
    w = jnp.ones(n, dtype) if weights is None else weights.astype(dtype)
    if centers0 is None:
        centers0 = points[jnp.linspace(0, n - 1, k).astype(jnp.int32)]
    if n_global is None:
        n_global = n * (1 if axis_name is None else
                        jax.lax.psum(1, axis_name))
    valid = w > 0                # padded shard slots carry weight zero

    total_w = jnp.maximum(_reduce(jnp.sum(w), axis_name), 1e-12)
    base_target = (total_w / k if target_weight is None
                   else jnp.asarray(target_weight, dtype))
    lo = _reduce(jnp.min(points, axis=0), axis_name, "min")
    hi = _reduce(jnp.max(points, axis=0), axis_name, "max")
    diag = jnp.sqrt(jnp.sum((hi - lo) ** 2))
    delta_threshold = cfg.delta_tol * diag

    if cfg.warmup and not warm_start:
        # the warm-up round count is a Python-level loop bound, so the
        # global point count must be static here. jax versions that
        # constant-fold psum-of-a-constant make n_global concrete even
        # under shard_map; where that folding is absent (or a caller
        # passes a traced value), fail with an actionable error instead
        # of an opaque tracer-conversion crash.
        try:
            ng = int(n_global)
        except (jax.errors.TracerIntegerConversionError,
                jax.errors.ConcretizationTypeError) as e:
            raise ValueError(
                "balanced_kmeans: warmup=True needs a *static* global "
                "point count to derive the number of warm-up rounds. "
                "Under shard_map/axis_name pass n_global=<int global n> "
                "(the distributed driver does), or disable warmup.") from e
        n_warm = int(np.ceil(np.log2(max(ng / cfg.warmup_start, 1))))
    else:
        n_warm = 0

    def sample_mask(it):
        # warm starts never sample: the movement loop must see the full
        # weight field even if the caller's cfg still has warmup=True
        if not cfg.warmup or warm_start:
            return jnp.ones(n, dtype)
        # sample size doubles per round; local prefix of the permutation
        frac = jnp.minimum((cfg.warmup_start * 2.0 ** it) / n_global, 1.0)
        s_local = jnp.ceil(frac * n).astype(jnp.int32)
        # explicit int32: the per-shard slot index is int32 by kernel
        # contract (the sharded front door enforces ceil(n/P) <= 2**31-1
        # via partition.distributed.check_index_capacity — global
        # position arithmetic stays int64 on the host side)
        return (jnp.arange(n, dtype=jnp.int32) < s_local).astype(dtype)

    hist_len = cfg.max_iter

    def body(carry):
        (it, centers, infl, A, ub, lb, _, hist) = carry
        mask = sample_mask(it)
        w_eff = w * mask
        # scale the target by the sampled-weight fraction so warm-up rounds
        # balance the sample against a proportionally reduced bar
        w_round = jnp.maximum(_reduce(jnp.sum(w_eff), axis_name), 1e-12)
        target = base_target * (w_round / total_w)
        A, infl, ub, lb, sizes, csum_l, rad2_l, st = assign_and_balance(
            points, w_eff, centers, infl, A, ub, lb, cfg, target, axis_name,
            valid=valid, n_valid=n_global)
        # --- movement phase (Alg. 2 lines 12-13): the moments rode out of
        # the balance loop's final assign+reduce sweep; only the paper's
        # global vector sums remain ([k, d] + [k] — `sizes` is already the
        # psum of the weighted counts)
        csum = _reduce(csum_l, axis_name)
        cw = sizes
        new_centers = jnp.where(cw[:, None] > 0, csum / jnp.maximum(cw, 1e-12)[:, None],
                                centers)
        delta = jnp.sqrt(jnp.sum((new_centers - centers) ** 2, axis=1))
        # --- influence erosion (Eqs. 2-3); beta = avg cluster diameter
        # proxy from the weighted true-distance² sums (exact best distances
        # from the final sweep, not the retained Hamerly bounds)
        rad2 = _reduce(rad2_l, axis_name) / jnp.maximum(cw, 1e-12)
        beta = 2.0 * jnp.mean(jnp.sqrt(jnp.maximum(rad2, 0.0)))
        infl_new = erode_influence(infl, delta, beta) if cfg.erosion else infl
        # --- bound relaxation for movement + erosion (Eqs. 4-5, corrected)
        ratio = infl / infl_new
        ub = ub * ratio[A] + delta[A] / infl_new[A]
        lb = jnp.maximum(lb * jnp.min(ratio) - jnp.max(delta / infl_new), 0.0)
        max_delta = jnp.max(delta)
        updates = {"skip_fraction": st["skip_fraction"],
                   "balance_iters": st["balance_iters"].astype(jnp.float32),
                   "max_delta": max_delta,
                   "imbalance": jnp.max(sizes) / target - 1.0}
        hist = {name: hist[name].at[it].set(updates[name]) for name in hist}
        return (it + 1, new_centers, infl_new, A, ub, lb, max_delta, hist)

    def cond(carry):
        it = carry[0]
        max_delta = carry[6]
        in_warm = it < n_warm
        keep_going = in_warm | (max_delta > delta_threshold)
        if warm_start:
            # never declare convergence while the last balance phase ended
            # above epsilon — each extra movement iteration buys another
            # full influence-adaptation budget (at it == 0 the pre-pass
            # already folded balance into delta0)
            last_imb = carry[7]["imbalance"][jnp.maximum(it - 1, 0)]
            keep_going = keep_going | ((it > 0) & (last_imb > cfg.epsilon))
        return (it < cfg.max_iter) & keep_going

    hist0 = {name: jnp.zeros(hist_len, jnp.float32)
             for name in ["skip_fraction", "balance_iters", "max_delta", "imbalance"]}
    centers0 = centers0.astype(dtype)
    infl0 = (jnp.ones(k, dtype) if influence0 is None
             else jnp.asarray(influence0, dtype))
    if warm_start:
        # Convergence pre-pass: assignment + exact Hamerly bounds under the
        # previous (centers, influence), and the movement the first
        # iteration WOULD make. If that movement is already below the
        # threshold, the while_loop body never runs and the final balance
        # pass re-emits the previous assignment bit-for-bit.
        A0, best0, second0, csum_l, cw_l, _ = assign_reduce(
            points, w, centers0, infl0, cfg)
        csum0 = _reduce(csum_l, axis_name)
        cw0 = _reduce(cw_l, axis_name)
        cand0 = jnp.where(cw0[:, None] > 0,
                          csum0 / jnp.maximum(cw0, 1e-12)[:, None], centers0)
        delta0 = jnp.max(jnp.sqrt(jnp.sum((cand0 - centers0) ** 2, axis=1)))
        # an imbalanced previous state is never "converged", no matter how
        # still its centers: force the movement loop to run so balance is
        # restored by repeated influence adaptation, not only by the single
        # final pass
        imb0 = jnp.max(cw0) / base_target - 1.0
        balanced0 = imb0 <= cfg.epsilon
        delta0 = jnp.where(balanced0, delta0, jnp.inf)
        if prev_assignment is not None:
            # no-op detection: unchanged assignment + still balanced means
            # the previous partition is re-emitted verbatim (zero
            # migration), even if the k-means objective could still improve
            mismatches = _reduce(
                jnp.sum((A0 != prev_assignment.astype(jnp.int32))
                        .astype(jnp.int32)), axis_name)
            delta0 = jnp.where((mismatches == 0) & balanced0, 0.0, delta0)
        init = (jnp.int32(0), centers0, infl0, A0, best0, second0,
                delta0.astype(dtype), hist0)
    else:
        init = (jnp.int32(0), centers0, infl0,
                jnp.zeros(n, jnp.int32), jnp.full(n, jnp.inf, dtype),
                jnp.zeros(n, dtype), jnp.array(jnp.inf, dtype), hist0)
    it, centers, infl, A, ub, lb, _, hist = jax.lax.while_loop(cond, body, init)

    # final full assignment + balance pass on ALL points (mask = 1) so the
    # returned assignment is exact and balanced even if warm-up dominated
    target = base_target
    A, infl, ub, lb, sizes, _, _, st = assign_and_balance(
        points, w, centers, infl, A,
        jnp.full(n, jnp.inf, dtype), jnp.zeros(n, dtype), cfg, target,
        axis_name, valid=valid, n_valid=n_global)
    # tile-pruning effectiveness under the final state: fraction of the
    # kernel's (point-tile x center-tile) grid the bbox bound skips
    # (estimated from the converged second-best; ops.tile_prune_fraction).
    # lb after the final pass IS the second-best effective distance
    # (entered with lb=0/ub=inf, so the Hamerly skip never retains stale
    # bounds), squared back to the kernel's effective-sq space.
    from repro.kernels.ops import tile_prune_fraction
    frac = tile_prune_fraction(points, centers, infl, lb * lb,
                               cfg.block_p, cfg.block_c)
    n_shards = 1 if axis_name is None else jax.lax.psum(1, axis_name)
    stats = {"iters": it, "final_sizes": sizes,
             "final_imbalance": jnp.max(sizes) / target - 1.0,
             "final_balance_iters": st["balance_iters"],
             "skip_fraction_final": st["skip_fraction"],
             "tiles_pruned_frac": _reduce(frac, axis_name) / n_shards,
             "history": hist}
    return A, centers, infl, stats


@functools.partial(jax.jit, static_argnames=("cfg", "warm_start"))
def balanced_kmeans_jit(points, cfg: BKMConfig, weights=None, centers0=None,
                        influence0=None, warm_start=False):
    return balanced_kmeans(points, cfg, weights, centers0,
                           influence0=influence0, warm_start=warm_start)
