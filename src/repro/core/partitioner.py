"""Geographer: SFC bootstrap + balanced k-means (paper Algorithm 2).

Two entry points:

* ``geographer_partition`` — single-host orchestration (numpy SFC sort +
  jitted balanced k-means). Used by benchmarks and the quality experiments.
* ``geographer_partition_distributed`` — full SPMD version under
  ``shard_map``: global-bbox psum, in-graph Hilbert keys, sample-sort bucket
  redistribution over ``all_to_all`` (the static-shape analogue of the
  paper's distributed quicksort), strided initial centers from the global
  SFC order, then the replicated-center balanced k-means with psum
  reductions — the paper's exact communication structure.
"""
from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from .balanced_kmeans import BKMConfig, balanced_kmeans
from .sfc import hilbert_index_jnp, sfc_initial_centers


def geographer_partition(points: np.ndarray, k: int,
                         weights: np.ndarray | None = None,
                         cfg: BKMConfig | None = None,
                         seed: int = 0,
                         return_stats: bool = False,
                         return_state: bool = False):
    """Partition ``points`` into k balanced blocks. Returns [n] block ids.

    ``return_stats=True`` returns ``(labels, stats)``; ``return_state=True``
    returns ``(labels, centers, influence, stats)`` — the (centers,
    influence) pair is the warm-start state consumed by
    ``geographer_repartition`` / ``repro.partition.repartition``.

    This remains the raw single-host implementation; prefer the unified
    front door ``repro.partition.partition(problem, method="geographer")``,
    which adds the registry, hierarchical (k1 x k2) mode, and quality
    evaluation on top of it.
    """
    cfg = cfg or BKMConfig(k=k)
    if cfg.k != k:
        cfg = replace(cfg, k=k)
    n = points.shape[0]
    pts64 = np.asarray(points, dtype=np.float64)
    centers0 = sfc_initial_centers(pts64, k, weights)
    # random permutation for the sampled warm-up (paper §4.5)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    pts = jnp.asarray(pts64[perm], dtype=cfg.dtype)
    w = None if weights is None else jnp.asarray(np.asarray(weights)[perm],
                                                 dtype=cfg.dtype)
    A, centers, infl, stats = _run_jit(pts, cfg, w, jnp.asarray(centers0, cfg.dtype))
    out = np.empty(n, dtype=np.int64)
    out[perm] = np.asarray(A)
    if return_state:
        return (out, np.asarray(centers), np.asarray(infl),
                jax.tree.map(np.asarray, stats))
    if return_stats:
        return out, jax.tree.map(np.asarray, stats)
    return out


@functools.partial(jax.jit, static_argnames=("cfg",))
def _run_jit(points, cfg, weights, centers0):
    return balanced_kmeans(points, cfg, weights, centers0)


def geographer_repartition(points: np.ndarray, k: int,
                           centers0: np.ndarray,
                           influence0: np.ndarray | None = None,
                           weights: np.ndarray | None = None,
                           cfg: BKMConfig | None = None,
                           seed: int = 0,
                           prev_labels: np.ndarray | None = None):
    """Warm-started Geographer: balanced k-means resumed from a previous
    partition's ``(centers0, influence0)`` state, skipping the SFC
    bootstrap and the sampled warm-up entirely (DESIGN.md §8).

    Args:
        points:     [n, d] point coordinates (possibly moved since the
                    previous partition).
        k:          number of blocks; must match ``centers0.shape[0]``.
        centers0:   [k, d] centers of the previous partition.
        influence0: [k] influence of the previous partition (None = ones).
        weights:    [n] node weights (possibly re-weighted since the
                    previous partition), or None for unit weights.
        cfg:        BKMConfig; ``warmup`` is forced off (warm starts never
                    sample) and ``k`` is forced to match.
        seed:       permutation seed — pass the SAME seed as the previous
                    run so the sharded ``devices=1`` path stays bit-for-bit
                    identical (both permute with the problem seed).
        prev_labels: [n] previous block ids (original point order). When
                    given, an unchanged-and-still-balanced partition is
                    re-emitted verbatim (no-op detection — zero migration,
                    ``stats["iters"] == 0``).

    Returns:
        (labels [n] int64, centers [k, d], influence [k], stats dict).
        ``stats["iters"]`` is the movement-iteration count — 0 when the
        previous state is still a fixed point of the (unchanged) problem.
    """
    cfg = cfg or BKMConfig(k=k, warmup=False)
    if cfg.k != k or cfg.warmup:
        cfg = replace(cfg, k=k, warmup=False)
    if centers0.shape[0] != k:
        raise ValueError(f"centers0 has {centers0.shape[0]} rows, k={k}")
    n = points.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    pts = jnp.asarray(np.asarray(points, np.float64)[perm], dtype=cfg.dtype)
    w = None if weights is None else jnp.asarray(np.asarray(weights)[perm],
                                                 dtype=cfg.dtype)
    infl0 = (None if influence0 is None
             else jnp.asarray(influence0, cfg.dtype))
    prev = (None if prev_labels is None
            else jnp.asarray(np.asarray(prev_labels)[perm], jnp.int32))
    A, centers, infl, stats = _run_warm_jit(
        pts, cfg, w, jnp.asarray(centers0, cfg.dtype), infl0, prev)
    out = np.empty(n, dtype=np.int64)
    out[perm] = np.asarray(A)
    return (out, np.asarray(centers), np.asarray(infl),
            jax.tree.map(np.asarray, stats))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _run_warm_jit(points, cfg, weights, centers0, influence0,
                  prev_assignment):
    return balanced_kmeans(points, cfg, weights, centers0,
                           influence0=influence0, warm_start=True,
                           prev_assignment=prev_assignment)


# ---------------------------------------------------------------------------
# Distributed (shard_map) version
# ---------------------------------------------------------------------------

def _sfc_redistribute(points, weights, axis_name, n_shards, oversample=32,
                      capacity_factor=2.0):
    """Sample-sort bucket redistribution by Hilbert key (static shapes).

    Each shard ends up with ``cap = capacity_factor * n_local`` slots holding
    points whose keys fall in its splitter range; a validity mask marks real
    points. Returns (points, weights, valid, my_count, my_offset).
    """
    n_local, d = points.shape
    lo = jax.lax.pmin(jnp.min(points, axis=0), axis_name)
    hi = jax.lax.pmax(jnp.max(points, axis=0), axis_name)
    keys = hilbert_index_jnp(points, lo=lo, hi=hi)
    order = jnp.argsort(keys)
    points, weights, keys = points[order], weights[order], keys[order]

    # splitters from a regular sample of each shard's sorted keys
    samp_idx = jnp.linspace(0, n_local - 1, oversample).astype(jnp.int32)
    sample = keys[samp_idx]
    all_samples = jnp.sort(jax.lax.all_gather(sample, axis_name).reshape(-1))
    s_idx = (jnp.arange(1, n_shards) * oversample * n_shards) // n_shards
    splitters = all_samples[s_idx]                       # [n_shards-1]

    dest = jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)
    cap = int(np.ceil(capacity_factor * n_local / n_shards))
    # slot points into [n_shards, cap] send buffers (by arrival order)
    slot_in_dest = jnp.cumsum(jax.nn.one_hot(dest, n_shards, dtype=jnp.int32),
                              axis=0)[jnp.arange(n_local), dest] - 1
    ok = slot_in_dest < cap                              # overflow dropped+counted
    flat = jnp.where(ok, dest * cap + slot_in_dest, n_shards * cap)
    buf_p = jnp.zeros((n_shards * cap + 1, d), points.dtype).at[flat].set(points)[:-1]
    buf_w = jnp.zeros((n_shards * cap + 1,), weights.dtype).at[flat].set(weights)[:-1]
    buf_k = jnp.full((n_shards * cap + 1,), -1, keys.dtype).at[flat].set(keys)[:-1]
    buf_v = jnp.zeros((n_shards * cap + 1,), jnp.bool_).at[flat].set(ok)[:-1]
    n_dropped = jax.lax.psum(jnp.sum(~ok), axis_name)

    def exch(x):
        x = x.reshape(n_shards, cap, *x.shape[1:])
        return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                                  tiled=False).reshape(n_shards * cap, *x.shape[2:])

    rp, rw, rk, rv = exch(buf_p), exch(buf_w), exch(buf_k), exch(buf_v)
    # local sort received points by key, invalid (key -1 -> put last via where)
    rk_sort = jnp.where(rv, rk, jnp.iinfo(jnp.int32).max)
    o = jnp.argsort(rk_sort)
    rp, rw, rv = rp[o], rw[o], rv[o]
    my_count = jnp.sum(rv.astype(jnp.int32))
    counts = jax.lax.all_gather(my_count, axis_name)
    my_offset = jnp.cumsum(counts)[jax.lax.axis_index(axis_name)] - my_count
    return rp, rw, rv, my_count, my_offset, n_dropped


def _strided_centers(points, weights, valid, my_count, my_offset, k, axis_name):
    """Initial centers at global sorted positions i*N/k + N/2k (Alg. 2 l.7)."""
    n_total = jax.lax.psum(my_count, axis_name)
    gpos = (jnp.arange(k) * n_total) // k + n_total // (2 * k)   # [k] global
    local_pos = gpos - my_offset
    mine = (local_pos >= 0) & (local_pos < my_count)
    idx = jnp.clip(local_pos, 0, points.shape[0] - 1)
    contrib = jnp.where(mine[:, None], points[idx], 0.0)
    return jax.lax.psum(contrib, axis_name)


def make_distributed_partitioner(mesh, cfg: BKMConfig, axis_name="data"):
    """Builds a jitted shard_map partitioner over ``mesh[axis_name]``.

    Input: points [N, d], weights [N] sharded on axis 0. Output: block ids
    [N] (aligned with the *redistributed* order), plus diagnostics.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis_name]

    def local_fn(points, weights):
        points = points.reshape(-1, points.shape[-1])
        weights = weights.reshape(-1)
        rp, rw, rv, cnt, off, dropped = _sfc_redistribute(
            points, weights, axis_name, n_shards)
        centers0 = _strided_centers(rp, rw, rv, cnt, off, cfg.k, axis_name)
        w_eff = jnp.where(rv, rw, 0.0)
        A, centers, infl, stats = balanced_kmeans(
            rp, cfg, w_eff, centers0, axis_name=axis_name,
            n_global=points.shape[0] * n_shards)  # static (pre-redistribution)
        A = jnp.where(rv, A, -1)
        return (A[None], rp[None], rv[None], centers, infl,
                stats["final_imbalance"], dropped)

    inner = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis_name, None), P(axis_name)),
        out_specs=(P(axis_name, None), P(axis_name, None, None),
                   P(axis_name, None), P(), P(), P(), P()),
        check_rep=False)

    @jax.jit
    def run(points, weights):
        A, rp, rv, centers, infl, imb, dropped = inner(points, weights)
        s = A.shape
        return (A.reshape(s[0] * s[1]), rp.reshape(-1, points.shape[-1]),
                rv.reshape(-1), centers, infl, imb, dropped)

    return run
