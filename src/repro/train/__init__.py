from .step import (TrainHParams, make_train_step, init_train_state,
                   abstract_train_state, train_state_logical_specs)
from .trainer import Trainer, TrainerConfig
