"""Train-step factory: grad-accumulation scan, remat, z-loss, gradient
compression, and the balanced-k-means MoE router state (paper Eq. 1)
threaded functionally through the step.

The returned ``train_step(state, batch)`` is a pure jittable function;
``state`` is a plain pytree (params / opt / influence / error-feedback), so
it shards, checkpoints and reshards uniformly.

Distributed-optimization tricks (DESIGN.md §7):

* microbatch grad accumulation via ``lax.scan`` (pipelining-friendly; XLA
  overlaps the per-microbatch FSDP all-gathers with compute);
* gradient compression — accumulated grads are cast to bf16 or stochastic-
  rounded int8 *before* the optimizer consumes them, which is the point
  where GSPMD inserts the data-parallel reduction, halving/quartering DP
  collective bytes; an error-feedback buffer keeps the update unbiased;
* optimizer moments in bf16 for the 400B-class configs (model config).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models import moe as MOE
from repro.optim import AdamWConfig, adamw_init, adamw_update, make_schedule


@dataclass(frozen=True)
class TrainHParams:
    microbatches: int = 1
    z_loss: float = 1e-4
    remat: bool = True
    unroll: bool = False                 # python-unroll layers (exact dry-run FLOPs)
    grad_acc_dtype: str = "float32"      # bf16 for the 400B class: grads of
    #                                      bf16 params are natively bf16; an
    #                                      f32 accumulator doubles their HBM
    grad_compress: str = "none"          # none | bf16 | int8
    lr_kind: str = "cosine"
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()


def init_train_state(cfg, key, hp: TrainHParams):
    params = M.init_params(cfg, key)
    opt = adamw_init(params, _adamw_cfg(cfg, hp))
    state = {"params": params, "opt": opt}
    rs = MOE.init_router_state(cfg)
    if rs is not None:
        state["influence"] = rs["influence"]
    if hp.grad_compress in ("bf16", "int8"):
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_train_state(cfg, hp: TrainHParams):
    """ShapeDtypeStruct mirror of init_train_state (dry-run, no alloc)."""
    params = M.abstract_params(cfg)
    mdt = jnp.dtype(_adamw_cfg(cfg, hp).moment_dtype)
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), params)
    state = {"params": params,
             "opt": {"mu": mom, "nu": mom,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}}  # noqa
    n_moe = sum(1 for s in cfg.pattern if s.mlp == "moe")
    if cfg.moe is not None and cfg.moe.router == "balanced_kmeans" and n_moe:
        state["influence"] = jax.ShapeDtypeStruct(
            (cfg.n_repeats, n_moe, cfg.moe.n_experts), jnp.float32)
    if hp.grad_compress in ("bf16", "int8"):
        state["ef"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return state


def train_state_logical_specs(cfg, hp: TrainHParams):
    pspec = M.param_logical_specs(cfg)
    state = {"params": pspec,
             "opt": {"mu": pspec, "nu": pspec, "step": ()}}
    n_moe = sum(1 for s in cfg.pattern if s.mlp == "moe")
    if cfg.moe is not None and cfg.moe.router == "balanced_kmeans" and n_moe:
        state["influence"] = ("repeat", None, None)
    if hp.grad_compress in ("bf16", "int8"):
        state["ef"] = pspec
    return state


def _adamw_cfg(cfg, hp: TrainHParams) -> AdamWConfig:
    return AdamWConfig(
        b1=hp.adamw.b1, b2=hp.adamw.b2, eps=hp.adamw.eps,
        weight_decay=hp.adamw.weight_decay, grad_clip=hp.adamw.grad_clip,
        moment_dtype=cfg.moment_dtype)


def _compress(g, ef, kind, key):
    """Error-feedback compression. Returns (g_compressed_f32, new_ef)."""
    if kind == "none":
        return g, ef
    gf = jax.tree.map(lambda x, e: x.astype(jnp.float32) + e, g, ef)
    if kind == "bf16":
        q = jax.tree.map(lambda x: x.astype(jnp.bfloat16), gf)
    else:  # int8, stochastic rounding, per-tensor scale
        leaves, treedef = jax.tree.flatten(gf)
        qs = []
        for i, x in enumerate(leaves):
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
            noise = jax.random.uniform(jax.random.fold_in(key, i), x.shape) - 0.5
            qi = jnp.clip(jnp.round(x / scale + noise), -127, 127)
            qs.append(qi.astype(jnp.int8).astype(jnp.float32) * scale)
        q = treedef.unflatten(qs)
    deq = jax.tree.map(lambda x: x.astype(jnp.float32), q)
    new_ef = jax.tree.map(lambda x, d: x - d, gf, deq)
    return deq, new_ef


def make_train_step(cfg, rules, hp: TrainHParams):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch``: {"tokens": [B, S] (or [B,S,n] codebooks / "embeddings"
    [B,S,D]), "labels": [B, S](+)} with B divisible by hp.microbatches.
    """
    schedule = make_schedule(hp.lr_kind, hp.lr_peak, hp.warmup_steps,
                             hp.total_steps)
    acfg = _adamw_cfg(cfg, hp)
    use_infl = cfg.moe is not None and cfg.moe.router == "balanced_kmeans" \
        and any(s.mlp == "moe" for s in cfg.pattern)

    def loss_fn(params, mb, influence):
        logits, new_infl, stats = M.forward(
            params, mb, cfg, rules, unroll=hp.unroll, remat=hp.remat,
            influence=influence)
        loss = M.loss_fn(logits, mb["labels"], cfg, z_loss=hp.z_loss)
        return loss, (new_infl, stats)

    def train_step(state, batch):
        params = state["params"]
        infl = state.get("influence")
        mbs = hp.microbatches

        def split(x):
            return x.reshape(mbs, x.shape[0] // mbs, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        acc_dt = jnp.dtype(hp.grad_acc_dtype)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

        def mb_body(carry, mb):
            gacc, infl_c, loss_acc, drop_acc = carry
            (loss, (ninf, st)), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, infl_c)
            gacc = jax.tree.map(lambda a, b: a + b.astype(acc_dt),
                                gacc, g)
            infl_n = ninf if use_infl else infl_c
            return (gacc, infl_n, loss_acc + loss,
                    drop_acc + st["moe_dropped_frac"]), None

        carry0 = (g0, infl, jnp.float32(0.0), jnp.float32(0.0))
        if hp.unroll:
            # roofline programs python-unroll the accumulation so
            # cost_analysis counts every microbatch's FLOPs
            carry = carry0
            for i in range(mbs):
                carry, _ = mb_body(carry, jax.tree.map(lambda x: x[i],
                                                       micro))
            gacc, new_infl, loss_sum, drop_sum = carry
        else:
            (gacc, new_infl, loss_sum, drop_sum), _ = jax.lax.scan(
                mb_body, carry0, micro)
        grads = jax.tree.map(lambda g: g / mbs, gacc)

        key = jax.random.fold_in(jax.random.PRNGKey(17),
                                 state["opt"]["step"])
        ef = state.get("ef")
        grads, new_ef = _compress(grads, ef, hp.grad_compress, key)

        lr = schedule(state["opt"]["step"])
        new_params, new_opt, ostats = adamw_update(
            params, grads, state["opt"], acfg, lr)
        new_state = dict(state, params=new_params, opt=new_opt)
        if use_infl:
            new_state["influence"] = new_infl
        if ef is not None:
            new_state["ef"] = new_ef
        metrics = {"loss": loss_sum / mbs,
                   "moe_dropped_frac": drop_sum / mbs,
                   "grad_norm": ostats["grad_norm"], "lr": lr,
                   "step": new_opt["step"]}
        return new_state, metrics

    return train_step
