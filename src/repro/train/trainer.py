"""Fault-tolerant training loop.

* resumes from the latest complete checkpoint (manifest-validated);
* periodic + on-exception checkpointing (preemption-safe: SIGTERM-style
  interruptions save before exit);
* one-deep host prefetch (input-side straggler hide);
* metrics history kept on host, loss logged every ``log_every``.

The loop owns no model logic — it drives the pure ``train_step`` built by
``train/step.py`` with whatever sharding ``rules`` the caller resolved,
so the same Trainer runs the CPU smoke configs and the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import Prefetcher
from .step import TrainHParams, init_train_state, make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0               # 0 = only final
    ckpt_dir: str | None = None
    keep_n: int = 3
    async_ckpt: bool = False
    resume: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg, rules, hp: TrainHParams, tc: TrainerConfig):
        self.cfg = cfg
        self.rules = rules
        self.hp = hp
        self.tc = tc
        self.step_fn = jax.jit(make_train_step(cfg, rules, hp), donate_argnums=0)
        self.ckpt = (CheckpointManager(tc.ckpt_dir, tc.keep_n, tc.async_ckpt)
                     if tc.ckpt_dir else None)
        self.history: list[dict] = []

    def init_or_resume(self):
        state = init_train_state(self.cfg, jax.random.PRNGKey(self.tc.seed),
                                 self.hp)
        start = 0
        if self.ckpt and self.tc.resume and self.ckpt.latest_step() is not None:
            state, start = self.ckpt.restore(state)
        return state, start

    def fit(self, data_iter, state=None, start_step: int | None = None):
        if state is None:
            state, start_step = self.init_or_resume()
        elif start_step is None:
            start_step = int(jax.device_get(state["opt"]["step"]))
        data = iter(Prefetcher(data_iter))
        step = start_step
        t0 = time.perf_counter()
        try:
            while step < self.tc.steps:
                batch = next(data)
                state, metrics = self.step_fn(state, batch)
                step += 1
                if step % self.tc.log_every == 0 or step == self.tc.steps:
                    m = {k: float(np.asarray(jax.device_get(v)))
                         for k, v in metrics.items()}
                    m["wall_s"] = time.perf_counter() - t0
                    self.history.append(m)
                if (self.ckpt and self.tc.ckpt_every
                        and step % self.tc.ckpt_every == 0):
                    self.ckpt.save(step, state)
        except (KeyboardInterrupt, SystemExit):
            if self.ckpt:                       # preemption: save and re-raise
                self.ckpt.save(step, state)
                self.ckpt.wait()
            raise
        if self.ckpt:
            self.ckpt.save(step, state)
            self.ckpt.wait()
        return state, self.history
