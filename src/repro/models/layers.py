"""Core transformer layers: RMSNorm, RoPE, GQA/SWA attention, MLP.

All modules follow the same convention:

* ``<mod>_params(cfg, create, ...)`` builds the parameter subtree through a
  ``create(shape, logical_axes, scale)`` callback — the same structure code
  serves init / abstract-eval / logical-spec extraction (models/model.py).
* ``<mod>_apply(params, x, ..., rules)`` is the pure forward function;
  ``rules`` carries the logical->mesh sharding table (dist/rules.py).

Attention supports three modes: full causal, sliding-window (gemma3), and
single-token decode against a KV cache (sequence- or batch-sharded).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm_params(d, create):
    return {"scale": create((d,), ("nil",), 0.0, init="ones")}


def rmsnorm(params, x, eps=1e-6):
    # square in the activation dtype, accumulate in f32: no f32 image of x
    # exists anywhere in the graph — with remat + scanned layers, any f32
    # cast of x gets stashed per layer next to the bf16 residual stack and
    # triples activation memory (DESIGN.md §5b). bf16 squaring costs ~2^-8
    # relative variance error, ~0.2% on the normalizer.
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def rope(x, positions, theta):
    """x: [..., S, H, dh]; positions: [S] or scalar broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [S, half]
    cos = jnp.cos(angles)[..., None, :]   # [S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def attention_params(cfg, create, kind="full"):
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": create((d, cfg.n_heads, hd), ("embed", "heads", "head_dim"),
                     d ** -0.5),
        "wk": create((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                     d ** -0.5),
        "wv": create((d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"),
                     d ** -0.5),
        "wo": create((cfg.n_heads, hd, d), ("heads", "head_dim", "embed"),
                     (cfg.n_heads * hd) ** -0.5),
    }


def _gqa_scores(q, k, cfg):
    """q: [B,S,H,dh], k: [B,T,KV,dh] -> scores [B,KV,H/KV,S,T] (f32)."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    q = q.reshape(B, S, KV, H // KV, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", q, k,
                   preferred_element_type=jnp.float32)
    return s * (dh ** -0.5)


# sequences >= this use the chunked (flash-style) paths: the dense S x T
# score matrix at S=4096+ would not fit HBM. On real TPUs the Pallas flash
# kernel (repro/kernels/flash_attention) replaces the inner chunk compute;
# the pure-JAX chunked path below is the portable/dry-run implementation
# with identical math (online softmax over KV chunks).
FLASH_S_MIN = 4096
_QC = 2048     # query chunk (triangular skipping; head-TP archs only)
_KVC = 2048    # key/value chunk


def _flash_full(q, k, v, cfg, rules, unroll_chunks: bool = False):
    """Causal full attention, online softmax over KV chunks. q: [B,S,H,dh]
    (roped), k/v: [B,S,KV,dh]. Returns [B,S,H,dh].

    When the sequence axis is unsharded (head-TP archs) queries are also
    chunked and strictly-above-diagonal (chunk_j > chunk_i) KV chunks are
    statically skipped — the triangular schedule that halves attention
    FLOPs. Under seq-SP the query dim stays whole (it is device-sharded;
    re-chunking it would fight GSPMD) and causal masking handles the upper
    triangle — the dead compute is reported honestly by the roofline and
    eliminated on TPU by the Pallas kernel's tile skipping.

    ``unroll_chunks=False`` (production / memory fit-check): the KV loop is
    a ``lax.scan`` — the while-loop structure guarantees one chunk's
    score/prob temps live at a time regardless of scheduler choices.
    ``unroll_chunks=True`` (roofline programs): python-unrolled, so
    ``cost_analysis`` counts every chunk's FLOPs exactly."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    f32 = jnp.float32
    scale = dh ** -0.5
    q_chunked = rules.table.get("act_seq") is None
    qc = _QC if (q_chunked and S % _QC == 0) else S
    kvc = _KVC if S % _KVC == 0 else S
    q5 = q.reshape(B, S, KV, G, dh)

    def chunk_pair(qi, ks, vs, kpos, m, l, acc, q0):
        """One (q-chunk, kv-chunk) online-softmax update. jax.checkpoint'd
        so the backward recomputes the O(qc*kvc) score/prob temps per
        chunk instead of holding all of them (the flash-backward recipe)."""
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi, ks,
                       preferred_element_type=f32) * scale
        if cfg.logit_softcap:
            s = jnp.tanh(s / cfg.logit_softcap) * cfg.logit_softcap
        qpos = q0 + jnp.arange(qi.shape[1])
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p, vs.astype(f32),
            preferred_element_type=f32)
        return m_new, l_new, acc_new

    ckpt = jax.checkpoint(chunk_pair,
                          policy=jax.checkpoint_policies.nothing_saveable)

    outs = []
    for i in range(S // qc):
        q0 = i * qc
        qi = q5[:, q0:q0 + qc]
        hi = q0 + qc if qc < S else S
        nkv = -(-hi // kvc)
        m = jnp.full((B, KV, G, qc), -jnp.inf, f32)
        l = jnp.zeros((B, KV, G, qc), f32)
        acc = jnp.zeros((B, KV, G, qc, dh), f32)
        if unroll_chunks:
            for j in range(nkv):
                t0 = j * kvc
                t1 = min(t0 + kvc, hi)
                kpos = t0 + jnp.arange(t1 - t0)
                m, l, acc = ckpt(qi, k[:, t0:t1], v[:, t0:t1], kpos,
                                 m, l, acc, q0)
        else:
            ks = k[:, :nkv * kvc].reshape(B, nkv, kvc, KV, dh) \
                .transpose(1, 0, 2, 3, 4)
            vs = v[:, :nkv * kvc].reshape(B, nkv, kvc, KV, dh) \
                .transpose(1, 0, 2, 3, 4)
            kpos = jnp.arange(nkv * kvc).reshape(nkv, kvc)

            def body(carry, xs):
                m, l, acc = carry
                ks_j, vs_j, kpos_j = xs
                m, l, acc = ckpt(qi, ks_j, vs_j, kpos_j, m, l, acc, q0)
                return (m, l, acc), None

            (m, l, acc), _ = jax.lax.scan(body, (m, l, acc),
                                          (ks, vs, kpos))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, dh)


def _local_band(q, k, v, cfg):
    """Sliding-window attention as banded block attention: each block of
    ``bc`` queries attends to (previous + own) key blocks, masked to the
    window — S * 2*bc compute instead of S^2. Requires bc >= window."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    f32 = jnp.float32
    bc = max(cfg.window, 1024)
    assert S % bc == 0 and bc >= cfg.window
    nb = S // bc
    qb = q.reshape(B, nb, bc, KV, G, dh)
    kb = k.reshape(B, nb, bc, KV, dh)
    vb = v.reshape(B, nb, bc, KV, dh).astype(f32)
    zero_k = jnp.zeros_like(kb[:, :1])
    zero_v = jnp.zeros_like(vb[:, :1])
    kcat = jnp.concatenate([jnp.concatenate([zero_k, kb[:, :-1]], 1), kb], 2)
    vcat = jnp.concatenate([jnp.concatenate([zero_v, vb[:, :-1]], 1), vb], 2)
    s = jnp.einsum("bnqkgd,bntkd->bnkgqt", qb, kcat,
                   preferred_element_type=f32) * (dh ** -0.5)
    if cfg.logit_softcap:
        s = jnp.tanh(s / cfg.logit_softcap) * cfg.logit_softcap
    rel = (bc + jnp.arange(bc))[:, None] - jnp.arange(2 * bc)[None, :]
    mask0 = (rel >= 0) & (rel < cfg.window)            # [bc, 2bc]
    first = jnp.arange(2 * bc)[None, :] >= bc          # block 0: no prev
    mask = jnp.where(jnp.arange(nb)[:, None, None] == 0,
                     mask0[None] & first[None], mask0[None])
    s = jnp.where(mask[None, :, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgqt,bntkd->bnqkgd", p, vcat,
                     preferred_element_type=f32)
    return out.reshape(B, S, H, dh)


def attention(params, x, cfg, rules, kind="full", positions=None,
              cache=None, cache_pos=None, want_cache=False,
              unroll_chunks=False):
    """Returns (out, new_cache). Train: cache=None, want_cache=False.
    Prefill: cache=None, want_cache=True -> new_cache holds the roped K/V
    for the whole sequence (the decode cache layout).

    Decode: x is [B,1,D]; cache = {"k": [B,T,KV,dh], "v": ...};
    cache_pos = scalar int32 write index.
    """
    B, S, D = x.shape
    theta = cfg.rope_theta
    if kind == "full" and cfg.rope_theta_global is not None:
        theta = cfg.rope_theta_global
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))

    if cache is None:
        if positions is None:
            positions = jnp.arange(S)
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
        q = rules.shard(q, "act_batch", "act_seq", "act_heads", None)
        k = rules.shard(k, "act_batch", "act_seq", "act_kv", None)
        v = rules.shard(v, "act_batch", "act_seq", "act_kv", None)
        if S >= FLASH_S_MIN and kind == "swa":
            out = _local_band(q, k, v, cfg).astype(x.dtype)
        elif S >= FLASH_S_MIN:
            out = _flash_full(q, k, v, cfg, rules,
                              unroll_chunks=unroll_chunks).astype(x.dtype)
        else:
            scores = _gqa_scores(q, k, cfg)
            qpos = positions[:, None]
            kpos = positions[None, :]
            mask = kpos <= qpos
            if kind == "swa":
                mask &= (qpos - kpos) < cfg.window
            if cfg.logit_softcap:
                scores = jnp.tanh(scores / cfg.logit_softcap) * \
                    cfg.logit_softcap
            scores = jnp.where(mask[None, None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
        new_cache = {"k": k, "v": v} if want_cache else None
    else:
        # single-token decode
        pos = cache_pos
        T = cache["k"].shape[1]
        ring = kind == "swa" and cfg.swa_ring_cache
        wpos = pos % T if ring else pos
        q = rope(q, jnp.full((S,), pos), theta)
        k = rope(k, jnp.full((S,), pos), theta)
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, wpos, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, wpos, 0, 0))
        ck = rules.shard(ck, "act_batch", "cache_seq", "cache_kv", None)
        cv = rules.shard(cv, "act_batch", "cache_seq", "cache_kv", None)
        scores = _gqa_scores(q, ck.astype(x.dtype), cfg)   # [B,KV,G,1,T]
        if ring:
            # slot s holds absolute position pos - ((pos - s) mod T);
            # unwritten slots map to negative positions and are masked
            kpos = pos - jnp.mod(pos - jnp.arange(T), T)
        else:
            kpos = jnp.arange(T)
        mask = (kpos <= pos) & (kpos >= 0)
        if kind == "swa":
            mask &= (pos - kpos) < cfg.window
        if cfg.logit_softcap:
            scores = jnp.tanh(scores / cfg.logit_softcap) * cfg.logit_softcap
        scores = jnp.where(mask[None, None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, cv.astype(x.dtype))
        new_cache = {"k": ck, "v": cv}

    out = out.reshape(B, S, cfg.n_heads, cfg.hd)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    out = rules.shard(out, "act_batch", "act_res_seq", "act_embed")
    return out, new_cache


def mlp_params(cfg, create):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {"w_gate": create((d, f), ("embed", "mlp"), d ** -0.5),
                "w_up": create((d, f), ("embed", "mlp"), d ** -0.5),
                "w_down": create((f, d), ("mlp", "embed"), f ** -0.5)}
    return {"w_up": create((d, f), ("embed", "mlp"), d ** -0.5),
            "w_down": create((f, d), ("mlp", "embed"), f ** -0.5)}


def mlp(params, x, cfg, rules):
    w_up = params["w_up"].astype(x.dtype)
    if cfg.mlp_kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (x @ w_up)
    else:
        h = jax.nn.gelu(x @ w_up)
    h = rules.shard(h, "act_batch", "act_seq", "act_mlp")
    out = h @ params["w_down"].astype(x.dtype)
    return rules.shard(out, "act_batch", "act_res_seq", "act_embed")
