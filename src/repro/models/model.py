"""Unified decoder-only LM covering all 10 assigned architectures.

Parameters are built through a single structure function (``_param_tree``)
driven by a ``create`` callback, so init / abstract shapes / logical
sharding specs always agree. Layer stacks are stored with a leading
``repeat`` dim (n_layers / pattern period) and either scanned (fast
compile; used for training and the memory fit-check) or python-unrolled
(exact per-layer FLOPs for the roofline dry-run).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import moe as MOE
from . import ssm as SSM
from .config import ModelConfig, LayerSpec


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _layer_params(cfg, spec: LayerSpec, create):
    p = {"ln1": L.rmsnorm_params(cfg.d_model, create),
         "ln2": L.rmsnorm_params(cfg.d_model, create)}
    if spec.attn in ("full", "swa"):
        p["attn"] = L.attention_params(cfg, create, spec.attn)
    elif spec.attn == "mamba":
        p["mamba"] = SSM.mamba_params(cfg, create)
    elif spec.attn == "rwkv":
        p["rwkv_t"] = SSM.rwkv_params(cfg, create)
    if spec.attn == "rwkv":
        p["rwkv_c"] = SSM.rwkv_channel_params(cfg, create)
    elif spec.mlp == "dense":
        p["mlp"] = L.mlp_params(cfg, create)
    else:
        p["moe"] = MOE.moe_params(cfg, create)
    return p


def _param_tree(cfg: ModelConfig, create):
    V, D = cfg.vocab_padded, cfg.d_model

    def stacked(shape, axes, scale, init="normal"):
        return create((cfg.n_repeats, *shape), ("repeat", *axes), scale, init)

    p: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        p["embed"] = create((V, D), ("vocab", "embed"), 1.0)
    elif cfg.input_mode == "codebooks":
        p["embed"] = create((cfg.n_codebooks, V, D), ("nil", "vocab", "embed"), 1.0)
    # embeddings mode: no input table (modality stub supplies activations)

    p["layers"] = {
        f"pos{i}": _layer_params(cfg, spec,
                                 lambda s, a, sc, init="normal":
                                 stacked(s, a, sc, init))
        for i, spec in enumerate(cfg.pattern)
    }
    p["final_norm"] = L.rmsnorm_params(D, create)
    if not cfg.tie_embeddings:
        if cfg.input_mode == "codebooks":
            p["lm_head"] = create((cfg.n_codebooks, D, V),
                                  ("nil", "embed", "vocab"), D ** -0.5)
        else:
            p["lm_head"] = create((D, V), ("embed", "vocab"), D ** -0.5)
    return p


def init_params(cfg: ModelConfig, key):
    pdt = jnp.dtype(cfg.param_dtype)
    counter = [0]

    def create(shape, axes, scale, init="normal"):
        counter[0] += 1
        k = jax.random.fold_in(key, counter[0])
        if init == "ones":
            return jnp.ones(shape, pdt)
        if init == "zeros":
            return jnp.zeros(shape, pdt)
        if init == "half":
            return jnp.full(shape, 0.5, pdt)
        if init == "ssm_a":        # A_log: log(1..d_state) per state dim
            ds = shape[-1]
            return jnp.broadcast_to(
                jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32)), shape
            ).astype(pdt)
        if init == "ssm_dt":       # softplus^-1(0.01)
            return jnp.full(shape, -4.6, pdt)
        if init == "ssm_w0":       # decay rate ~ exp(-exp(w0)) ~ 0.6/step
            return jnp.full(shape, -0.7, pdt)
        return (jax.random.normal(k, shape, jnp.float32) *
                (scale if scale else 0.02)).astype(pdt)

    return _param_tree(cfg, create)


def abstract_params(cfg: ModelConfig):
    pdt = jnp.dtype(cfg.param_dtype)
    return _param_tree(
        cfg, lambda shape, axes, scale, init="normal":
        jax.ShapeDtypeStruct(shape, pdt))


def param_logical_specs(cfg: ModelConfig):
    return _param_tree(
        cfg, lambda shape, axes, scale, init="normal": tuple(axes))


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_input(params, batch, cfg, rules):
    dt = cfg.act_dtype
    if cfg.input_mode == "tokens":
        x = params["embed"].astype(dt)[batch["tokens"]]
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    elif cfg.input_mode == "codebooks":
        emb = params["embed"].astype(dt)
        x = sum(emb[i][batch["tokens"][..., i]]
                for i in range(cfg.n_codebooks))
    else:  # embeddings (modality frontend stub)
        x = batch["embeddings"].astype(dt)
    return rules.shard(x, "act_batch", "act_res_seq", "act_embed")


def _layer_apply(p, spec: LayerSpec, x, cfg, rules, positions=None,
                 cache=None, pos=None, influence=None, unroll_chunks=False,
                 want_cache=False):
    """One pattern-position layer. Returns (x, new_cache, new_infl, stats).

    ``want_cache`` (prefill): with cache=None, also emit the end-of-
    sequence cache/state in the decode layout."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    new_cache = {}
    if spec.attn in ("full", "swa"):
        out, kv = L.attention(p["attn"], h, cfg, rules, spec.attn,
                              positions, cache=None if cache is None
                              else {"k": cache["k"], "v": cache["v"]},
                              cache_pos=pos, want_cache=want_cache,
                              unroll_chunks=unroll_chunks)
        if kv is not None:
            new_cache.update(kv)
    elif spec.attn == "mamba":
        st = None if cache is None else {"h": cache["h"], "conv": cache["conv"]}
        out, st2 = SSM.mamba_apply(p["mamba"], h, cfg, rules, state=st,
                                   unroll_chunks=unroll_chunks,
                                   want_state=want_cache)
        if st2 is not None:
            new_cache.update(st2)
    else:  # rwkv
        st = None if cache is None else {"s": cache["s"],
                                         "shift": cache["shift_t"]}
        out, st2 = SSM.rwkv_time_mix(p["rwkv_t"], h, cfg, rules, state=st,
                                     unroll_chunks=unroll_chunks,
                                     want_state=want_cache)
        if st2 is not None:
            new_cache["s"] = st2["s"]
            new_cache["shift_t"] = st2["shift"]
    x = x + out

    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    new_infl, stats = None, {}
    if spec.attn == "rwkv":
        st = None if cache is None else cache["shift_c"]
        out2, st2 = SSM.rwkv_channel_mix(p["rwkv_c"], h2, cfg, rules,
                                         state=st, want_state=want_cache)
        if st2 is not None:
            new_cache["shift_c"] = st2
    elif spec.mlp == "dense":
        out2 = L.mlp(p["mlp"], h2, cfg, rules)
    else:
        out2, new_infl, stats = MOE.moe_apply(p["moe"], h2, cfg, rules,
                                              influence)
    return x + out2, (new_cache or None), new_infl, stats


def forward(params, batch, cfg: ModelConfig, rules, unroll: bool = False,
            remat: bool = True, influence=None, want_cache: bool = False,
            last_only: bool = False):
    """Training/prefill forward. Returns (logits, new_influence, moe_stats)
    or, with ``want_cache``, (logits, new_influence, moe_stats, cache).

    ``influence``: [n_repeats, n_moe, E] balanced-k-means router state.
    ``want_cache``: emit the populated decode cache (prefill).
    ``last_only``: unembed only the final position (prefill returns one
    next-token distribution, not [B,S,V])."""
    x = _embed_input(params, batch, cfg, rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    moe_positions = [i for i, s in enumerate(cfg.pattern) if s.mlp == "moe"
                     and s.attn != "rwkv"]
    use_infl = influence is not None
    n_moe = len(moe_positions)

    def repeat_body(x, p_r, infl_r):
        new_infls, drop = [], jnp.zeros((), jnp.float32)
        cache_r = {}
        for i, spec in enumerate(cfg.pattern):
            li = moe_positions.index(i) if i in moe_positions else None
            inf_i = infl_r[li] if (use_infl and li is not None) else None
            x, nc, ni, st = _layer_apply(p_r[f"pos{i}"], spec, x, cfg, rules,
                                         positions, influence=inf_i,
                                         unroll_chunks=unroll,
                                         want_cache=want_cache)
            if want_cache:
                cache_r[f"pos{i}"] = nc
            if li is not None:
                new_infls.append(ni if ni is not None else
                                 jnp.ones(cfg.moe.n_experts, jnp.float32))
                drop = drop + st.get("dropped_frac", 0.0)
        ninf = (jnp.stack(new_infls) if new_infls
                else jnp.zeros((0, 1), jnp.float32))
        return x, ninf, drop, cache_r

    E = cfg.moe.n_experts if cfg.moe else 1
    infl_all = (influence if use_infl
                else jnp.zeros((cfg.n_repeats, n_moe or 0, E), jnp.float32))
    if unroll:
        drops, ninfs, caches = [], [], []
        for r in range(cfg.n_repeats):
            p_r = jax.tree.map(lambda v: v[r], params["layers"])
            x, ninf, drop, cache_r = repeat_body(x, p_r, infl_all[r])
            ninfs.append(ninf)
            drops.append(drop)
            caches.append(cache_r)
        new_influence = jnp.stack(ninfs) if use_infl else None
        drop_frac = jnp.mean(jnp.stack(drops)) if drops else 0.0
        cache = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                 if want_cache else None)
    else:
        def scan_body(x, inp):
            p_r, infl_r = inp
            x, ninf, drop, cache_r = repeat_body(x, p_r, infl_r)
            return x, (ninf, drop, cache_r)
        body = jax.checkpoint(scan_body,
                              policy=jax.checkpoint_policies.nothing_saveable
                              ) if remat else scan_body
        x, (ninf, drops, cache) = jax.lax.scan(body, x,
                                               (params["layers"], infl_all))
        new_influence = ninf if use_infl else None
        drop_frac = jnp.mean(drops)
        if not want_cache:
            cache = None

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:]
    logits = _unembed(params, x, cfg, rules)
    stats = {"moe_dropped_frac": drop_frac}
    if want_cache:
        return logits, new_influence, stats, cache
    return logits, new_influence, stats


def prefill(params, batch, cfg: ModelConfig, rules, unroll: bool = False):
    """Serving prefill: full-sequence forward that returns the last-position
    logits and the populated decode cache (paper-of-record layout matching
    ``init_cache``/``decode_step``)."""
    logits, _, _, cache = forward(params, batch, cfg, rules, unroll=unroll,
                                  remat=False, want_cache=True,
                                  last_only=True)
    return logits, cache


def _unembed(params, x, cfg, rules):
    dt = x.dtype
    if cfg.tie_embeddings:
        w = params["embed"].astype(dt).T
    else:
        w = params["lm_head"].astype(dt)
    if cfg.input_mode == "codebooks":
        logits = jnp.einsum("bsd,ndv->bsnv", x, w)
        return rules.shard(logits, "act_batch", "logits_seq", None,
                           "act_vocab")
    logits = x @ w
    return rules.shard(logits, "act_batch", "logits_seq", "act_vocab")


def loss_fn(logits, labels, cfg, z_loss: float = 1e-4):
    """Cross entropy over the padded vocab (padded ids masked out)."""
    V = cfg.vocab_padded
    lf = logits.astype(jnp.float32)
    if cfg.vocab_size < V:
        pad_mask = jnp.arange(V) >= cfg.vocab_size
        lf = jnp.where(pad_mask, -1e30, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, rules):
    """Per-pattern-position caches stacked over repeats, pre-sharded."""
    dt = cfg.act_dtype
    R = cfg.n_repeats
    cache = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.attn in ("full", "swa"):
            seq = max_seq
            if spec.attn == "swa" and cfg.swa_ring_cache:
                # ring cache: a window of keys suffices; decode writes at
                # pos % ring and masks by absolute distance
                seq = min(max_seq, cfg.window)
            # distinct buffers: k/v are donated separately in serve_step
            c = {"k": jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd),
                                dt),
                 "v": jnp.zeros((R, batch, seq, cfg.n_kv_heads, cfg.hd),
                                dt)}
        elif spec.attn == "mamba":
            st = SSM.mamba_state_init(cfg, batch, dt)
            c = jax.tree.map(lambda x: jnp.broadcast_to(x, (R, *x.shape)), st)
        else:
            st = SSM.rwkv_state_init(cfg, batch)
            c = jax.tree.map(lambda x: jnp.broadcast_to(x, (R, *x.shape)), st)
        cache[f"pos{i}"] = c
    return cache


def extend_cache(cache, cfg: ModelConfig, max_seq: int):
    """Pad a prefill-produced cache (seq length = prompt) out to the decode
    horizon so ``decode_step`` can write positions >= prompt length."""
    out = {}
    for i, spec in enumerate(cfg.pattern):
        c = cache[f"pos{i}"]
        if spec.attn in ("full", "swa"):
            pad = max_seq - c["k"].shape[2]
            out[f"pos{i}"] = {kk: jnp.pad(
                v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                for kk, v in c.items()}
        else:
            out[f"pos{i}"] = c
    return out


def cache_logical_specs(cfg: ModelConfig):
    specs = {}
    for i, spec in enumerate(cfg.pattern):
        if spec.attn in ("full", "swa"):
            s = ("repeat", "act_batch", "cache_seq", "cache_kv", None)
            c = {"k": s, "v": s}
        elif spec.attn == "mamba":
            c = {"h": ("repeat", "act_batch", "act_mlp", None),
                 "conv": ("repeat", "act_batch", None, "act_mlp")}
        else:
            c = {"s": ("repeat", "act_batch", None, None, None),
                 "shift_t": ("repeat", "act_batch", None),
                 "shift_c": ("repeat", "act_batch", None)}
        specs[f"pos{i}"] = c
    return specs


def decode_step(params, cache, batch, pos, cfg: ModelConfig, rules,
                unroll: bool = False):
    """One-token decode. batch: {"tokens": [B,1]...}; pos: scalar int32.
    Returns (logits [B,1,V], new_cache)."""
    x = _embed_input(params, batch, cfg, rules)

    def repeat_body(x, p_r, cache_r):
        new_cache_r = {}
        for i, spec in enumerate(cfg.pattern):
            x, nc, _, _ = _layer_apply(p_r[f"pos{i}"], spec, x, cfg, rules,
                                       cache=cache_r[f"pos{i}"], pos=pos)
            new_cache_r[f"pos{i}"] = nc
        return x, new_cache_r

    if unroll:
        ncs = []
        for r in range(cfg.n_repeats):
            p_r = jax.tree.map(lambda v: v[r], params["layers"])
            c_r = jax.tree.map(lambda v: v[r], cache)
            x, nc = repeat_body(x, p_r, c_r)
            ncs.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    else:
        def scan_body(x, inp):
            p_r, c_r = inp
            x, nc = repeat_body(x, p_r, c_r)
            return x, nc
        x, new_cache = jax.lax.scan(scan_body, x, (params["layers"], cache))

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, x, cfg, rules)
    return logits, new_cache
