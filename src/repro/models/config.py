"""Model configuration for the unified decoder stack.

One ``ModelConfig`` describes any of the 10 assigned architectures:
dense GQA transformers, sliding-window patterns (gemma3), MoE (llama4 /
granite / jamba), Mamba-hybrid (jamba) and RWKV6. A *layer pattern* of
period ``p`` is repeated ``n_layers / p`` times; parameters are stored
stacked per pattern position with a leading ``repeat`` dim so the stack
can be scanned (fast compile) or unrolled (exact dry-run FLOPs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax.numpy as jnp

AttnKind = Literal["full", "swa", "mamba", "rwkv"]
MlpKind = Literal["dense", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    capacity_factor: float = 1.25
    router: Literal["linear", "balanced_kmeans"] = "linear"
    router_d_eff: int = 8          # effective dim for influence Eq. (1)
    router_influence_clip: float = 0.05
    n_shared_experts: int = 0      # llama4-style shared expert
    dispatch_no_repeat: bool = False   # gather tokens via idx//K instead of
    #                                    materializing a K-times-repeated
    #                                    source (perf opt; default off =
    #                                    measured baseline)


@dataclass(frozen=True)
class LayerSpec:
    attn: AttnKind = "full"
    mlp: MlpKind = "dense"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // n_heads
    pattern: tuple = (LayerSpec(),)  # repeated n_layers/len(pattern) times
    mlp_kind: Literal["swiglu", "gelu"] = "swiglu"
    moe: MoEConfig | None = None
    window: int = 1024                     # swa window
    swa_ring_cache: bool = False           # window-sized ring decode cache
    #                                        (perf opt; default off = paper-
    #                                        faithful full-length cache)
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None  # gemma3: different global theta
    logit_softcap: float | None = None
    input_mode: Literal["tokens", "embeddings", "codebooks"] = "tokens"
    n_codebooks: int = 1                   # musicgen
    tie_embeddings: bool = False
    # mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    moment_dtype: str = "float32"          # optimizer moments (bf16 for 400B)
    # misc hints
    seq_len_hint: int | None = None
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % self.period == 0
        return self.n_layers // self.period

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 128) * 128  # pad to 128 lanes

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Analytic parameter count (excludes biases we don't use)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_padded * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_padded * d * (self.n_codebooks if
                                          self.input_mode == "codebooks" else 1)
        per_pattern = 0
        for spec in self.pattern:
            if spec.attn in ("full", "swa"):
                per_pattern += d * (self.n_heads * hd) + \
                    2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
            elif spec.attn == "mamba":
                di = self.mamba_expand * d
                dt_rank = max(d // 16, 1)
                per_pattern += d * 2 * di + di * self.mamba_d_conv + \
                    di * (dt_rank + 2 * self.mamba_d_state) + dt_rank * di + \
                    di * self.mamba_d_state + di + di * d
            elif spec.attn == "rwkv":
                per_pattern += 4 * d * d + d * d  # r,k,v,g,o
                per_pattern += 2 * d * self.rwkv_lora_rank
            if spec.mlp == "dense":
                mult = 3 if self.mlp_kind == "swiglu" else 2
                per_pattern += mult * d * self.d_ff
            elif spec.mlp == "moe":
                m = self.moe
                mult = 3
                per_pattern += m.n_experts * mult * d * m.d_ff
                per_pattern += d * m.n_experts  # router
                per_pattern += m.n_shared_experts * mult * d * m.d_ff
        n += per_pattern * self.n_repeats
        return n

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        m = self.moe
        n_moe_layers = sum(1 for s in self.pattern if s.mlp == "moe") * self.n_repeats
        inactive = n_moe_layers * (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff
        return full - inactive
