"""State-space / linear-recurrence blocks: Mamba (jamba) and RWKV6 (finch).

TPU adaptation notes (DESIGN.md §4): both blocks are expressed as *chunked*
recurrences — an outer ``lax.scan`` over sequence chunks carrying the
recurrent state, with fully-parallel (associative-scan / matmul) compute
inside each chunk. This maps the sequential recurrence onto MXU/VPU-friendly
dense ops, keeps the live workspace to one chunk, and gives bit-consistent
train/decode semantics (tested against step-by-step oracles).

RWKV6 numerics: decays are processed in log space; the intra-chunk
attention-like term uses factors exp(±cum) whose exponent is bounded by
``chunk * |w_log|_max``; with chunk=16 and w_log clamped to >= -5 the
factors stay inside f32 range (|exp| <= e^80 < f32 max).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ===========================================================================
# Mamba (selective SSM, as interleaved in Jamba)
# ===========================================================================

def mamba_params(cfg, create):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds = cfg.mamba_d_state
    dk = cfg.mamba_d_conv
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": create((d, 2 * di), ("embed", "mlp"), d ** -0.5),
        "conv_w": create((dk, di), ("conv", "mlp"), dk ** -0.5),
        "x_proj": create((di, dt_rank + 2 * ds), ("mlp", "nil"), di ** -0.5),
        "dt_proj": create((dt_rank, di), ("rank", "mlp"), dt_rank ** -0.5),
        "dt_bias": create((di,), ("mlp",), 0.0, init="ssm_dt"),
        "a_log": create((di, ds), ("mlp", "state"), 0.0, init="ssm_a"),
        "d_skip": create((di,), ("mlp",), 0.0, init="ones"),
        "out_proj": create((di, d), ("mlp", "embed"), di ** -0.5),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: [B,S,di], w: [dk,di].
    state: [B,dk-1,di] trailing context (decode). Returns (y, new_state)."""
    dk = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dk - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, S+dk-1, di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(dk))
    new_state = xp[:, -(dk - 1):]
    return y, new_state


def _ssm_chunk(h0, dt_c, b_c, x_c, cmat, a):
    """One chunk of the selective scan via associative scan.

    The discretized transition/input tensors da/db ([B,C,di,ds]) are
    computed HERE, per chunk, from the chunk's dt/B/x slices — computing
    them for the full sequence up front materializes an S x di x ds f32
    tensor (hundreds of GB/device for jamba at 4k+), the single largest
    memory hazard in the hybrid stack.

    h0: [B,di,ds]; dt_c/x_c: [B,C,di]; b_c/cmat: [B,C,ds]; a: [di,ds].
    Returns (y [B,C,di], hC)."""
    da = jnp.exp(dt_c[..., None] * a[None, None])          # [B,C,di,ds]
    db = dt_c[..., None] * b_c[:, :, None, :] * x_c[..., None]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    aprod, bacc = jax.lax.associative_scan(combine, (da, db), axis=1)
    h = aprod * h0[:, None] + bacc                   # [B,C,di,ds]
    y = jnp.einsum("bcds,bcs->bcd", h, cmat)
    return y, h[:, -1]


def mamba_apply(params, x, cfg, rules, state=None, chunk=128,
                unroll_chunks=False, want_state=False):
    """x: [B,S,D]. state (decode): {"h": [B,di,ds], "conv": [B,dk-1,di]}.
    ``want_state`` (prefill): return the end-of-sequence recurrent state.
    Returns (out, new_state)."""
    B, S, D = x.shape
    di = cfg.mamba_expand * D
    ds = cfg.mamba_d_state
    dt_rank = max(D // 16, 1)
    xz = x @ params["in_proj"].astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = rules.shard(xs, "act_batch", "act_seq", "act_mlp")
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _causal_conv(xs, params["conv_w"].astype(x.dtype),
                                conv_state)
    xs = jax.nn.silu(xs)
    dbc = xs @ params["x_proj"].astype(x.dtype)
    dt_in, bmat, cmat = jnp.split(dbc, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"].astype(x.dtype)
                         + params["dt_bias"].astype(x.dtype))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # [di, ds]
    dtf = dt.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    xf = xs.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    if state is not None:                                      # decode (S==1)
        da0 = jnp.exp(dtf[:, 0, :, None] * a[None])
        db0 = dtf[:, 0, :, None] * bf[:, 0, None, :] * xf[:, 0, :, None]
        h = state["h"] * da0 + db0
        y = jnp.einsum("bds,bs->bd", h, cf[:, 0])[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
        nc = S // chunk if S % chunk == 0 else 1
        csz = chunk if S % chunk == 0 else S
        dt_c = dtf.reshape(B, nc, csz, di)
        b_c = bf.reshape(B, nc, csz, ds)
        x_c = xf.reshape(B, nc, csz, di)
        cm_c = cf.reshape(B, nc, csz, ds)
        # unroll cap: beyond 64 chunks the unrolled HLO explodes; the scan
        # body is then counted once by cost_analysis — an undercount of
        # the state-recurrence term only (<5% of mamba-layer FLOPs, the
        # projections dominate); documented in EXPERIMENTS.md §Roofline.
        if unroll_chunks and nc <= 64:
            ys, h = [], h0
            for i in range(nc):
                y_i, h = _ssm_chunk(h, dt_c[:, i], b_c[:, i], x_c[:, i],
                                    cm_c[:, i], a)
                ys.append(y_i)
            y = jnp.concatenate(ys, axis=1)
        else:
            def step(h, inp):
                y_i, h = _ssm_chunk(h, *inp, a)
                return h, y_i
            h, ys = jax.lax.scan(
                step, h0, (dt_c.transpose(1, 0, 2, 3),
                           b_c.transpose(1, 0, 2, 3),
                           x_c.transpose(1, 0, 2, 3),
                           cm_c.transpose(1, 0, 2, 3)))
            y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
        new_state = {"h": h, "conv": new_conv} if want_state else None
    y = y.astype(x.dtype) + xs * params["d_skip"].astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ params["out_proj"].astype(x.dtype)
    return rules.shard(out, "act_batch", "act_res_seq", "act_embed"), new_state


def mamba_state_init(cfg, batch, dtype=jnp.float32):
    di = cfg.mamba_expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype)}


# ===========================================================================
# RWKV6 ("finch": data-dependent per-channel decay)
# ===========================================================================

def rwkv_params(cfg, create):
    d = cfg.d_model
    r = cfg.rwkv_lora_rank
    H = d // cfg.rwkv_head_dim
    p = {
        "mu": create((5, d), ("nil", "embed"), 0.0, init="half"),  # r,k,v,g,w
        "w0": create((d,), ("embed",), 0.0, init="ssm_w0"),
        "w_lora_a": create((d, r), ("embed", "rank"), d ** -0.5),
        "w_lora_b": create((r, d), ("rank", "embed"), 0.01 * r ** -0.5),
        "wr": create((d, d), ("embed", "heads_joined"), d ** -0.5),
        "wk": create((d, d), ("embed", "heads_joined"), d ** -0.5),
        "wv": create((d, d), ("embed", "heads_joined"), d ** -0.5),
        "wg": create((d, d), ("embed", "heads_joined"), d ** -0.5),
        "wo": create((d, d), ("heads_joined", "embed"), d ** -0.5),
        "u": create((H, cfg.rwkv_head_dim), ("nil", "nil"), 0.5),
        "ln_w": create((H, cfg.rwkv_head_dim), ("nil", "nil"), 0.0, init="ones"),
    }
    return p


W_LOG_MIN = -5.0
RWKV_CHUNK = 16


def _rwkv_chunk(s0, r, k, v, wlog, u):
    """One chunk. s0: [B,H,dk,dv]; r/k/v: [B,C,H,dh]; wlog: [B,C,H,dk].
    out_t = r_t (u*k_t) v_t + r_t S_{t-1};  S_t = diag(w_t) S_{t-1} + k_t v_t
    Returns (out [B,C,H,dv], sC)."""
    cum = jnp.cumsum(wlog, axis=1)                     # inclusive
    cum_prev = cum - wlog
    q = r * jnp.exp(cum_prev)                          # bounded <= 1-ish
    inter = jnp.einsum("bchk,bhkv->bchv", q, s0)
    kd = k * jnp.exp(-cum)                             # bounded by e^{C|w|}
    A = jnp.einsum("bchk,bjhk->bhcj", q, kd)
    C = r.shape[1]
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    A = jnp.where(mask[None, None], A, 0.0)
    diag = jnp.einsum("bchk,bchk->bch", r, u[None, None] * k)
    intra = jnp.einsum("bhcj,bjhv->bchv", A, v) + diag[..., None] * v
    out = inter + intra
    decay_end = jnp.exp(cum[:, -1])                    # [B,H,dk]
    k_end = k * jnp.exp(cum[:, -1:] - cum)             # bounded <= 1
    s_new = decay_end[..., None] * s0 + jnp.einsum("bchk,bchv->bhkv", k_end, v)
    return out, s_new


def rwkv_time_mix(params, x, cfg, rules, state=None, unroll_chunks=False,
                  want_state=False):
    """x: [B,S,D]. state: {"s": [B,H,dk,dv], "shift": [B,D]}.
    ``want_state`` (prefill): return the end-of-sequence WKV state.
    Returns (out, new_state)."""
    B, S, D = x.shape
    dh = cfg.rwkv_head_dim
    H = D // dh
    if state is None:
        xprev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        xprev = state["shift"][:, None]
    mu = params["mu"].astype(x.dtype)
    mix = [x + (xprev - x) * mu[i][None, None] for i in range(5)]
    xr, xk, xv, xg, xw = mix
    f32 = jnp.float32
    r = (xr @ params["wr"].astype(x.dtype)).reshape(B, S, H, dh).astype(f32)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B, S, H, dh).astype(f32)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B, S, H, dh).astype(f32)
    g = xg @ params["wg"].astype(x.dtype)
    lora = jnp.tanh(xw @ params["w_lora_a"].astype(x.dtype)) @ \
        params["w_lora_b"].astype(x.dtype)
    wlog = -jnp.exp(params["w0"].astype(f32)[None, None] + lora.astype(f32))
    wlog = jnp.maximum(wlog, W_LOG_MIN).reshape(B, S, H, dh)
    u = params["u"].astype(f32)

    if state is not None:                               # decode (S == 1)
        s0 = state["s"]
        r1, k1, v1, w1 = r[:, 0], k[:, 0], v[:, 0], wlog[:, 0]
        out = jnp.einsum("bhk,bhkv->bhv", r1, s0) + \
            jnp.einsum("bhk,bhk->bh", r1, u[None] * k1)[..., None] * v1
        s_new = jnp.exp(w1)[..., None] * s0 + \
            jnp.einsum("bhk,bhv->bhkv", k1, v1)
        out = out[:, None]                              # [B,1,H,dv]
        new_state = {"s": s_new, "shift": x[:, -1]}
    else:
        c = RWKV_CHUNK if S % RWKV_CHUNK == 0 else S
        nc = S // c
        rs = r.reshape(B, nc, c, H, dh)
        ks = k.reshape(B, nc, c, H, dh)
        vs = v.reshape(B, nc, c, H, dh)
        ws = wlog.reshape(B, nc, c, H, dh)
        s0 = jnp.zeros((B, H, dh, dh), f32)
        # same unroll cap as mamba: the wkv recurrence is ~3% of rwkv-layer
        # FLOPs (d*d projections dominate); scan-undercount documented.
        if unroll_chunks and nc <= 64:
            outs, s = [], s0
            for i in range(nc):
                o, s = _rwkv_chunk(s, rs[:, i], ks[:, i], vs[:, i], ws[:, i], u)
                outs.append(o)
            out = jnp.concatenate(outs, axis=1)
        else:
            def step(s, inp):
                o, s = _rwkv_chunk(s, *inp, u)
                return s, o
            s, outs = jax.lax.scan(
                step, s0, (rs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
                           vs.transpose(1, 0, 2, 3, 4), ws.transpose(1, 0, 2, 3, 4)))
            out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
        out = out.reshape(B, S, H, dh)
        new_state = {"s": s, "shift": x[:, -1]} if want_state else None

    # per-head groupnorm, gate, output proj
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5) * \
        params["ln_w"].astype(f32)[None, None]
    out = out.reshape(*out.shape[:-2], H * dh).astype(x.dtype) * jax.nn.silu(g)
    out = out @ params["wo"].astype(x.dtype)
    return rules.shard(out, "act_batch", "act_res_seq", "act_embed"), new_state


def rwkv_channel_params(cfg, create):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": create((2, d), ("nil", "embed"), 0.0, init="half"),  # k, r
        "wk": create((d, f), ("embed", "mlp"), d ** -0.5),
        "wv": create((f, d), ("mlp", "embed"), f ** -0.5),
        "wr": create((d, d), ("embed", "nil"), d ** -0.5),
    }


def rwkv_channel_mix(params, x, cfg, rules, state=None, want_state=False):
    B, S, D = x.shape
    if state is None:
        xprev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        new_state = x[:, -1] if want_state else None
    else:
        xprev = state[:, None]
        new_state = x[:, -1]
    mu = params["mu"].astype(x.dtype)
    xk = x + (xprev - x) * mu[0][None, None]
    xr = x + (xprev - x) * mu[1][None, None]
    h = jnp.square(jax.nn.relu(xk @ params["wk"].astype(x.dtype)))
    h = rules.shard(h, "act_batch", "act_seq", "act_mlp")
    out = jax.nn.sigmoid(xr @ params["wr"].astype(x.dtype)) * \
        (h @ params["wv"].astype(x.dtype))
    return rules.shard(out, "act_batch", "act_res_seq", "act_embed"), new_state


def rwkv_state_init(cfg, batch):
    dh = cfg.rwkv_head_dim
    H = cfg.d_model // dh
    return {"s": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "shift_t": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
            "shift_c": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype))}
