"""Mixture-of-Experts layer with two routers:

* ``linear``          — standard learned-logits router (baseline).
* ``balanced_kmeans`` — the paper's technique as a first-class MoE router:
  experts are cluster centers in token-embedding space; tokens are assigned
  by *effective distance* ``sqdist(x, centroid)/influence^2`` and per-expert
  influence values are updated each step with the paper's geometric rule
  (Eq. 1, via ``core.balanced_kmeans.adapt_influence``). This is an
  aux-loss-free load-balancing mechanism: oversubscribed experts lose
  influence and shed tokens, exactly like oversized clusters in the paper.
  Router *state* (influence + running load) is carried outside params and
  updated functionally by the train step.

Dispatch is **scatter-based** (sort-free MegaBlocks-style): tokens are
placed into a per-expert slot buffer with `.at[].set` using positions from
a cumulative count — no O(T·E·C) one-hot einsum, so compiled HLO FLOPs
reflect only real expert compute (critical for honest rooflines).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.balanced_kmeans import adapt_influence


def moe_params(cfg, create):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.n_experts
    p = {
        "router": create((d, E), ("embed", "expert"), d ** -0.5),
        "w_gate": create((E, d, f), ("expert", "e_embed", "e_mlp"), d ** -0.5),
        "w_up": create((E, d, f), ("expert", "e_embed", "e_mlp"), d ** -0.5),
        "w_down": create((E, f, d), ("expert", "e_mlp", "e_embed"), f ** -0.5),
    }
    if m.n_shared_experts:
        fs = m.d_ff * m.n_shared_experts
        p["shared"] = {
            "w_gate": create((d, fs), ("embed", "mlp"), d ** -0.5),
            "w_up": create((d, fs), ("embed", "mlp"), d ** -0.5),
            "w_down": create((fs, d), ("mlp", "embed"), fs ** -0.5)}
    if m.router == "balanced_kmeans":
        p["centroids"] = create((E, d), ("expert", "embed"), d ** -0.5)
    return p


def init_router_state(cfg):
    """Per-MoE-layer influence vector (paper: initialized to 1)."""
    if cfg.moe is None or cfg.moe.router != "balanced_kmeans":
        return None
    n_moe = sum(1 for s in cfg.pattern if s.mlp == "moe")
    return {"influence": jnp.ones((cfg.n_repeats, n_moe, cfg.moe.n_experts),
                                  jnp.float32)}


def router_logits(params, x, m, influence):
    """x: [T, D] -> logits [T, E] (higher = preferred)."""
    if m.router == "linear":
        return x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    c = params["centroids"].astype(jnp.float32)
    xf = x.astype(jnp.float32)
    sq = (jnp.sum(xf * xf, -1, keepdims=True) + jnp.sum(c * c, -1)[None]
          - 2.0 * xf @ c.T)
    eff = jnp.maximum(sq, 0.0) / (influence * influence)[None]
    return -eff  # min effective distance == max logit


def moe_apply(params, x, cfg, rules, influence=None):
    """x: [B, S, D]. Returns (out, new_influence, load_stats).

    Dispatch groups are per batch row (group = one sequence): capacity is
    ``top_k * S / E * cf`` per group, the cumulative-position scatter runs
    over S*K items per group, keeping dispatch state tiny and fully batch-
    sharded. Expert weights are expert-sharded (EP) when E % tp == 0, else
    d_model-TP (contracting-dim sharding with psum) — see dist/rules.py.
    """
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k

    infl = influence if influence is not None else jnp.ones(E, jnp.float32)
    logits = router_logits(params, x.reshape(B * S, D), m, infl)
    logits = logits.reshape(B, S, E)
    gates, eidx = jax.lax.top_k(logits, K)               # [B,S,K]
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    C = int(max(1, round(K * S / E * m.capacity_factor)))
    T = S * K
    flat_e = eidx.reshape(B, T)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # [B,S*K,E]
    cum = jnp.cumsum(onehot, axis=1)
    pos = jnp.take_along_axis(cum, flat_e[..., None], axis=2)[..., 0] - 1
    ok = pos < C
    slot = jnp.where(ok, flat_e * C + pos, E * C)        # overflow -> sentinel
    src = None if m.dispatch_no_repeat else \
        (jnp.repeat(x, K, axis=1) if K > 1 else x)       # [B,S*K,D]
    # --- gather-based dispatch ------------------------------------------
    # A scatter into [B, E*C, D] slot buffers does not partition under
    # GSPMD (it replicates — hundreds of GB/device for the 400B MoE
    # cells). Instead, stable-sort token ids by expert; slot (e, c) then
    # *gathers* token order[b, starts[e]+c] — gathers with a leading batch
    # dim partition cleanly. Within-expert order matches the cumulative
    # `pos` above, so the return path can keep indexing by `slot`.
    order = jnp.argsort(flat_e, axis=1, stable=True)     # [B, T]
    counts = jnp.sum(onehot, axis=1)                     # [B, E]
    starts = jnp.cumsum(counts, axis=1) - counts         # exclusive
    c_idx = jnp.arange(C)[None, None]
    src_pos = jnp.clip(starts[:, :, None] + c_idx, 0, T - 1)
    valid = c_idx < jnp.minimum(counts, C)[:, :, None]   # [B, E, C]
    tok_idx = jnp.take_along_axis(order, src_pos.reshape(B, E * C), axis=1)
    if m.dispatch_no_repeat:
        # flat position t corresponds to token t // K: gather straight from
        # x — no K-times-repeated source tensor is ever materialized
        hidden = jnp.take_along_axis(x, (tok_idx // K)[..., None], axis=1)
    else:
        hidden = jnp.take_along_axis(src, tok_idx[..., None], axis=1)
    hidden = hidden * valid.reshape(B, E * C, 1).astype(x.dtype)
    hidden = hidden.reshape(B, E, C, D)
    hidden = rules.shard(hidden, "act_batch", "expert", None, "act_e_embed")

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", hidden,
                               params["w_gate"].astype(x.dtype)))
    u = jnp.einsum("becd,edf->becf", hidden, params["w_up"].astype(x.dtype))
    eo = jnp.einsum("becf,efd->becd", g * u, params["w_down"].astype(x.dtype))
    eo = rules.shard(eo, "act_batch", "expert", None, "act_e_embed")
    eo = jnp.concatenate([eo.reshape(B, E * C, D),
                          jnp.zeros((B, 1, D), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(eo, slot[..., None], axis=1)  # [B,S*K,D]
    w = (gates.reshape(B, S * K) * ok.astype(x.dtype))[..., None]
    out = jnp.sum((gathered * w).reshape(B, S, K, D), axis=2)

    if m.n_shared_experts:
        sp = params["shared"]
        h = jax.nn.silu(x @ sp["w_gate"].astype(x.dtype)) * \
            (x @ sp["w_up"].astype(x.dtype))
        h = rules.shard(h, "act_batch", "act_seq", "act_mlp")
        out = out + h @ sp["w_down"].astype(x.dtype)

    # --- paper Eq. (1): influence update from realized loads -------------
    load = jnp.sum(onehot.astype(jnp.float32), axis=(0, 1))      # [E]
    stats = {"dropped_frac": 1.0 - jnp.mean(ok.astype(jnp.float32)),
             "load_imbalance": jnp.max(load) / (K * B * S / E) - 1.0}
    new_infl = None
    if m.router == "balanced_kmeans":
        target = K * B * S / E
        new_infl, _ = adapt_influence(infl, load, target, m.router_d_eff,
                                      m.router_influence_clip)
        # only influence *ratios* matter; renormalize to geometric mean 1
        # so the state cannot drift out of float range over long runs
        new_infl = new_infl * jnp.exp(-jnp.mean(jnp.log(
            jnp.maximum(new_infl, 1e-12))))
    return rules.shard(out, "act_batch", "act_res_seq", "act_embed"), new_infl, stats
