"""Distributed evaluation subsystem (DESIGN.md §9).

In-graph sharded quality metrics — the evaluation-layer counterpart of
the sharded solver path — plus the paper-style experiment harness that
reproduces the §5 method-vs-method comparison matrix:

    from repro.eval import ShardedGraph, evaluate_sharded

    prob = PartitionProblem.from_mesh(mesh, k=64)
    res = partition(prob, devices=8)
    evaluate_sharded(prob, res.labels, devices=8)   # == res.evaluate()

    from repro.eval.experiments import run_matrix   # §5 tables analogue
"""
from .sharded import (ShardedGraph, boundary_nodes_sharded,
                      comm_volume_sharded, edge_cut_sharded,
                      evaluate_sharded)

__all__ = [
    "ShardedGraph", "edge_cut_sharded", "comm_volume_sharded",
    "boundary_nodes_sharded", "evaluate_sharded",
]
