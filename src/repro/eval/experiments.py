"""Paper-style experiment harness (§5 comparison matrix).

The paper's headline claims are comparative: Geographer beats geometric
Zoltan partitioners on cut and communication volume across a zoo of
meshes. This module reproduces that method-vs-method matrix end to end:
every registered partitioning method × the expanded mesh zoo, each cell
evaluated with the *distributed* metric subsystem (``repro.eval.sharded``
— bit-for-bit equal to host numpy, so the matrix scales with the solver
layer instead of capping out at replicated-CSR sizes).

``benchmarks/experiments.py`` is the CLI wrapper that prints the tables
and emits the ``BENCH_experiments.json`` regression file;
``tools/bench_compare.py compare_experiments`` gates the paper trend
(geographer ≤ sfc/rcb on comm volume, geomean over the zoo) in CI.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import meshes as MESH
from repro.partition import (PartitionProblem, available_methods, factor_k,
                             partition, refine, refiner_short_name)

from .sharded import ShardedGraph, evaluate_sharded

# The §5 zoo: FEM grid, adaptively-refined 2D + larger 3D, anisotropic
# stretched grid, power-law-weighted rgg, 2.5D weighted climate mesh.
# Values are per-family point-count multipliers (the 3D refined family
# runs larger, as in the paper's hugetric-vs-delaunay3d size split).
EXPERIMENT_FAMILIES: dict[str, float] = {
    "tri": 1.0,
    "refined2d": 1.0,
    "refined3d": 2.0,
    "aniso": 1.0,
    "rggpow": 1.0,
    "climate25d": 1.0,
}

#: metrics gated / summarized per cell (lower is better for all three)
CELL_METRICS = ("cut", "maxCommVol", "totalCommVol")


def experiment_methods() -> list[str]:
    """Every registered flat method plus the hierarchical k1xk2 mode."""
    return available_methods() + ["hierarchical"]


def _geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    if xs.size == 0:
        return 0.0
    return float(np.exp(np.mean(np.log(xs))))


def run_cell(problem: PartitionProblem, method: str, eval_devices: int,
             graph: ShardedGraph | None = None,
             refiner: str | None = None) -> list[dict]:
    """One (mesh, method) cell: partition + sharded evaluation, plus —
    when ``refiner`` is set — the refined sibling row over the same
    solve (the post-pass runs *sharded* over ``eval_devices``, reusing
    the evaluation graph's layout; bit-for-bit equal to the host
    reference).

    Args:
        problem: the instance to cut (must carry a CSR graph).
        method: a registry name, or ``"hierarchical"`` for the k1xk2 mode.
        eval_devices: shard count for the metric evaluation (and the
            refinement pass).
        graph: optional pre-built ``ShardedGraph`` (reuse across the
            methods sharing one mesh).
        refiner: refinement registry name (e.g. ``"label_prop"``), or
            None for the base row only.

    Returns:
        Row dicts: the base row, then (if ``refiner``) the refined row —
        ``tool`` suffixed (``"sfc+lp"``), ``refined=True``,
        ``base_tool`` naming the sibling.
    """
    t0 = time.perf_counter()
    if method == "hierarchical":
        res = partition(problem, hierarchy=factor_k(problem.k))
    else:
        res = partition(problem, method=method)
    t_part = time.perf_counter() - t0
    t0 = time.perf_counter()
    ev = evaluate_sharded(problem, res.labels, eval_devices, graph=graph)
    t_eval = time.perf_counter() - t0
    row = dict(ev)
    row.update(tool=method, graph=problem.name, n=problem.n, k=problem.k,
               balanced=bool(ev["imbalance"] <= problem.epsilon + 1e-6),
               refined=False, base_tool=method, time_refine_s=0.0,
               time_partition_s=t_part, time_eval_s=t_eval)
    rows = [row]
    if refiner is not None:
        t0 = time.perf_counter()
        ref = refine(problem, res, refiner, devices=eval_devices,
                     graph=graph)
        t_ref = time.perf_counter() - t0
        t0 = time.perf_counter()
        ev_r = evaluate_sharded(problem, ref.labels, eval_devices,
                                graph=graph)
        t_eval_r = time.perf_counter() - t0
        rrow = dict(ev_r)
        st = ref.stats["refine"]
        rrow.update(tool=f"{method}+{refiner_short_name(refiner)}",
                    graph=problem.name, n=problem.n, k=problem.k,
                    balanced=bool(
                        ev_r["imbalance"] <= problem.epsilon + 1e-6),
                    refined=True, base_tool=method,
                    refine_rounds=st["rounds"], refine_moves=st["moves"],
                    refine_converged=st["converged"],
                    time_refine_s=t_ref, time_partition_s=t_part,
                    time_eval_s=t_eval_r)
        rows.append(rrow)
    return rows


def run_matrix(n: int, k: int, families=None, methods=None,
               eval_devices: int | None = None, seed: int = 0,
               epsilon: float = 0.03, quick: bool = False,
               refiner: str | None = "label_prop") -> dict:
    """The full method × mesh-zoo comparison matrix (each cell with its
    label-propagation-refined sibling row).

    Args:
        n: base point count (scaled per family by ``EXPERIMENT_FAMILIES``).
        k: block count.
        families: mesh-family subset (default: the whole zoo).
        methods: method subset (default: every registered method +
            hierarchical).
        eval_devices: shard count for metric evaluation; None picks
            ``min(4, visible jax devices)``.
        seed: mesh + permutation seed.
        epsilon: balance slack for every cell.
        quick: recorded in the output (CI commensurability check).
        refiner: refinement pass for the sibling rows (None skips them —
            rows then halve, and the refined summaries are empty).

    Returns:
        dict with ``rows`` (two per cell: base + refined), ``summary``
        (``geo_over_tool`` per-tool geomean ratios of geographer's
        metrics over the tool's — < 1 means geographer wins —
        ``geo_refined_over_tool`` with refined geographer in the
        numerator, and ``refined_over_unrefined`` per-tool refinement
        gains) and the config echo.
    """
    import jax
    if eval_devices is None:
        eval_devices = min(4, len(jax.devices()))
    families = dict(EXPERIMENT_FAMILIES) if families is None else {
        f: EXPERIMENT_FAMILIES.get(f, 1.0) for f in families}
    methods = experiment_methods() if methods is None else list(methods)

    rows = []
    for fam, scale in families.items():
        mesh = MESH.REGISTRY[fam](int(n * scale), seed=seed)
        problem = PartitionProblem.from_mesh(mesh, k, epsilon=epsilon,
                                             seed=seed)
        graph = ShardedGraph.from_problem(problem, eval_devices)
        for method in methods:
            for row in run_cell(problem, method, eval_devices,
                                graph=graph, refiner=refiner):
                row["family"] = fam
                rows.append(row)

    # paper-trend summary: geographer's metric / tool's metric, geomean
    # over the zoo (< 1.0 = geographer better, the §5 claim for comm
    # volume vs the Zoltan-style geometric baselines)
    by_cell = {(r["family"], r["tool"]): r for r in rows}
    suffix = "" if refiner is None else f"+{refiner_short_name(refiner)}"

    def _tool_ratios(num_tool: str, den_tool: str) -> dict:
        ratios = {}
        for met in CELL_METRICS:
            rs = []
            for fam in families:
                num = by_cell.get((fam, num_tool))
                den = by_cell.get((fam, den_tool))
                if num and den and den[met] > 0:
                    rs.append(num[met] / den[met])
            ratios[met] = _geomean(rs)
        return ratios

    summary: dict[str, dict] = {"geo_over_tool": {},
                                "geo_refined_over_tool": {},
                                "refined_over_unrefined": {}}
    for tool in methods:
        if tool != "geographer":
            summary["geo_over_tool"][tool] = _tool_ratios("geographer",
                                                          tool)
            if refiner is not None:
                # refined geographer vs the *unrefined* baselines: the
                # tightened paper-trend claim the CI gate enforces
                summary["geo_refined_over_tool"][tool] = _tool_ratios(
                    f"geographer{suffix}", tool)
        if refiner is not None:
            summary["refined_over_unrefined"][tool] = _tool_ratios(
                f"{tool}{suffix}", tool)
    summary["all_balanced"] = bool(all(r["balanced"] for r in rows))
    # baseline tools may legitimately bust epsilon on stress families
    # (e.g. quantile-cut sfc on power-law weights); geographer must not —
    # refined or not
    summary["geographer_all_balanced"] = bool(all(
        r["balanced"] for r in rows if r["base_tool"] == "geographer"))
    # refinement must never worsen balance: every refined row stays
    # within max(its sibling's imbalance, epsilon) — an unbalanced
    # baseline input (sfc on power-law weights) is not the refiner's to
    # fix, but it must not get worse
    summary["refined_imbalance_ok"] = bool(all(
        r["imbalance"] <= max(
            by_cell[(r["family"], r["base_tool"])]["imbalance"],
            epsilon) + 1e-9
        for r in rows if r["refined"]))

    return {"schema": 2, "quick": bool(quick), "n": n, "k": k,
            "epsilon": epsilon, "seed": seed,
            "eval_devices": int(eval_devices),
            "refiner": refiner,
            "families": sorted(families), "methods": sorted(methods),
            "rows": rows, "summary": summary}
