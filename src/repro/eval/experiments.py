"""Paper-style experiment harness (§5 comparison matrix).

The paper's headline claims are comparative: Geographer beats geometric
Zoltan partitioners on cut and communication volume across a zoo of
meshes. This module reproduces that method-vs-method matrix end to end:
every registered partitioning method × the expanded mesh zoo, each cell
evaluated with the *distributed* metric subsystem (``repro.eval.sharded``
— bit-for-bit equal to host numpy, so the matrix scales with the solver
layer instead of capping out at replicated-CSR sizes).

``benchmarks/experiments.py`` is the CLI wrapper that prints the tables
and emits the ``BENCH_experiments.json`` regression file;
``tools/bench_compare.py compare_experiments`` gates the paper trend
(geographer ≤ sfc/rcb on comm volume, geomean over the zoo) in CI.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import meshes as MESH
from repro.partition import (PartitionProblem, available_methods, factor_k,
                             partition)

from .sharded import ShardedGraph, evaluate_sharded

# The §5 zoo: FEM grid, adaptively-refined 2D + larger 3D, anisotropic
# stretched grid, power-law-weighted rgg, 2.5D weighted climate mesh.
# Values are per-family point-count multipliers (the 3D refined family
# runs larger, as in the paper's hugetric-vs-delaunay3d size split).
EXPERIMENT_FAMILIES: dict[str, float] = {
    "tri": 1.0,
    "refined2d": 1.0,
    "refined3d": 2.0,
    "aniso": 1.0,
    "rggpow": 1.0,
    "climate25d": 1.0,
}

#: metrics gated / summarized per cell (lower is better for all three)
CELL_METRICS = ("cut", "maxCommVol", "totalCommVol")


def experiment_methods() -> list[str]:
    """Every registered flat method plus the hierarchical k1xk2 mode."""
    return available_methods() + ["hierarchical"]


def _geomean(xs) -> float:
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    if xs.size == 0:
        return 0.0
    return float(np.exp(np.mean(np.log(xs))))


def run_cell(problem: PartitionProblem, method: str, eval_devices: int,
             graph: ShardedGraph | None = None) -> dict:
    """One (mesh, method) cell: partition + sharded evaluation.

    Args:
        problem: the instance to cut (must carry a CSR graph).
        method: a registry name, or ``"hierarchical"`` for the k1xk2 mode.
        eval_devices: shard count for the metric evaluation.
        graph: optional pre-built ``ShardedGraph`` (reuse across the
            methods sharing one mesh).

    Returns:
        Row dict: tool, quality metrics, wall times.
    """
    t0 = time.perf_counter()
    if method == "hierarchical":
        res = partition(problem, hierarchy=factor_k(problem.k))
    else:
        res = partition(problem, method=method)
    t_part = time.perf_counter() - t0
    t0 = time.perf_counter()
    ev = evaluate_sharded(problem, res.labels, eval_devices, graph=graph)
    t_eval = time.perf_counter() - t0
    row = dict(ev)
    row.update(tool=method, graph=problem.name, n=problem.n, k=problem.k,
               balanced=bool(ev["imbalance"] <= problem.epsilon + 1e-6),
               time_partition_s=t_part, time_eval_s=t_eval)
    return row


def run_matrix(n: int, k: int, families=None, methods=None,
               eval_devices: int | None = None, seed: int = 0,
               epsilon: float = 0.03, quick: bool = False) -> dict:
    """The full method × mesh-zoo comparison matrix.

    Args:
        n: base point count (scaled per family by ``EXPERIMENT_FAMILIES``).
        k: block count.
        families: mesh-family subset (default: the whole zoo).
        methods: method subset (default: every registered method +
            hierarchical).
        eval_devices: shard count for metric evaluation; None picks
            ``min(4, visible jax devices)``.
        seed: mesh + permutation seed.
        epsilon: balance slack for every cell.
        quick: recorded in the output (CI commensurability check).

    Returns:
        dict with ``rows`` (one per cell), ``summary`` (per-tool geomean
        ratios of geographer's metrics over the tool's — < 1 means
        geographer wins) and the config echo.
    """
    import jax
    if eval_devices is None:
        eval_devices = min(4, len(jax.devices()))
    families = dict(EXPERIMENT_FAMILIES) if families is None else {
        f: EXPERIMENT_FAMILIES.get(f, 1.0) for f in families}
    methods = experiment_methods() if methods is None else list(methods)

    rows = []
    for fam, scale in families.items():
        mesh = MESH.REGISTRY[fam](int(n * scale), seed=seed)
        problem = PartitionProblem.from_mesh(mesh, k, epsilon=epsilon,
                                             seed=seed)
        graph = ShardedGraph.from_problem(problem, eval_devices)
        for method in methods:
            row = run_cell(problem, method, eval_devices, graph=graph)
            row["family"] = fam
            rows.append(row)

    # paper-trend summary: geographer's metric / tool's metric, geomean
    # over the zoo (< 1.0 = geographer better, the §5 claim for comm
    # volume vs the Zoltan-style geometric baselines)
    by_cell = {(r["family"], r["tool"]): r for r in rows}
    summary: dict[str, dict] = {"geo_over_tool": {}}
    for tool in methods:
        if tool == "geographer":
            continue
        ratios = {}
        for met in CELL_METRICS:
            rs = []
            for fam in families:
                geo = by_cell.get((fam, "geographer"))
                other = by_cell.get((fam, tool))
                if geo and other and other[met] > 0:
                    rs.append(geo[met] / other[met])
            ratios[met] = _geomean(rs)
        summary["geo_over_tool"][tool] = ratios
    summary["all_balanced"] = bool(all(r["balanced"] for r in rows))
    # baseline tools may legitimately bust epsilon on stress families
    # (e.g. quantile-cut sfc on power-law weights); geographer must not
    summary["geographer_all_balanced"] = bool(all(
        r["balanced"] for r in rows if r["tool"] == "geographer"))

    return {"schema": 1, "quick": bool(quick), "n": n, "k": k,
            "epsilon": epsilon, "seed": seed,
            "eval_devices": int(eval_devices),
            "families": sorted(families), "methods": sorted(methods),
            "rows": rows, "summary": summary}
