"""In-graph sharded quality metrics (paper §2 at §4.1 scale).

``core.metrics`` evaluates partitions with host numpy over a replicated
CSR graph, which caps the evaluation layer far below what the sharded
solver (``partition(problem, devices=P)``) can partition. This module is
the distributed counterpart: ``edge_cut`` / ``comm_volume`` /
``boundary_nodes`` computed under ``shard_map`` from a ``ShardedGraph`` —
the CSR companion of ``ShardedPartitionProblem``.

Layout. ``ShardedGraph`` deals the CSR rows onto the *same* seed-permuted
round-robin point layout the solver uses: the directed edges of the point
living at (shard p, slot s) become ``(src=s, dst=global neighbor id)``
entries of shard p's flat edge list, padded to a common per-shard cap
``ecap`` so shapes stay static. Padded slots (and padded edges) are
masked, exactly like the solver's weight-zero padding.

Communication. Every label a shard needs from its neighbors is resolved
by ONE global vector sum: each shard scatters its local labels into an
[n] zero vector at its own global positions and the psum of those
per-device partials IS the replicated label vector — no all_gather, no
point-to-point halo, the same "global sums over per-device partials"
discipline as the solver core (paper §4.1). The remaining collectives
are [k]-sized psums of per-device metric partials.

Exactness. All three metrics are integer counts, and integer additions
commute exactly — so the sharded metrics are **bit-for-bit equal** to the
numpy metrics at ``devices=1`` *and* at every device count (property
tested in tests/test_metrics_properties.py at P in {1, 2, 4, 8}).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.partition.distributed import ShardedPartitionProblem
from repro.partition.problem import PartitionProblem


@dataclass(frozen=True)
class ShardedGraph:
    """CSR adjacency dealt onto a ``ShardedPartitionProblem`` layout.

    Attributes:
        sharded: the point-layout companion (owns gather/valid and the
            source ``PartitionProblem``, which must carry a CSR graph).
        src: [P, ecap] int32 — local slot index of each directed edge's
            source (a valid slot of that shard).
        dst: [P, ecap] int64 — *global* point id of the edge's target
            (resolved against the psum'd label vector in-graph).
        edge_valid: [P, ecap] bool — False for padding entries.
    """
    sharded: ShardedPartitionProblem
    src: np.ndarray
    dst: np.ndarray
    edge_valid: np.ndarray

    @property
    def problem(self) -> PartitionProblem:
        return self.sharded.problem

    @property
    def devices(self) -> int:
        return self.sharded.devices

    @property
    def ecap(self) -> int:
        """Per-shard edge-slot count (max directed edges over shards)."""
        return self.src.shape[1]

    @classmethod
    def from_sharded(cls, sharded: ShardedPartitionProblem,
                     edge_cap: int | None = None) -> "ShardedGraph":
        """Deal the problem's CSR rows onto ``sharded``'s point layout.

        Args:
            sharded: an existing sharded view whose problem carries a CSR
                graph.
            edge_cap: per-shard edge-slot count ``ecap``. None sizes it
                to the max per-shard directed-edge count (the minimal
                valid cap). An explicit cap below that count is an
                error — a short slab would silently drop edges, which
                corrupts every metric downstream.

        Returns:
            The static-shape sharded graph.

        Raises:
            ValueError: the underlying problem has no CSR adjacency, or
                ``edge_cap`` is smaller than some shard's edge count.
        """
        prob = sharded.problem
        if not prob.has_graph:
            raise ValueError(
                "problem carries no CSR graph (indptr/indices); sharded "
                "graph metrics need one — build the PartitionProblem via "
                "from_mesh or pass indptr/indices")
        indptr = np.asarray(prob.indptr, np.int64)
        indices = np.asarray(prob.indices, np.int64)
        deg = np.diff(indptr)
        P = sharded.devices
        srcs, dsts, counts = [], [], []
        for p in range(P):
            slots = np.nonzero(sharded.valid[p])[0]
            g = sharded.gather[p][slots]               # global ids, this shard
            dg = deg[g]
            tot = int(dg.sum())
            counts.append(tot)
            row = np.repeat(np.arange(len(g)), dg)
            # within-row offsets: position minus the start of its row
            within = np.arange(tot) - np.repeat(
                np.concatenate([[0], np.cumsum(dg)[:-1]]), dg)
            dsts.append(indices[indptr[g][row] + within])
            srcs.append(slots[row].astype(np.int32))
        need = max(max(counts), 1)                     # >= 1: no 0-size slabs
        if edge_cap is None:
            ecap = need
        else:
            ecap = int(edge_cap)
            if ecap < need:
                raise ValueError(
                    f"edge_cap={ecap} is smaller than the largest "
                    f"per-shard directed-edge count {need}; a short edge "
                    "slab would silently truncate edges — pass "
                    f"edge_cap >= {need} (or None to size automatically)")
        src = np.zeros((P, ecap), np.int32)
        dst = np.zeros((P, ecap), np.int64)
        valid = np.zeros((P, ecap), bool)
        for p in range(P):
            src[p, :counts[p]] = srcs[p]
            dst[p, :counts[p]] = dsts[p]
            valid[p, :counts[p]] = True
        return cls(sharded=sharded, src=src, dst=dst, edge_valid=valid)

    @classmethod
    def from_problem(cls, problem: PartitionProblem, devices: int,
                     edge_cap: int | None = None) -> "ShardedGraph":
        """Shard ``problem``'s points *and* graph over ``devices`` shards
        (convenience for ``from_sharded(problem.to_sharded(devices))``)."""
        return cls.from_sharded(
            ShardedPartitionProblem.from_problem(problem, devices),
            edge_cap=edge_cap)


@functools.lru_cache(maxsize=64)
def _build_metrics_fn(devices: int, cap: int, ecap: int, n: int, k: int):
    """Compile-cached shard_map metric kernel for one shape combo.

    Returns a jitted fn(labels [P,cap] i32, gidx [P,cap] i64, lvalid
    [P,cap] bool, src [P,ecap] i32, dst [P,ecap] i64, evalid [P,ecap]
    bool) -> (cut2 scalar, comm_per_block [k], boundary_per_block [k])
    with every output replicated (already psum'd inside)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.rules import PARTITION_AXIS, partition_mesh

    mesh = partition_mesh(devices)
    axis = PARTITION_AXIS

    def local(labels, gidx, lvalid, src, dst, evalid):  # spmdlint: psum-budget=4
        labels = labels.reshape(cap)
        gidx = gidx.reshape(cap)
        lvalid = lvalid.reshape(cap)
        src = src.reshape(ecap)
        dst = dst.reshape(ecap)
        evalid = evalid.reshape(ecap)
        # halo resolution as ONE global vector sum: every global position
        # is owned by exactly one (shard, valid slot), all other shards
        # contribute zero — the psum of the partials is the full label
        # vector (label 0 works because non-owners add 0, owners add the
        # label itself)
        partial = jnp.zeros(n, jnp.int32).at[gidx].add(
            jnp.where(lvalid, labels, 0))
        glabels = jax.lax.psum(partial, axis)
        nb = glabels[dst]                       # [ecap] neighbor block
        mine = labels[src]                      # [ecap] own block
        is_cut = evalid & (nb != mine)
        cut2 = jax.lax.psum(jnp.sum(is_cut.astype(jnp.int32)), axis)
        # distinct (local slot, remote block) pairs via a [cap, k]
        # scatter-or table — the in-graph unique-per-row
        table = jnp.zeros((cap, k), bool).at[src, nb].max(is_cut)
        per_node = jnp.sum(table, axis=1)       # [cap] #remote blocks
        comm = jax.lax.psum(
            jnp.zeros(k, jnp.int32).at[labels].add(
                jnp.where(lvalid, per_node, 0)), axis)
        bnd = jax.lax.psum(
            jnp.zeros(k, jnp.int32).at[labels].add(
                (lvalid & (per_node > 0)).astype(jnp.int32)), axis)
        return cut2, comm, bnd

    inner = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(), P(), P()),
        check_rep=False)
    return jax.jit(inner)


def _run_metrics(graph: ShardedGraph, labels: np.ndarray):
    """Run the shard_map kernel; returns host (cut, comm_pb, bnd_pb).

    The kernel computes all three metrics in one pass, and the last
    (labels, result) pair is memoized on the graph — so the natural
    pattern of calling ``edge_cut_sharded`` / ``comm_volume_sharded`` /
    ``boundary_nodes_sharded`` back to back on one labeling costs one
    device round trip, not three."""
    import jax
    import jax.numpy as jnp

    sp = graph.sharded
    labels = np.asarray(labels)
    if labels.shape != (sp.problem.n,):
        raise ValueError(f"labels must be [{sp.problem.n}], "
                         f"got {labels.shape}")
    key = labels.astype(np.int32, copy=False).tobytes()
    cached = getattr(graph, "_memo", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    fn = _build_metrics_fn(sp.devices, sp.cap, graph.ecap, sp.problem.n,
                           sp.problem.k)
    cut2, comm, bnd = fn(jnp.asarray(sp.deal(labels.astype(np.int32))),
                         jnp.asarray(sp.gather.astype(np.int32)),
                         jnp.asarray(sp.valid),
                         jnp.asarray(graph.src),
                         jnp.asarray(graph.dst.astype(np.int32)),
                         jnp.asarray(graph.edge_valid))
    cut2, comm, bnd = jax.device_get((cut2, comm, bnd))
    result = (int(cut2) // 2, np.asarray(comm, np.int64),
              np.asarray(bnd, np.int64))
    object.__setattr__(graph, "_memo", (key, result))   # frozen dataclass
    return result


def edge_cut_sharded(graph: ShardedGraph, labels: np.ndarray) -> int:
    """Distributed edge cut — equals ``metrics.edge_cut`` exactly.

    Args:
        graph: the sharded CSR view.
        labels: [n] block ids in original point order.

    Returns:
        #undirected edges with endpoints in different blocks.
    """
    return _run_metrics(graph, labels)[0]


def comm_volume_sharded(graph: ShardedGraph,
                        labels: np.ndarray) -> tuple[int, int, np.ndarray]:
    """Distributed communication volume — equals ``metrics.comm_volume``
    exactly.

    Args:
        graph: the sharded CSR view.
        labels: [n] block ids in original point order.

    Returns:
        (max_comm, total_comm, per_block_comm [k]).
    """
    _, comm, _ = _run_metrics(graph, labels)
    return int(comm.max(initial=0)), int(comm.sum()), comm


def boundary_nodes_sharded(graph: ShardedGraph,
                           labels: np.ndarray) -> tuple[int, np.ndarray]:
    """Distributed boundary-node count — equals ``metrics.boundary_nodes``
    exactly.

    Args:
        graph: the sharded CSR view.
        labels: [n] block ids in original point order.

    Returns:
        (total, per_block [k]) boundary-vertex counts.
    """
    _, _, bnd = _run_metrics(graph, labels)
    return int(bnd.sum()), bnd


def evaluate_sharded(problem: PartitionProblem, labels: np.ndarray,
                     devices: int,
                     graph: ShardedGraph | None = None) -> dict:
    """The paper's §2 metric set, graph metrics computed in-graph over
    ``devices`` shards — drop-in for ``metrics.evaluate_problem`` when the
    problem carries a CSR graph (identical keys and values; balance
    metrics stay host-side numpy, they need no graph).

    Args:
        problem: the partitioning instance (must carry indptr/indices).
        labels: [n] block ids in original point order.
        devices: shard count P (1 <= P <= min(n, jax device count)).
        graph: optional pre-built ``ShardedGraph`` to reuse across calls
            (e.g. one mesh evaluated for many methods); must match
            ``problem`` and ``devices``.

    Returns:
        dict with ``imbalance`` / ``n_blocks_used`` / ``cut`` /
        ``maxCommVol`` / ``totalCommVol`` / ``boundaryNodes``.
    """
    from repro.core import metrics

    if graph is None:
        graph = ShardedGraph.from_problem(problem, devices)
    elif graph.problem is not problem or graph.devices != devices:
        raise ValueError("graph was built for a different problem/devices")
    labels = np.asarray(labels)
    cut, comm, bnd = _run_metrics(graph, labels)
    return {
        "imbalance": metrics.imbalance(labels, problem.k, problem.weights),
        "n_blocks_used": int(len(np.unique(labels))),
        "cut": cut,
        "maxCommVol": int(comm.max(initial=0)),
        "totalCommVol": int(comm.sum()),
        "boundaryNodes": int(bnd.sum()),
    }
