from .pipeline import SyntheticLM, Prefetcher, sfc_batch_order
