"""Data pipeline: synthetic token/embedding streams + SFC-locality ordering.

Everything is deterministic given a seed, infinite, and host-side numpy
(the trainer overlaps host batch production with device compute through a
one-deep prefetch queue — the standard straggler hide for input pipelines).

The paper's Hilbert-sort redistribution reappears here as
``sfc_batch_order``: examples with spatial/embedding coordinates are
ordered along a Hilbert curve so that consecutive microbatches touch
nearby data (better cache/page locality for geometric workloads, and the
canonical input layout the partitioner expects).
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.core.sfc import hilbert_index_np


class SyntheticLM:
    """Markov-chain token stream — cheap, deterministic, learnable.

    Tokens follow ``t' = (a * t + b + eta) mod V`` with small noise, so a
    model can reduce loss well below uniform entropy within a few hundred
    steps (used by examples/train_*.py to show real learning curves).
    """

    def __init__(self, cfg, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self._epoch = 0

    def _tokens(self, rng, shape):
        V = self.cfg.vocab_size
        a, b = 31, 7
        t = rng.integers(0, V, size=shape[:-1] + (1,))
        cols = [t]
        for _ in range(shape[-1] - 1):
            noise = rng.integers(0, 3, size=t.shape)
            t = (a * t + b + noise) % V
            cols.append(t)
        return np.concatenate(cols, axis=-1).astype(np.int32)

    def __iter__(self):
        i = 0
        while True:
            rng = np.random.default_rng((self.seed, i))
            cfg = self.cfg
            B, S = self.batch, self.seq
            if cfg.input_mode == "tokens":
                toks = self._tokens(rng, (B, S + 1))
                batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            elif cfg.input_mode == "codebooks":
                toks = np.stack([self._tokens(rng, (B, S + 1))
                                 for _ in range(cfg.n_codebooks)], axis=-1)
                batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            else:  # embeddings (modality stub): random patch embeddings
                emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
                lab = self._tokens(rng, (B, S))
                batch = {"embeddings": emb, "labels": lab}
            yield batch
            i += 1


def sfc_batch_order(coords: np.ndarray, batch: int) -> np.ndarray:
    """Order examples along a Hilbert curve; returns the permutation.

    ``coords``: [n, d] (d in {2,3}) per-example coordinates (spatial
    position for mesh data, projected embeddings for documents).
    Consecutive windows of ``batch`` indices form spatially compact batches
    — the paper's locality argument applied to the input pipeline.
    """
    keys = hilbert_index_np(coords)
    order = np.argsort(keys, kind="stable")
    n_full = (len(order) // batch) * batch
    return order[:n_full].reshape(-1, batch), order[n_full:]


class Prefetcher:
    """One-deep background prefetch: hides host batch production behind
    device compute (straggler mitigation for the input side)."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for x in self._it:
                self._q.put(x)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
