"""Process-environment knobs that must be set before the first jax import.

Deliberately imports nothing heavy (``repro`` is a namespace package, so
``import repro.envflags`` pulls no jax): tests/conftest.py,
benchmarks/run.py and the examples all call ``force_virtual_devices``
first thing, before any module that imports jax.
"""
from __future__ import annotations

import os

_COUNT_FLAG = "--xla_force_host_platform_device_count"


def force_virtual_devices(n: int = 8, override: bool = False) -> None:
    """Expose ``n`` virtual CPU devices via ``XLA_FLAGS``.

    Appends to operator-set flags instead of clobbering them. An existing
    device-count flag wins unless ``override=True`` (which replaces only
    that flag and keeps the rest). Has no effect on processes that
    already imported jax — call this before the first jax import.
    """
    cur = os.environ.get("XLA_FLAGS", "")
    if _COUNT_FLAG in cur:
        if not override:
            return
        cur = " ".join(p for p in cur.split() if not p.startswith(_COUNT_FLAG))
    os.environ["XLA_FLAGS"] = f"{cur} {_COUNT_FLAG}={n}".strip()
