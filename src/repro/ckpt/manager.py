"""Sharded, manifest-driven, atomic checkpointing with elastic restore.

Design (DESIGN.md §7):

* a checkpoint is a directory ``step_<n>/`` holding one ``.npy`` file per
  pytree leaf plus ``manifest.json`` (tree structure, shapes, dtypes,
  crc32 per leaf, step). The manifest is written LAST and the directory is
  created under a ``tmp.`` name and atomically renamed — a crash mid-write
  can never produce a directory that looks complete;
* restore validates checksums, rebuilds the pytree, and ``device_put``s
  each leaf with the *current* sharding — checkpoints store logical
  arrays, not device layouts, so restoring onto a different mesh shape
  (elastic shrink/grow after node failure) is the same code path;
* ``keep_n`` garbage collection; optional async save (state is snapshotted
  to host synchronously, the file writes happen on a worker thread so the
  train loop resumes immediately).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._worker: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state) -> None:
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()                      # one in-flight save at a time
        if self.async_save:
            self._worker = threading.Thread(
                target=self._write, args=(step, host, treedef), daemon=True)
            self._worker.start()
        else:
            self._write(step, host, treedef)

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, host_leaves, treedef) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = os.path.join(self.dir, f"tmp.step_{step:09d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        entries = []
        for i, arr in enumerate(host_leaves):
            fn = _leaf_name(i)
            np.save(os.path.join(tmp, fn), arr)
            entries.append({"file": fn, "shape": list(arr.shape),
                            "dtype": str(arr.dtype),
                            "crc": zlib.crc32(np.ascontiguousarray(arr)
                                              .tobytes())})
        manifest = {"step": step, "n_leaves": len(host_leaves),
                    "treedef": str(treedef), "leaves": entries}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: int | None = None,
                shardings=None, strict_crc: bool = True):
        """Rebuild ``state_like``'s pytree from disk.

        ``shardings``: optional pytree (matching state) of NamedSharding —
        leaves are device_put with them, which is how a checkpoint written
        on one mesh is resharded onto another (elastic restore).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = _flatten(state_like)
        if len(leaves_like) != manifest["n_leaves"]:
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"state has {len(leaves_like)}")
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        out = []
        for i, (like, entry) in enumerate(zip(leaves_like,
                                              manifest["leaves"])):
            arr = np.load(os.path.join(path, entry["file"]))
            if strict_crc and zlib.crc32(
                    np.ascontiguousarray(arr).tobytes()) != entry["crc"]:
                raise IOError(f"crc mismatch in {entry['file']} @ step {step}")
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"shape mismatch leaf {i}: "
                                 f"{arr.shape} vs {like.shape}")
            out.append(jax.device_put(arr, shard_leaves[i])
                       if shard_leaves[i] is not None else
                       jax.device_put(arr))
        return treedef.unflatten(out), step
