from .manager import CheckpointManager
