"""Logical-axis sharding rules (DESIGN.md §6).

Model / serving / training code never mentions mesh axes directly; every
tensor is annotated with *logical* axis names (``act_batch``, ``act_mlp``,
``embed``, ``expert``, ...) and a ``Rules`` object resolves those names to
mesh axes (or ``None`` = replicated) per phase:

* ``resolve_rules(mesh, cfg, phase)`` builds the table for a phase in
  {"train", "prefill", "decode", "long_decode"} — batch data-parallel over
  ``data`` (+ ``pod`` when present), tensor-parallel over ``model`` for
  heads / mlp / experts / vocab, FSDP-style parameter sharding in train.
* ``rules.shard(x, *logical)`` applies a ``with_sharding_constraint``;
  unknown / ``None`` names mean replicated, and any logical axis whose mesh
  extent does not divide the tensor dimension falls back to replicated so
  the same annotations run on a 1x1 host mesh and a 16x16 pod.
* ``param_shardings(rules, logical_specs)`` maps a pytree of logical-axis
  tuples (``models.model.param_logical_specs``) to ``NamedSharding``s for
  ``jax.jit`` in/out shardings.

Per-arch overrides come from ``configs.sharding_overrides(arch, mode)``
({logical: mesh_axes}) and are merged last.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh axis aliases
_DATA = "data"
_MODEL = "model"
_POD = "pod"

# axis name of the 1-D point-sharding mesh used by the distributed
# geometric partitioner (repro.partition.distributed)
PARTITION_AXIS = "shard"

# axis names of the 2-D hierarchical-partitioner mesh: the coarse k1-way
# cut shards its points over the *product* of both axes (so it is
# bit-identical to the flat 1-D run over P1*P2 devices — a psum over
# ("coarse", "refine") reduces in the same flattened device order), and
# the k1 refinement blocks then batch over REFINE_AXIS alone
COARSE_AXIS = "coarse"
REFINE_AXIS = "refine"


def partition_mesh(devices: int | None = None,
                   axis_name: str = PARTITION_AXIS) -> Mesh:
    """1-D device mesh for the sharded partitioner: points/weights live on
    ``axis_name``, centers/influence are replicated.

    ``devices=None`` spans every visible device; an int takes the first
    ``devices`` of ``jax.devices()``. CPU hosts grow virtual devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before the
    first jax import — tests/conftest.py and the CI workflow both do).
    """
    avail = jax.devices()
    n = len(avail) if devices is None else int(devices)
    if not 1 <= n <= len(avail):
        raise ValueError(
            f"devices={devices} out of range: {len(avail)} visible jax "
            f"device(s); on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={devices} before the "
            f"first jax import")
    return Mesh(np.asarray(avail[:n]), (axis_name,))


def partition_mesh2d(p1: int, p2: int) -> Mesh:
    """2-D ``(COARSE_AXIS, REFINE_AXIS)`` device mesh for the hierarchical
    sharded partitioner: the first ``p1 * p2`` visible devices reshaped to
    ``[p1, p2]``, row-major.

    The flattened device order equals ``partition_mesh(p1 * p2)``'s, which
    is what makes the coarse pass (sharded over the axis *product*)
    bit-identical to the flat 1-D run — same partial-sum placement, same
    psum reduction order.
    """
    p1, p2 = int(p1), int(p2)
    if p1 < 1 or p2 < 1:
        raise ValueError(f"mesh extents must be >= 1, got ({p1}, {p2})")
    avail = jax.devices()
    if p1 * p2 > len(avail):
        raise ValueError(
            f"devices=({p1}, {p2}) needs {p1 * p2} devices but only "
            f"{len(avail)} are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={p1 * p2} before the "
            f"first jax import")
    return Mesh(np.asarray(avail[:p1 * p2]).reshape(p1, p2),
                (COARSE_AXIS, REFINE_AXIS))


def _batch_axes(mesh: Mesh):
    if _POD in mesh.axis_names:
        return (_POD, _DATA)
    return _DATA


def _default_table(mesh: Mesh, phase: str) -> dict:
    batch = _batch_axes(mesh)
    table: dict[str, Any] = {
        # --- activations
        "act_batch": batch,
        "act_seq": None,            # flash path q-chunks when seq unsharded
        "act_res_seq": None,        # residual-stream sequence axis
        "logits_seq": None,
        "act_embed": None,
        "act_mlp": _MODEL,
        "act_heads": _MODEL,
        "act_kv": _MODEL,
        "act_vocab": _MODEL,
        "act_e_embed": None,
        # --- caches
        "cache_seq": None,
        "cache_kv": _MODEL,
        # --- params
        "repeat": None,             # stacked-layer leading axis
        "nil": None,
        "embed": _DATA if phase == "train" else None,   # FSDP in train
        "mlp": _MODEL,
        "heads": _MODEL,
        "heads_joined": _MODEL,
        "kv_heads": _MODEL,
        "head_dim": None,
        "vocab": _MODEL,
        "rank": None,
        "state": None,
        "conv": None,
        "expert": _MODEL,
        "e_embed": None,
        "e_mlp": None,
        "codebooks": None,
    }
    return table


def _axis_extent(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    ext = 1
    for a in axes:
        ext *= mesh.shape[a]
    return ext


@dataclasses.dataclass(frozen=True)
class Rules:
    """Resolved logical->mesh table for one (mesh, config, phase)."""
    mesh: Mesh
    table: Mapping[str, Any]
    phase: str = "train"

    def spec(self, *logical) -> P:
        """PartitionSpec for a tuple of logical axis names (None entries and
        unknown names are replicated)."""
        return P(*[self.table.get(name) if name is not None else None
                   for name in logical])

    def sharding(self, logical) -> NamedSharding:
        """NamedSharding for a logical-axis tuple (e.g. a param spec)."""
        return NamedSharding(self.mesh, self.spec(*logical))

    def shard(self, x, *logical):
        """Constrain ``x`` to the resolved sharding. Logical names must
        match ``x.ndim``; axes whose mesh extent does not divide the
        corresponding dimension are dropped (replicated) so the same code
        runs on any mesh."""
        names = list(logical)
        assert len(names) == x.ndim, (
            f"{len(names)} logical names for rank-{x.ndim} tensor")
        resolved = []
        for dim, name in zip(x.shape, names):
            axes = self.table.get(name) if name is not None else None
            ext = _axis_extent(self.mesh, axes)
            resolved.append(axes if ext > 1 and dim % ext == 0 else None)
        if all(r is None for r in resolved):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*resolved)))


def resolve_rules(mesh: Mesh, cfg, phase: str, batch_size: int | None = None,
                  overrides: Mapping[str, Any] | None = None) -> Rules:
    """Build the sharding rules for ``phase``.

    ``batch_size``: when given and not divisible by the batch-axis extent,
    batch data-parallelism is dropped (replicated batch) instead of failing
    at trace time. ``overrides``: {logical: mesh_axes} merged last (per-arch
    ``SHARDING_OVERRIDES`` from the config registry).
    """
    if phase not in ("train", "prefill", "decode", "long_decode"):
        raise ValueError(f"unknown phase {phase!r}")
    table = _default_table(mesh, phase)
    if batch_size is not None:
        ext = _axis_extent(mesh, table["act_batch"])
        if ext > 1 and batch_size % ext != 0:
            table["act_batch"] = None
    if overrides:
        table.update(overrides)
    # drop mesh axes the mesh does not have (e.g. "pod" overrides on a
    # single-pod mesh)
    names = set(mesh.axis_names)

    def known(axes):
        if axes is None:
            return None
        if isinstance(axes, str):
            return axes if axes in names else None
        kept = tuple(a for a in axes if a in names)
        return kept if kept else None

    table = {k: known(v) for k, v in table.items()}
    return Rules(mesh=mesh, table=table, phase=phase)


def param_shardings(rules: Rules, logical_specs):
    """Pytree of logical-axis tuples -> pytree of NamedShardings."""
    return jax.tree.map(rules.sharding, logical_specs,
                        is_leaf=lambda x: isinstance(x, tuple))
