from .rules import Rules, param_shardings, resolve_rules

__all__ = ["Rules", "param_shardings", "resolve_rules"]
