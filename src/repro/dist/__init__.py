from .rules import (PARTITION_AXIS, Rules, param_shardings, partition_mesh,
                    resolve_rules)

__all__ = ["PARTITION_AXIS", "Rules", "param_shardings", "partition_mesh",
           "resolve_rules"]
