"""Serving: single-token ``serve_step`` factory + a batched request engine.

``make_serve_step`` builds the jittable one-token decode used by the
decode_32k / long_500k dry-run cells: greedy next-token from the KV-cache
(or SSM-state) decode path, cache updated functionally. The KV cache is
sequence-sharded over ``model`` (and over everything for the batch=1
long-context cells) per dist/rules.py; attention against the sharded cache
becomes a distributed-LSE reduction that GSPMD lowers to an all-reduce.

``ServeEngine`` is a batched-request driver: requests are admitted into
fixed slots, prefill populates each slot's cache through the shared
position-aligned decode path, completed rows are masked and refilled —
static shapes throughout, which is what a TPU serving loop needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


def make_serve_step(cfg, rules, sample: str = "greedy",
                    unroll: bool = False):
    """Returns serve_step(params, cache, tokens, pos) ->
    (next_tokens [B,1(,n_codebooks)], new_cache, logits)."""

    def serve_step(params, cache, tokens, pos):
        if cfg.input_mode == "embeddings":
            batch = {"embeddings": tokens}     # [B,1,D] stub frontend
        else:
            batch = {"tokens": tokens}
        logits, new_cache = M.decode_step(params, cache, batch, pos, cfg,
                                          rules, unroll=unroll)
        lf = logits.astype(jnp.float32)
        if cfg.vocab_size < cfg.vocab_padded:
            pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
            lf = jnp.where(pad, -jnp.inf, lf)
        nxt = jnp.argmax(lf, axis=-1).astype(jnp.int32)
        return nxt, new_cache, logits

    return serve_step


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [P] (or [P, n_codebooks])
    max_new: int = 16
    eos_id: int | None = None
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Batched greedy decoding over fixed slots (static shapes).

    Rounds: admit up to B requests, right-align nothing — all slots share
    the step position; shorter prompts emit pad tokens that are masked out
    of their transcript. Decode proceeds until every admitted request hit
    ``max_new`` or EOS. This is static batching with per-row masking — the
    TPU-friendly core that continuous batching schedulers wrap.
    """

    def __init__(self, cfg, rules, params, batch: int, max_seq: int,
                 pad_id: int = 0):
        self.cfg = cfg
        self.rules = rules
        self.params = params
        self.B = batch
        self.max_seq = max_seq
        self.pad_id = pad_id
        self.step_fn = jax.jit(make_serve_step(cfg, rules),
                               donate_argnums=(1,))

    def _fresh_cache(self):
        return M.init_cache(self.cfg, self.B, self.max_seq, self.rules)

    def run(self, requests: list[Request]) -> list[Request]:
        cfg = self.cfg
        for base in range(0, len(requests), self.B):
            group = requests[base:base + self.B]
            self._run_group(group)
        return requests

    def _run_group(self, group: list) -> None:
        cfg = self.cfg
        B = self.B
        plens = [len(r.prompt) for r in group]
        pmax = max(plens)
        tok_shape = (B, pmax) if cfg.input_mode != "codebooks" else \
            (B, pmax, cfg.n_codebooks)
        toks = np.full(tok_shape, self.pad_id, np.int32)
        for i, r in enumerate(group):
            toks[i, :plens[i]] = r.prompt
        cache = self._fresh_cache()
        params = self.params
        # prefill by stepping the decode path over the prompt (cache fills
        # position by position; static shapes)
        assert pmax >= 1, "empty prompts unsupported"
        cur = None
        for p in range(pmax):
            cur, cache, _ = self.step_fn(params, cache,
                                         jnp.asarray(toks[:, p:p + 1]),
                                         jnp.int32(p))
        max_new = max(r.max_new for r in group)
        done = np.zeros(B, bool)
        for t in range(max_new):
            pos = pmax + t
            if pos >= self.max_seq:
                break
            for i, r in enumerate(group):
                if not done[i] and t < r.max_new:
                    tok = np.asarray(jax.device_get(cur))[i]
                    tok_val = int(tok.reshape(-1)[0])
                    r.out.append(tok_val)
                    if r.eos_id is not None and tok_val == r.eos_id:
                        done[i] = True
                elif t >= r.max_new:
                    done[i] = True
            if done.all():
                break
            cur, cache, _ = self.step_fn(params, cache, cur, jnp.int32(pos))
        for r in group:
            r.done = True
