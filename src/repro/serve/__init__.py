from .engine import make_serve_step, ServeEngine, Request
from .partition_server import (DEFAULT_TIERS, PartitionRequest,
                               PartitionResponse, PartitionServer,
                               request_stream)

__all__ = [
    "make_serve_step", "ServeEngine", "Request",
    "PartitionServer", "PartitionRequest", "PartitionResponse",
    "DEFAULT_TIERS", "request_stream",
]
