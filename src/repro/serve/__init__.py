from .engine import make_serve_step, ServeEngine, Request
