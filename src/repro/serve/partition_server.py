"""Partitioning-as-a-service: a batched multi-tenant ``PartitionServer``.

The paper's pitch is *repeated* load balancing during long-running
simulations — which in production means many independent simulations
re-balancing concurrently against one engine. This module is that front
door: heterogeneous partition/repartition requests (varying n and k) are
admitted into **static slot buckets** (power-of-two point tiers × fixed
slots per tier, mirroring ``ServeEngine``'s fixed-slot/static-shape
discipline), every bucket is solved in ONE jitted vmap dispatch through
``partition.batched.bucket_balanced_kmeans``, and per-tenant warm state
(centers + influence, ``repartition.WarmState``) lives in an LRU slot
cache so steady-state requests take the ~10x-cheaper warm path
automatically::

    from repro.serve import PartitionServer, PartitionRequest

    server = PartitionServer(tiers=(1024, 2048, 4096), slots=4)
    server.submit(PartitionRequest(tenant="sim-a", points=pts_a, k=16))
    server.submit(PartitionRequest(tenant="sim-b", points=pts_b, k=8))
    for resp in server.step():          # one vmap dispatch per bucket
        resp.labels, resp.warm, resp.iters

    # next timestep: same tenants, drifted weights -> warm hits
    server.submit(PartitionRequest(tenant="sim-a", points=pts_a, k=16,
                                   weights=w_t))
    [resp] = server.step()
    assert resp.warm and resp.iters <= a_cold_solve_would_need

Static-shape contract (DESIGN.md §10): a request with n points lands in
the smallest tier with cap >= n; within its slot it is padded to the cap
by *cycling its own permuted points at weight zero* — exactly the
refinement-batch padding discipline, so bounding boxes stay tight and all
weighted sums are exact. A request whose n exceeds the largest tier is
rejected at ``submit()`` with a clear error. Requests sharing a bucket
key (cap, k, d, epsilon, warm/cold) are grouped ``slots`` at a time;
short groups are topped up with filler copies of their first request,
masked invalid. Every distinct bucket key compiles once and is reused
for the lifetime of the process — the serving steady state never
retraces.

Determinism: each slot is an independent vmap lane, bit-for-bit equal to
a standalone solve of the same padded subproblem, and per-request prep
(permutation by the request seed, SFC bootstrap from the request's own
points) never depends on queue order — so a request stream yields
identical labels regardless of admission interleaving (property-tested in
tests/test_partition_server.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Hashable

import numpy as np

from repro.core.balanced_kmeans import BKMConfig
from repro.core.sfc import sfc_initial_centers
from repro.partition.batched import bucket_balanced_kmeans
from repro.partition.repartition import (MAX_BALANCE_RETRIES,
                                         WARM_DELTA_TOL, WarmState)

DEFAULT_TIERS = (1024, 2048, 4096, 8192, 16384)

_BKM_FIELDS = {f.name for f in dataclasses.fields(BKMConfig)}


@dataclass
class PartitionRequest:
    """One tenant's (re)partition request.

    Attributes:
        tenant: hashable tenant id — the warm-state cache key. Successive
            requests from the same tenant with unchanged (n, k) resume
            from the cached warm state automatically.
        points: [n, d] float coordinates.
        k: number of blocks, ``1 <= k <= n``.
        weights: [n] nonneg node weights, or None (= unit weights).
        epsilon: balance slack (bucket key component: requests solved
            together must share it).
        seed: permutation seed — per-request, so results are independent
            of how requests are interleaved into buckets.
        uid: server-assigned admission id (set by ``submit``).
    """
    tenant: Hashable
    points: np.ndarray
    k: int
    weights: np.ndarray | None = None
    epsilon: float = 0.03
    seed: int = 0
    uid: int | None = None

    def __post_init__(self):
        self.points = np.asarray(self.points, np.float64)
        if self.points.ndim != 2:
            raise ValueError(f"points must be [n, d], "
                             f"got {self.points.shape}")
        if not (1 <= self.k <= self.n):
            raise ValueError(f"k={self.k} out of range for n={self.n}")
        if self.weights is not None:
            self.weights = np.asarray(self.weights, np.float64)
            if self.weights.shape != (self.n,):
                raise ValueError(
                    f"weights must be [{self.n}], "
                    f"got {self.weights.shape}")

    @property
    def n(self) -> int:
        return self.points.shape[0]

    @property
    def dim(self) -> int:
        return self.points.shape[1]


@dataclass
class PartitionResponse:
    """The server's answer to one ``PartitionRequest``.

    Attributes:
        uid / tenant: echo of the request.
        labels: [n] int64 block ids in the request's point order.
        centers: [k, d] final centers (also cached as warm state).
        influence: [k] final influence.
        warm: True when the solve resumed from cached warm state.
        iters: movement iterations spent (cumulative over balance
            retries) — the serving cost metric the warm path shrinks.
        imbalance: measured per-request imbalance (computed in-graph on
            the padded batch).
        balanced: ``imbalance <= epsilon + 1e-6``.
        migration_fraction: fraction of weight that changed blocks vs the
            tenant's previous labels (warm solves only, else None).
        tier: the point cap of the bucket that served this request.
        time_s: wall time of the bucket dispatch(es) this request rode in.
        stats: raw per-slot solver stats (numpy pytree slice).
    """
    uid: int
    tenant: Hashable
    labels: np.ndarray
    centers: np.ndarray
    influence: np.ndarray
    warm: bool
    iters: int
    imbalance: float
    balanced: bool
    migration_fraction: float | None
    tier: int
    time_s: float
    stats: dict = field(default_factory=dict)


class PartitionServer:
    """Multi-tenant partition serving over static slot buckets.

    Args:
        tiers: ascending power-of-two point caps. A request is padded to
            the smallest tier >= its n; larger requests are rejected at
            ``submit``.
        slots: fixed lane count per bucket dispatch (the vmap batch
            size). Short groups are filler-padded and masked.
        cache_slots: warm-state cache capacity (LRU over tenants);
            0 disables warm serving entirely (every solve cold-starts —
            the fair all-cold baseline used by benchmarks/serving.py).
        **solver_opts: BKMConfig field overrides shared by every solve
            (``max_iter``, ``backend``, ...); unknown names raise.
            Warm solves additionally force ``warmup=False`` and default
            ``delta_tol`` to the warm movement threshold, exactly like
            ``repartition()``.
    """

    def __init__(self, tiers=DEFAULT_TIERS, slots: int = 4,
                 cache_slots: int = 64, **solver_opts):
        tiers = tuple(sorted(int(t) for t in tiers))
        if not tiers:
            raise ValueError("need at least one tier")
        for t in tiers:
            if t < 1 or (t & (t - 1)):
                raise ValueError(f"tiers must be powers of two, got {t}")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if cache_slots < 0:
            raise ValueError(f"cache_slots must be >= 0, got {cache_slots}")
        bad = set(solver_opts) - _BKM_FIELDS
        if bad:
            raise TypeError(f"unknown BKMConfig options {sorted(bad)}")
        for fixed in ("k", "epsilon"):
            if fixed in solver_opts:
                raise TypeError(f"{fixed!r} is per-request state, not a "
                                "server-wide solver option")
        self.tiers = tiers
        self.slots = int(slots)
        self.cache_slots = int(cache_slots)
        self._opts = dict(solver_opts)
        self._queue: list[PartitionRequest] = []
        self._cache: OrderedDict[Hashable, WarmState] = OrderedDict()
        self._next_uid = 0
        self.stats: dict[str, int] = {
            "submitted": 0, "solved": 0, "dispatches": 0,
            "warm_hits": 0, "cold_solves": 0, "invalidations": 0,
            "evictions": 0, "filler_slots": 0, "balance_retries": 0,
        }

    # -- admission ---------------------------------------------------------

    def tier_for(self, n: int) -> int:
        """Smallest tier cap >= n; ValueError past the largest tier."""
        for t in self.tiers:
            if n <= t:
                return t
        raise ValueError(
            f"request with n={n} points exceeds the largest tier "
            f"(cap={self.tiers[-1]}); configure a bigger tier or shrink "
            "the request")

    def submit(self, request: PartitionRequest) -> int:
        """Admit one request; returns its uid. Shape/tier validation
        happens here so oversized requests fail loudly at the front door,
        not inside a bucket dispatch."""
        if not isinstance(request, PartitionRequest):
            raise TypeError(f"submit() takes a PartitionRequest, "
                            f"got {type(request)}")
        self.tier_for(request.n)
        request.uid = self._next_uid
        self._next_uid += 1
        self._queue.append(request)
        self.stats["submitted"] += 1
        return request.uid

    def pending(self) -> int:
        """Number of admitted, not yet served requests."""
        return len(self._queue)

    # -- warm cache --------------------------------------------------------

    def _lookup_warm(self, req: PartitionRequest) -> WarmState | None:
        state = self._cache.get(req.tenant)
        if state is None:
            return None
        if not state.compatible_with(req.n, req.k):
            # tenant changed its problem shape — the cached state cannot
            # seed the solve; drop it so the slot frees up immediately
            del self._cache[req.tenant]
            self.stats["invalidations"] += 1
            return None
        return state

    def _store_warm(self, tenant: Hashable, state: WarmState) -> None:
        if self.cache_slots == 0:
            return
        if tenant in self._cache:
            del self._cache[tenant]
        self._cache[tenant] = state          # most-recently-used at the end
        while len(self._cache) > self.cache_slots:
            self._cache.popitem(last=False)  # evict least-recently-used
            self.stats["evictions"] += 1

    def cached_tenants(self) -> list:
        """Tenant ids currently holding a warm slot, LRU-first."""
        return list(self._cache)

    # -- serving -----------------------------------------------------------

    def step(self) -> list[PartitionResponse]:
        """Drain the queue: group requests into static buckets, solve each
        bucket in one jitted vmap dispatch (plus warm balance retries),
        update the warm cache, and return one response per request (in
        bucket order). An empty queue returns [] without dispatching."""
        queue, self._queue = self._queue, []
        if not queue:
            return []
        buckets: OrderedDict[tuple, list] = OrderedDict()
        for req in queue:
            state = self._lookup_warm(req)
            key = (self.tier_for(req.n), req.k, req.dim, req.epsilon,
                   state is not None)
            buckets.setdefault(key, []).append((req, state))
        responses: list[PartitionResponse] = []
        for (cap, k, _d, epsilon, warm), group in buckets.items():
            for base in range(0, len(group), self.slots):
                chunk = group[base:base + self.slots]
                responses.extend(
                    self._solve_bucket(cap, k, epsilon, warm, chunk))
        return responses

    def serve(self, requests: list[PartitionRequest]
              ) -> list[PartitionResponse]:
        """Submit ``requests`` and step until the queue drains; responses
        come back in submission order."""
        for r in requests:
            self.submit(r)
        out: list[PartitionResponse] = []
        while self._queue:
            out.extend(self.step())
        return sorted(out, key=lambda r: r.uid)

    # -- bucket mechanics --------------------------------------------------

    def _cfg(self, k: int, epsilon: float, warm: bool) -> BKMConfig:
        opts = dict(self._opts)
        if warm:
            opts.setdefault("delta_tol", WARM_DELTA_TOL)
            opts["warmup"] = False
        return BKMConfig(k=k, epsilon=epsilon, **opts)

    def _prep_slot(self, req: PartitionRequest, cap: int,
                   state: WarmState | None):
        """Per-request static-shape prep: permute by the request seed
        (mirroring ``geographer_partition``), pad to the cap by cycling
        the permuted points at weight zero, and seed centers from the SFC
        bootstrap (cold) or the cached warm state."""
        n = req.n
        perm = np.random.default_rng(req.seed).permutation(n)
        idx = perm[np.arange(cap) % n]
        live = np.arange(cap) < n
        pts = req.points[idx]
        w = np.ones(n) if req.weights is None else req.weights
        w = np.where(live, w[idx], 0.0)
        if state is None:
            c0 = sfc_initial_centers(req.points, req.k, req.weights)
            i0 = np.ones(req.k)
            pa = np.zeros(cap, np.int32)
        else:
            c0 = state.centers
            i0 = state.influence_or_ones()
            # padded duplicates inherit their source point's previous
            # label, so slot-level no-op detection matches the unpadded
            # problem's exactly
            pa = state.labels[idx].astype(np.int32)
        return perm, pts, w, c0, i0, pa

    def _solve_bucket(self, cap: int, k: int, epsilon: float, warm: bool,
                      chunk: list) -> list[PartitionResponse]:
        S = self.slots
        d = chunk[0][0].dim
        pts = np.zeros((S, cap, d))
        w = np.zeros((S, cap))
        c0 = np.zeros((S, k, d))
        i0 = np.ones((S, k))
        pa = np.zeros((S, cap), np.int32)
        perms, counts = [], np.ones(S, np.int64)
        for s, (req, state) in enumerate(chunk):
            perm, pts[s], w[s], c0[s], i0[s], pa[s] = \
                self._prep_slot(req, cap, state)
            perms.append(perm)
            counts[s] = req.n
        for s in range(len(chunk), S):     # filler lanes: copies of slot 0
            pts[s], w[s], c0[s], i0[s], pa[s] = (pts[0], w[0], c0[0],
                                                 i0[0], pa[0])
            counts[s] = counts[0]
        valid = np.arange(S) < len(chunk)
        self.stats["filler_slots"] += int(S - len(chunk))
        cfg = self._cfg(k, epsilon, warm)

        t0 = time.perf_counter()
        A, C, infl, stats = bucket_balanced_kmeans(
            pts, w, c0, cfg, counts=counts, valid=valid, warm=warm,
            influence0=i0 if warm else None,
            prev_assignment=pa if warm else None)
        total_iters = np.asarray(stats["iters"], np.int64).copy()
        retries = 0
        if warm:
            # mirror repartition()'s balance-retry loop: a slot whose
            # final balance pass ended above epsilon is re-warmed from its
            # own output state; balanced slots re-emit verbatim through
            # no-op detection, so retrying the whole bucket is safe
            while retries < MAX_BALANCE_RETRIES:
                imb = np.asarray(stats["imbalance"])
                if not np.any(valid & (imb > epsilon + 1e-6)):
                    break
                A, C, infl, stats = bucket_balanced_kmeans(
                    pts, w, np.asarray(C), cfg, counts=counts, valid=valid,
                    warm=True, influence0=np.asarray(infl),
                    prev_assignment=np.asarray(A))
                total_iters += np.asarray(stats["iters"], np.int64)
                retries += 1
                self.stats["balance_retries"] += 1
        dt = time.perf_counter() - t0
        self.stats["dispatches"] += 1 + retries

        A = np.asarray(A)
        C = np.asarray(C)
        infl = np.asarray(infl)
        imb = np.asarray(stats["imbalance"])
        # keep only per-slot array leaves (solver stats like "history"
        # may be None/scalar placeholders)
        host_stats = {name: np.asarray(v) for name, v in stats.items()
                      if v is not None and np.ndim(v) >= 1
                      and np.shape(v)[0] == S}
        responses = []
        for s, (req, state) in enumerate(chunk):
            labels = np.empty(req.n, np.int64)
            labels[perms[s]] = A[s, :req.n]
            mf = None
            if warm:
                # measured against the tenant's previous labels under the
                # NEW weights (repartition() semantics); after retries the
                # in-graph per-dispatch value is vs the retry input, so
                # recompute from the original warm state on the host
                if retries == 0:
                    mf = float(host_stats["migration_fraction"][s])
                else:
                    from repro.core import metrics
                    mf = float(metrics.migration_fraction(
                        state.labels, labels, req.weights))
            resp = PartitionResponse(
                uid=req.uid, tenant=req.tenant, labels=labels,
                centers=C[s], influence=infl[s], warm=warm,
                iters=int(total_iters[s]), imbalance=float(imb[s]),
                balanced=bool(imb[s] <= epsilon + 1e-6),
                migration_fraction=mf, tier=cap, time_s=dt,
                stats={name: v[s] for name, v in host_stats.items()
                       if name not in ("counts", "valid")})
            self._store_warm(req.tenant, WarmState(
                centers=C[s], influence=infl[s], labels=labels))
            self.stats["solved"] += 1
            self.stats["warm_hits" if warm else "cold_solves"] += 1
            responses.append(resp)
        return responses


def request_stream(problems: "list[Any]", workload, steps: int,
                   seed_base: int = 0):
    """Yield per-step request lists for a tenant fleet driven by one
    time-evolving workload — the serving benchmark's input generator.

    Args:
        problems: list of ``PartitionProblem``s, one per tenant (tenant id
            = index); each keeps its own n/k/epsilon/seed.
        workload: ``core.meshes`` workload with ``weights_at(points, t)``.
        steps: number of steps T; step 0 is the cold start, steps 1..T-1
            re-weight every tenant (warm hits on a caching server).
        seed_base: added to each problem's seed (kept constant across
            steps so warm state stays valid).

    Yields:
        ``list[PartitionRequest]`` per step t in [0, steps).
    """
    for t in range(steps):
        batch = []
        for i, prob in enumerate(problems):
            w_t = np.asarray(workload.weights_at(prob.points, t))
            batch.append(PartitionRequest(
                tenant=i, points=prob.points, k=prob.k, weights=w_t,
                epsilon=prob.epsilon, seed=prob.seed + seed_base))
        yield batch
