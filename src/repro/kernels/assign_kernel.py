"""Pallas TPU kernel: fused effective-distance assignment (paper's hot loop).

Computes, for every point p, the cluster c minimizing
``sqdist(p, c) / influence(c)^2`` together with the best and second-best
effective squared distances (needed for the Hamerly bounds, Eqs. 4-5).
With ``with_moments=True`` the same pass also accumulates the per-cluster
weighted moments (Alg. 2's movement reductions) in a VMEM block revisited
across point tiles, so the point array is streamed exactly once.

TPU adaptation of the paper's geometric optimizations (DESIGN.md §4):

* The pairwise-distance inner loop becomes an MXU matmul per
  (point-tile × center-tile): ``sq = |p|^2 + |c|^2 - 2 p @ c^T``.
* The paper's per-point Hamerly branch and bounding-box center ordering
  become **tile-level pruning**: the wrapper (ops.py) precomputes a lower
  bound on the effective sqdist between each point-tile's bounding box and
  each center-tile; inside the kernel a whole center-tile is skipped via
  ``pl.when`` when its bound cannot beat the tile's current worst
  second-best. Centers are pre-sorted by distance to the local bounding box
  (paper Alg. 1 line 6) so prunable tiles appear late in the ``arbitrary``
  grid dimension.
* Padded centers (the ``_FAR`` rows the wrapper appends to reach a
  ``block_c`` multiple) are masked to ``+inf`` effective distance by the
  static real-center count ``k_real`` — the distance math itself is never
  trusted for them (``|FAR|^2`` overflows f32 and can turn into NaN via
  ``inf - inf`` for large-coordinate inputs, which used to corrupt both
  the argmin and the second-best).
* Running (best, second, argmin) accumulators live in the output VMEM
  blocks, revisited across the center-tile grid dimension. In moments mode
  the ``[d+2, K]`` moment block (csum rows, weight row, radius row) is
  revisited across the *point*-tile dimension as well: each point tile
  adds its one-hot-matmul partial after its last center tile, so both grid
  dimensions become ``arbitrary`` (sequential) to keep the accumulation
  well-defined.
* ``double_buffer=True`` (the roofline-driven DMA optimization,
  DESIGN.md §4c): the point array moves to ``ANY`` (compiler-placed,
  HBM-resident) memory and the kernel DMAs point tiles into a two-slot
  VMEM scratch itself — tile ``i+1``'s copy is started when tile ``i``
  begins its center sweep, so the HBM fetch of the next point tile
  overlaps the MXU work of the current one across the whole center-tile
  loop instead of only the one-block lookahead of the automatic
  pipeline. Cross-iteration DMA state forces both grid dimensions
  sequential (``arbitrary``); the default (``None``) enables it only for
  the compiled TPU path and keeps the interpreter on the automatically
  pipelined variant (CI covers both via an explicit flag).
* ``precision="bf16"`` computes the ``p @ c^T`` cross term on the MXU in
  bf16 (f32 accumulation); the norms ``|p|^2``/``|c|^2``, the Hamerly
  best/second accumulators and the moment block stay f32. Tolerance
  bounds documented in DESIGN.md §4c.

Grid: ``(n_point_tiles, n_center_tiles)``. VMEM per step: BP*D + BC*D +
BP*BC floats (+ 3 BP-sized accumulators, + BP + (d+2)*K + BP*K in moments
mode, + 2*BP*D double-buffer scratch) — e.g. BP=1024, BC=128, D<=128,
K=1024 → ~5.5 MB, under the ~16 MB v5e VMEM budget, with BP*BC = 1024x128
matching MXU tiling (multiples of 128 on the lane dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
_ANY = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
_ANY = _ANY.ANY

PRECISIONS = ("f32", "bf16")


def _check_tiling(n: int, k: int, block_p: int, block_c: int,
                  entry: str) -> None:
    """Wrapper-side padding contract: the kernel entry points only accept
    tile-multiple shapes — ``ops.assign_argmin`` pads before calling. A
    non-multiple shape reaching this point is a caller bug; name it."""
    if n % block_p != 0:
        raise ValueError(
            f"{entry}: points axis n={n} is not a multiple of "
            f"block_p={block_p}; pad the point array (ops.assign_argmin "
            "does this) or pass a dividing block_p")
    if k % block_c != 0:
        raise ValueError(
            f"{entry}: centers axis k={k} is not a multiple of "
            f"block_c={block_c}; pad the center array with _FAR rows "
            "(ops.assign_argmin does this) or pass a dividing block_c")


def _cross_term(p, c, precision: str):
    """-2 p @ c^T cross term of the squared distance, [BP, BC] f32.

    ``bf16`` casts both operands to bfloat16 before the MXU matmul
    (accumulation stays f32 via ``preferred_element_type``): half the
    operand bandwidth and double the MXU rate on TPU, at a relative
    distance error bounded by ~2^-8 per coordinate product."""
    if precision == "bf16":
        p = p.astype(jnp.bfloat16)
        c = c.astype(jnp.bfloat16)
    return jax.lax.dot_general(p, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _assign_step(p, bounds_ref, centers_ref, inv2_ref, idx_ref, best_ref,
                 second_ref, *, block_c: int, k_real: int, precision: str):
    """One (point-tile × center-tile) grid step: init at the first center
    tile, tile-level bbox pruning, distance matmul + running
    (best, second, argmin) update. ``p`` is the point tile, however it got
    into VMEM (automatic pipeline or the double-buffer scratch)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        second_ref[...] = jnp.full_like(second_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # Tile-level Hamerly/bbox pruning: skip this center tile when its
    # lower bound cannot improve any point's second-best.
    bound = bounds_ref[0, 0]
    worst_second = jnp.max(second_ref[...])

    @pl.when((j == 0) | (bound < worst_second))
    def _compute():
        c = centers_ref[...]                   # [BC, D]
        inv2 = inv2_ref[...]                   # [1, BC]
        pn = jnp.sum(p * p, axis=1, keepdims=True)          # [BP, 1]
        cn = jnp.sum(c * c, axis=1)[None, :]                # [1, BC]
        sq = pn + cn - 2.0 * _cross_term(p, c, precision)   # [BP, BC]
        eff = jnp.maximum(sq, 0.0) * inv2                   # [BP, BC]
        # mask padded (_FAR) centers to +inf: their f32 distance overflows
        # (or NaNs via inf - inf) and must never reach argmin/second
        cols = j * block_c + jax.lax.broadcasted_iota(
            jnp.int32, eff.shape, 1)
        eff = jnp.where(cols < k_real, eff, jnp.inf)

        local_idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
        local_best = jnp.min(eff, axis=1)
        bc = eff.shape[1]
        onehot = jax.nn.one_hot(local_idx, bc, dtype=jnp.bool_)
        local_second = jnp.min(jnp.where(onehot, jnp.inf, eff), axis=1)

        old_best = best_ref[...]
        old_second = second_ref[...]
        old_idx = idx_ref[...]
        take_new = local_best < old_best
        new_best = jnp.where(take_new, local_best, old_best)
        new_second = jnp.minimum(
            jnp.minimum(old_second, local_second),
            jnp.maximum(old_best, local_best))
        new_idx = jnp.where(take_new, j * block_c + local_idx, old_idx)
        best_ref[...] = new_best
        second_ref[...] = new_second
        idx_ref[...] = new_idx


def _moments_step(p, w_ref, idx_ref, best_ref, moments_ref):
    """Moment accumulation into the grid-wide ``[d+2, K]`` VMEM block:
    rows ``0..d-1`` hold the weighted coordinate sums, row ``d`` the
    weighted counts, row ``d+1`` the weighted best effective-sq distances
    — all in *sorted-center* column space (the wrapper un-sorts). Each
    point tile contributes its one-hot matmul partial once, after its
    final center tile. Accumulation is always f32, independent of the
    distance-matmul precision."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _zero():
        moments_ref[...] = jnp.zeros_like(moments_ref)

    @pl.when(j == pl.num_programs(1) - 1)
    def _accumulate():
        w = w_ref[...]                                       # [BP]
        idx = idx_ref[...]                                   # [BP]
        best = best_ref[...]                                 # [BP]
        kpad = moments_ref.shape[1]
        onehot = idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (p.shape[0], kpad), 1)                # [BP, K]
        ww = jnp.where(onehot, w[:, None], 0.0)              # [BP, K]
        stacked = jnp.concatenate(
            [p, jnp.ones((p.shape[0], 1), p.dtype), best[:, None]],
            axis=1)                                          # [BP, D+2]
        moments_ref[...] += jax.lax.dot_general(
            stacked, ww, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [D+2, K]


def _points_db(points_hbm, pbuf, sem, block_p: int):
    """Double-buffered point-tile fetch: wait for tile ``i``'s DMA (slot
    ``i % 2``) at its first center tile and immediately start tile
    ``i+1``'s copy into the other slot, so the next tile's HBM read is in
    flight for the whole center sweep of the current one. Returns the
    current tile's VMEM view. Requires a sequential point-tile grid
    dimension (cross-iteration scratch + semaphore state)."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    def dma(slot, tile):
        return pltpu.make_async_copy(
            points_hbm.at[pl.ds(tile * block_p, block_p), :],
            pbuf.at[slot], sem.at[slot])

    @pl.when((i == 0) & (j == 0))
    def _warmup():
        dma(0, 0).start()

    @pl.when(j == 0)
    def _rotate():
        dma(i % 2, i).wait()

        @pl.when(i + 1 < pl.num_programs(0))
        def _prefetch():
            dma((i + 1) % 2, i + 1).start()

    return pbuf[i % 2]


def _assign_kernel(bounds_ref, points_ref, centers_ref, inv2_ref,
                   idx_ref, best_ref, second_ref, *, block_c: int,
                   k_real: int, precision: str):
    _assign_step(points_ref[...], bounds_ref, centers_ref, inv2_ref,
                 idx_ref, best_ref, second_ref, block_c=block_c,
                 k_real=k_real, precision=precision)


def _assign_kernel_db(bounds_ref, points_hbm, centers_ref, inv2_ref,
                      idx_ref, best_ref, second_ref, pbuf, sem, *,
                      block_p: int, block_c: int, k_real: int,
                      precision: str):
    p = _points_db(points_hbm, pbuf, sem, block_p)
    _assign_step(p, bounds_ref, centers_ref, inv2_ref,
                 idx_ref, best_ref, second_ref, block_c=block_c,
                 k_real=k_real, precision=precision)


def _assign_moments_kernel(bounds_ref, points_ref, centers_ref, inv2_ref,
                           w_ref, idx_ref, best_ref, second_ref,
                           moments_ref, *, block_c: int, k_real: int,
                           precision: str):
    p = points_ref[...]
    _assign_step(p, bounds_ref, centers_ref, inv2_ref, idx_ref, best_ref,
                 second_ref, block_c=block_c, k_real=k_real,
                 precision=precision)
    _moments_step(p, w_ref, idx_ref, best_ref, moments_ref)


def _assign_moments_kernel_db(bounds_ref, points_hbm, centers_ref,
                              inv2_ref, w_ref, idx_ref, best_ref,
                              second_ref, moments_ref, pbuf, sem, *,
                              block_p: int, block_c: int, k_real: int,
                              precision: str):
    p = _points_db(points_hbm, pbuf, sem, block_p)
    _assign_step(p, bounds_ref, centers_ref, inv2_ref, idx_ref, best_ref,
                 second_ref, block_c=block_c, k_real=k_real,
                 precision=precision)
    _moments_step(p, w_ref, idx_ref, best_ref, moments_ref)


def default_interpret() -> bool:
    """Backend auto-detection: run the Mosaic-compiled kernel on real TPUs,
    the Pallas interpreter everywhere else (CPU CI containers, GPU hosts)."""
    return jax.default_backend() != "tpu"


def _resolve_db(double_buffer: bool | None, interpret: bool) -> bool:
    # auto: manual DMA overlap pays on real hardware; the interpreter
    # emulates DMAs synchronously, so default to the pipelined variant
    # there (tests opt in explicitly to cover the DMA path on CPU).
    return (not interpret) if double_buffer is None else double_buffer


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret", "precision",
                                    "double_buffer"))
def assign_argmin_pallas(points, centers, inv2, tile_bounds, k_real: int,
                         block_p: int = 1024, block_c: int = 128,
                         interpret: bool | None = None,
                         precision: str = "f32",
                         double_buffer: bool | None = None):
    """points [N, D], centers [K, D] (pre-padded), inv2 [K] = 1/influence^2,
    tile_bounds [N/BP, K/BC], k_real = number of real (non-_FAR) centers.
    Returns (idx, best_eff_sq, second_eff_sq).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    Pass an explicit bool to override (e.g. interpret-mode debugging on
    TPU hosts). ``precision`` is the distance-matmul mode ("f32"/"bf16");
    ``double_buffer`` selects the manual two-slot point-tile DMA (None =
    only when compiled)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    _check_tiling(n, k, block_p, block_c, "assign_argmin_pallas")
    db = _resolve_db(double_buffer, interpret)
    grid = (n // block_p, k // block_c)
    common = [
        pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),      # centers
        pl.BlockSpec((1, block_c), lambda i, j: (0, j)),      # inv2
    ]
    if db:
        kernel = functools.partial(_assign_kernel_db, block_p=block_p,
                                   block_c=block_c, k_real=k_real,
                                   precision=precision)
        points_spec = pl.BlockSpec(memory_space=_ANY)
        scratch = [pltpu.VMEM((2, block_p, d), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
        semantics = ("arbitrary", "arbitrary")
    else:
        kernel = functools.partial(_assign_kernel, block_c=block_c,
                                   k_real=k_real, precision=precision)
        points_spec = pl.BlockSpec((block_p, d), lambda i, j: (i, 0))
        scratch = []
        semantics = ("parallel", "arbitrary")
    idx, best, second = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, j)),  # bounds
                  points_spec] + common,
        out_specs=[
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(tile_bounds, points, centers, inv2[None, :])
    return idx, best, second


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret", "precision",
                                    "double_buffer"))
def assign_reduce_pallas(points, centers, inv2, tile_bounds, weights,
                         k_real: int, block_p: int = 1024,
                         block_c: int = 128,
                         interpret: bool | None = None,
                         precision: str = "f32",
                         double_buffer: bool | None = None):
    """Fused assign+reduce: one pass over the point tiles returning
    (idx, best_eff_sq, second_eff_sq, moments [d+2, K]) with the moment
    block accumulated in VMEM across point tiles (sorted-center columns:
    rows 0..d-1 weighted coordinate sums, row d weighted counts, row d+1
    weighted best-eff-sq sums). Args as ``assign_argmin_pallas`` plus
    ``weights [N]`` (zero marks padded points)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    _check_tiling(n, k, block_p, block_c, "assign_reduce_pallas")
    db = _resolve_db(double_buffer, interpret)
    grid = (n // block_p, k // block_c)
    if db:
        kernel = functools.partial(_assign_moments_kernel_db,
                                   block_p=block_p, block_c=block_c,
                                   k_real=k_real, precision=precision)
        points_spec = pl.BlockSpec(memory_space=_ANY)
        scratch = [pltpu.VMEM((2, block_p, d), jnp.float32),
                   pltpu.SemaphoreType.DMA((2,))]
    else:
        kernel = functools.partial(_assign_moments_kernel, block_c=block_c,
                                   k_real=k_real, precision=precision)
        points_spec = pl.BlockSpec((block_p, d), lambda i, j: (i, 0))
        scratch = []
    idx, best, second, moments = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),            # bounds
            points_spec,                                          # points
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),      # centers
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),      # inv2
            pl.BlockSpec((block_p,), lambda i, j: (i,)),          # weights
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((d + 2, k), lambda i, j: (0, 0)),        # moments
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((d + 2, k), jnp.float32),
        ],
        scratch_shapes=scratch,
        # the moment block accumulates across BOTH grid dimensions, so the
        # point-tile dimension must be sequential too
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_bounds, points, centers, inv2[None, :], weights)
    return idx, best, second, moments
