"""Pallas TPU kernel: fused effective-distance assignment (paper's hot loop).

Computes, for every point p, the cluster c minimizing
``sqdist(p, c) / influence(c)^2`` together with the best and second-best
effective squared distances (needed for the Hamerly bounds, Eqs. 4-5).
With ``with_moments=True`` the same pass also accumulates the per-cluster
weighted moments (Alg. 2's movement reductions) in a VMEM block revisited
across point tiles, so the point array is streamed exactly once.

TPU adaptation of the paper's geometric optimizations (DESIGN.md §4):

* The pairwise-distance inner loop becomes an MXU matmul per
  (point-tile × center-tile): ``sq = |p|^2 + |c|^2 - 2 p @ c^T``.
* The paper's per-point Hamerly branch and bounding-box center ordering
  become **tile-level pruning**: the wrapper (ops.py) precomputes a lower
  bound on the effective sqdist between each point-tile's bounding box and
  each center-tile; inside the kernel a whole center-tile is skipped via
  ``pl.when`` when its bound cannot beat the tile's current worst
  second-best. Centers are pre-sorted by distance to the local bounding box
  (paper Alg. 1 line 6) so prunable tiles appear late in the ``arbitrary``
  grid dimension.
* Padded centers (the ``_FAR`` rows the wrapper appends to reach a
  ``block_c`` multiple) are masked to ``+inf`` effective distance by the
  static real-center count ``k_real`` — the distance math itself is never
  trusted for them (``|FAR|^2`` overflows f32 and can turn into NaN via
  ``inf - inf`` for large-coordinate inputs, which used to corrupt both
  the argmin and the second-best).
* Running (best, second, argmin) accumulators live in the output VMEM
  blocks, revisited across the center-tile grid dimension. In moments mode
  the ``[d+2, K]`` moment block (csum rows, weight row, radius row) is
  revisited across the *point*-tile dimension as well: each point tile
  adds its one-hot-matmul partial after its last center tile, so both grid
  dimensions become ``arbitrary`` (sequential) to keep the accumulation
  well-defined.

Grid: ``(n_point_tiles, n_center_tiles)``. VMEM per step: BP*D + BC*D +
BP*BC floats (+ 3 BP-sized accumulators, + BP + (d+2)*K + BP*K in moments
mode) — e.g. BP=1024, BC=128, D<=128, K=1024 → ~5.5 MB, under the ~16 MB
v5e VMEM budget, with BP*BC = 1024x128 matching MXU tiling (multiples of
128 on the lane dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _assign_kernel(bounds_ref, points_ref, centers_ref, inv2_ref,
                   idx_ref, best_ref, second_ref, *, block_c: int,
                   k_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, jnp.inf)
        second_ref[...] = jnp.full_like(second_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    # Tile-level Hamerly/bbox pruning: skip this center tile when its
    # lower bound cannot improve any point's second-best.
    bound = bounds_ref[0, 0]
    worst_second = jnp.max(second_ref[...])

    @pl.when((j == 0) | (bound < worst_second))
    def _compute():
        p = points_ref[...]                    # [BP, D]
        c = centers_ref[...]                   # [BC, D]
        inv2 = inv2_ref[...]                   # [1, BC]
        pn = jnp.sum(p * p, axis=1, keepdims=True)          # [BP, 1]
        cn = jnp.sum(c * c, axis=1)[None, :]                # [1, BC]
        sq = pn + cn - 2.0 * jax.lax.dot_general(
            p, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # [BP, BC]
        eff = jnp.maximum(sq, 0.0) * inv2                   # [BP, BC]
        # mask padded (_FAR) centers to +inf: their f32 distance overflows
        # (or NaNs via inf - inf) and must never reach argmin/second
        cols = j * block_c + jax.lax.broadcasted_iota(
            jnp.int32, eff.shape, 1)
        eff = jnp.where(cols < k_real, eff, jnp.inf)

        local_idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
        local_best = jnp.min(eff, axis=1)
        bc = eff.shape[1]
        onehot = jax.nn.one_hot(local_idx, bc, dtype=jnp.bool_)
        local_second = jnp.min(jnp.where(onehot, jnp.inf, eff), axis=1)

        old_best = best_ref[...]
        old_second = second_ref[...]
        old_idx = idx_ref[...]
        take_new = local_best < old_best
        new_best = jnp.where(take_new, local_best, old_best)
        new_second = jnp.minimum(
            jnp.minimum(old_second, local_second),
            jnp.maximum(old_best, local_best))
        new_idx = jnp.where(take_new, j * block_c + local_idx, old_idx)
        best_ref[...] = new_best
        second_ref[...] = new_second
        idx_ref[...] = new_idx


def _assign_moments_kernel(bounds_ref, points_ref, centers_ref, inv2_ref,
                           w_ref, idx_ref, best_ref, second_ref,
                           moments_ref, *, block_c: int, k_real: int):
    """Assignment kernel + per-cluster moment accumulation.

    ``moments_ref`` is a ``[d+2, K]`` VMEM block revisited across the
    whole grid (constant index map): rows ``0..d-1`` hold the weighted
    coordinate sums, row ``d`` the weighted counts, row ``d+1`` the
    weighted best effective-sq distances — all in *sorted-center* column
    space (the wrapper un-sorts). Each point tile contributes its one-hot
    matmul partial once, after its final center tile.
    """
    _assign_kernel(bounds_ref, points_ref, centers_ref, inv2_ref,
                   idx_ref, best_ref, second_ref, block_c=block_c,
                   k_real=k_real)
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _zero():
        moments_ref[...] = jnp.zeros_like(moments_ref)

    @pl.when(j == pl.num_programs(1) - 1)
    def _accumulate():
        p = points_ref[...]                                  # [BP, D]
        w = w_ref[...]                                       # [BP]
        idx = idx_ref[...]                                   # [BP]
        best = best_ref[...]                                 # [BP]
        kpad = moments_ref.shape[1]
        onehot = idx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (p.shape[0], kpad), 1)                # [BP, K]
        ww = jnp.where(onehot, w[:, None], 0.0)              # [BP, K]
        stacked = jnp.concatenate(
            [p, jnp.ones((p.shape[0], 1), p.dtype), best[:, None]],
            axis=1)                                          # [BP, D+2]
        moments_ref[...] += jax.lax.dot_general(
            stacked, ww, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # [D+2, K]


def default_interpret() -> bool:
    """Backend auto-detection: run the Mosaic-compiled kernel on real TPUs,
    the Pallas interpreter everywhere else (CPU CI containers, GPU hosts)."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret"))
def assign_argmin_pallas(points, centers, inv2, tile_bounds, k_real: int,
                         block_p: int = 1024, block_c: int = 128,
                         interpret: bool | None = None):
    """points [N, D], centers [K, D] (pre-padded), inv2 [K] = 1/influence^2,
    tile_bounds [N/BP, K/BC], k_real = number of real (non-_FAR) centers.
    Returns (idx, best_eff_sq, second_eff_sq).

    ``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
    Pass an explicit bool to override (e.g. interpret-mode debugging on
    TPU hosts)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    assert n % block_p == 0 and k % block_c == 0
    grid = (n // block_p, k // block_c)
    kernel = functools.partial(_assign_kernel, block_c=block_c,
                               k_real=k_real)
    idx, best, second = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),            # bounds
            pl.BlockSpec((block_p, d), lambda i, j: (i, 0)),      # points
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),      # centers
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),      # inv2
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tile_bounds, points, centers, inv2[None, :])
    return idx, best, second


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret"))
def assign_reduce_pallas(points, centers, inv2, tile_bounds, weights,
                         k_real: int, block_p: int = 1024,
                         block_c: int = 128,
                         interpret: bool | None = None):
    """Fused assign+reduce: one pass over the point tiles returning
    (idx, best_eff_sq, second_eff_sq, moments [d+2, K]) with the moment
    block accumulated in VMEM across point tiles (sorted-center columns:
    rows 0..d-1 weighted coordinate sums, row d weighted counts, row d+1
    weighted best-eff-sq sums). Args as ``assign_argmin_pallas`` plus
    ``weights [N]`` (zero marks padded points)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    assert n % block_p == 0 and k % block_c == 0
    grid = (n // block_p, k // block_c)
    kernel = functools.partial(_assign_moments_kernel, block_c=block_c,
                               k_real=k_real)
    idx, best, second, moments = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),            # bounds
            pl.BlockSpec((block_p, d), lambda i, j: (i, 0)),      # points
            pl.BlockSpec((block_c, d), lambda i, j: (j, 0)),      # centers
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),      # inv2
            pl.BlockSpec((block_p,), lambda i, j: (i,)),          # weights
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((block_p,), lambda i, j: (i,)),
            pl.BlockSpec((d + 2, k), lambda i, j: (0, 0)),        # moments
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((d + 2, k), jnp.float32),
        ],
        # the moment block accumulates across BOTH grid dimensions, so the
        # point-tile dimension must be sequential too
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(tile_bounds, points, centers, inv2[None, :], weights)
    return idx, best, second, moments
