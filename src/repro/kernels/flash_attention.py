"""Pallas TPU kernel: causal flash attention (forward).

This is the TPU-native replacement for the pure-JAX chunked attention in
``models/layers.py`` (`_flash_full`): on real hardware the online-softmax
inner loop runs per (batch*head, q-tile, kv-tile) grid cell with running
(m, l, acc) accumulators in VMEM scratch, and **strictly-above-diagonal
kv-tiles are skipped** via ``pl.when`` — the triangular schedule that the
SPMD-level JAX path can only do when the sequence axis is unsharded.

Grid: ``(B*H, S/bq, S/bk)`` with semantics ("parallel","parallel",
"arbitrary"). VMEM per step: bq*dh (q) + 2*bk*dh (k,v) + bq*bk (scores)
+ bq*(dh+2) f32 scratch — bq=bk=512, dh=128: ~1.6 MB, MXU-aligned.

GQA is handled in the BlockSpec index maps: query head h reads kv head
``h // (H/KV)``; no head replication is materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, softcap: float, scale: float):
    i = pl.program_id(1)      # q tile
    j = pl.program_id(2)      # kv tile
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal: skip tiles strictly above the diagonal
    @pl.when(j * bk <= i * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [bq, dh]
        k = k_ref[0].astype(jnp.float32)               # [bk, dh]
        v = v_ref[0].astype(jnp.float32)               # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bq", "bk", "softcap", "interpret"))
def flash_attention_pallas(q, k, v, bq: int = 512, bk: int = 512,
                           softcap: float = 0.0, interpret: bool = True):
    """q: [BH, S, dh] (already GQA-expanded indexing via wrapper),
    k/v: [BKV, S, dh]; BH = B*H, BKV = B*KV with the head mapping done by
    the BlockSpec index maps. Returns o: [BH, S, dh]."""
    BH, S, dh = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    if S % bq != 0 or S % bk != 0:
        raise ValueError(
            f"flash_attention_pallas: sequence length {S} must be a "
            f"multiple of the query tile bq={bq} and the key tile "
            f"bk={bk}; pad the sequence or pass matching tile sizes")
    grid = (BH, S // bq, S // bk)
    scale = dh ** -0.5
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk,
                               softcap=softcap, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
