"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp


def assign_argmin_ref(points, centers, influence):
    """Effective-distance argmin (paper Alg. 1 inner loop), dense oracle.

    Returns (idx [n] int32, best_eff_sq [n], second_eff_sq [n]) where
    eff_sq = squared-distance / influence^2 (monotone in dist/influence).
    """
    inv2 = 1.0 / (influence * influence)
    pn = jnp.sum(points * points, axis=1, keepdims=True)
    cn = jnp.sum(centers * centers, axis=1)
    sq = jnp.maximum(pn + cn[None, :] - 2.0 * points @ centers.T, 0.0)
    eff = sq * inv2[None, :]
    idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(eff, idx[:, None], axis=1)[:, 0]
    masked = eff.at[jnp.arange(points.shape[0]), idx].set(jnp.inf)
    second = jnp.min(masked, axis=1)
    return idx, best, second


def center_update_ref(points, weights, assignment, k):
    """Weighted per-cluster sums (movement phase oracle).

    Returns (wsum [k, d], wcount [k])."""
    import jax
    wsum = jax.ops.segment_sum(weights[:, None] * points, assignment,
                               num_segments=k)
    wcount = jax.ops.segment_sum(weights, assignment, num_segments=k)
    return wsum, wcount


def flash_attention_ref(q, k, v, softcap: float = 0.0):
    """Dense causal attention oracle. q: [BH, S, dh], k/v: [BKV, S, dh]
    with BH % BKV == 0 (GQA). Returns [BH, S, dh] in q.dtype."""
    import jax
    BH, S, dh = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    kx = jnp.repeat(k, G, axis=0).astype(jnp.float32)
    vx = jnp.repeat(v, G, axis=0).astype(jnp.float32)
    s = jnp.einsum("hqd,htd->hqt", q.astype(jnp.float32), kx) * (dh ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqt,htd->hqd", p, vx).astype(q.dtype)


def router_topk_ref(x, centroids, inv2, top_k: int):
    """Balanced-k-means router oracle: top-k smallest effective sq-dists.
    Returns (idx [T, k] int32, eff [T, k] f32) in ascending-eff order."""
    import jax
    xf = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    eff = jnp.maximum(xn + cn - 2.0 * xf @ c.T, 0.0) * inv2[None, :]
    neg, idx = jax.lax.top_k(-eff, top_k)
    return idx.astype(jnp.int32), -neg
