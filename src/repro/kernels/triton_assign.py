"""GPU-portable ("triton-shaped") assignment backend.

Same math as ``assign_kernel.py`` — effective-distance argmin with
best/second tracking and optional fused moments — but structured the way
a Triton / Mosaic-GPU kernel wants it rather than the way a TPU Mosaic
kernel does (DESIGN.md §4c):

* **1-D grid over point tiles only.** Each program owns one ``[block_p,
  d]`` point tile and loops over center tiles with an in-kernel
  ``fori_loop`` + dynamic slices of the full ``[K, d]`` center block
  (centers are small enough to sit in every program's fast memory; on a
  GPU this is the classic "B matrix in L2/SMEM, loop over K tiles" shape).
  No second grid dimension means no cross-program sequential semantics.
* **Split-k moment partials.** Fused moments are written as one
  ``[d+2, K]`` partial *per program* and summed by the wrapper outside
  the kernel — the TPU kernel's grid-revisited VMEM accumulator has no
  portable GPU equivalent (it relies on Mosaic's sequential-grid
  guarantee), whereas partials + an XLA reduction lower everywhere.
* **No tile pruning.** The bbox-bound ``pl.when`` skip needs the
  sequential center-tile dimension to pay off; here every center tile is
  visited. The jnp-side center *sort* is skipped too — indices come out
  in original center order, no un-sort needed.
* Nothing TPU-only in the body: no manual DMA, no semaphores, no
  ``dimension_semantics`` requirements beyond a parallel 1-D grid —
  interpret-verified on CPU in CI (``REPRO_ASSIGN_BACKEND=triton`` leg)
  and lowerable through Mosaic-GPU unchanged.

Registered as ``triton`` with ``supports_moments=True``; ``auto``
resolves to it on GPU hosts (ops.resolve_assign_backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .assign_kernel import _check_tiling, _cross_term, default_interpret

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _sweep_centers(p, centers_ref, inv2_ref, *, block_c: int, k_real: int,
                   precision: str):
    """In-kernel loop over center tiles; returns the final
    (idx [BP], best [BP], second [BP]) carry in original center order."""
    bp = p.shape[0]
    kpad = centers_ref.shape[0]
    pn = jnp.sum(p * p, axis=1, keepdims=True)              # [BP, 1]

    def tile(j, carry):
        best0, second0, idx0 = carry
        c = centers_ref[pl.ds(j * block_c, block_c), :]     # [BC, D]
        inv2 = inv2_ref[:, pl.ds(j * block_c, block_c)]     # [1, BC]
        cn = jnp.sum(c * c, axis=1)[None, :]
        sq = pn + cn - 2.0 * _cross_term(p, c, precision)
        eff = jnp.maximum(sq, 0.0) * inv2                   # [BP, BC]
        cols = j * block_c + jax.lax.broadcasted_iota(
            jnp.int32, eff.shape, 1)
        eff = jnp.where(cols < k_real, eff, jnp.inf)

        local_idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
        local_best = jnp.min(eff, axis=1)
        onehot = jax.nn.one_hot(local_idx, block_c, dtype=jnp.bool_)
        local_second = jnp.min(jnp.where(onehot, jnp.inf, eff), axis=1)

        take_new = local_best < best0
        best = jnp.where(take_new, local_best, best0)
        second = jnp.minimum(jnp.minimum(second0, local_second),
                             jnp.maximum(best0, local_best))
        idx = jnp.where(take_new, j * block_c + local_idx, idx0)
        return best, second, idx

    init = (jnp.full((bp,), jnp.inf, jnp.float32),
            jnp.full((bp,), jnp.inf, jnp.float32),
            jnp.full((bp,), -1, jnp.int32))
    best, second, idx = jax.lax.fori_loop(0, kpad // block_c, tile, init)
    return idx, best, second


def _triton_kernel(points_ref, centers_ref, inv2_ref, idx_ref, best_ref,
                   second_ref, *, block_c: int, k_real: int,
                   precision: str):
    idx, best, second = _sweep_centers(
        points_ref[...], centers_ref, inv2_ref, block_c=block_c,
        k_real=k_real, precision=precision)
    idx_ref[...] = idx
    best_ref[...] = best
    second_ref[...] = second


def _triton_moments_kernel(points_ref, centers_ref, inv2_ref, w_ref,
                           idx_ref, best_ref, second_ref, partial_ref, *,
                           block_c: int, k_real: int, precision: str):
    p = points_ref[...]
    idx, best, second = _sweep_centers(
        p, centers_ref, inv2_ref, block_c=block_c, k_real=k_real,
        precision=precision)
    idx_ref[...] = idx
    best_ref[...] = best
    second_ref[...] = second
    # split-k moment partial for THIS program's point tile, [1, d+2, K];
    # accumulation stays f32 regardless of the distance-matmul precision
    kpad = centers_ref.shape[0]
    onehot = idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (p.shape[0], kpad), 1)
    ww = jnp.where(onehot, w_ref[...][:, None], 0.0)         # [BP, K]
    stacked = jnp.concatenate(
        [p, jnp.ones((p.shape[0], 1), p.dtype), best[:, None]], axis=1)
    partial_ref[...] = jax.lax.dot_general(
        stacked, ww, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[None]            # [1, D+2, K]


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret", "precision"))
def triton_assign_pallas(points, centers, inv2, k_real: int,
                         block_p: int = 256, block_c: int = 128,
                         interpret: bool | None = None,
                         precision: str = "f32"):
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    _check_tiling(n, k, block_p, block_c, "triton_assign_pallas")
    kernel = functools.partial(_triton_kernel, block_c=block_c,
                               k_real=k_real, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=(n // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(points, centers, inv2[None, :])


@functools.partial(jax.jit,
                   static_argnames=("k_real", "block_p", "block_c",
                                    "interpret", "precision"))
def triton_assign_reduce_pallas(points, centers, inv2, weights,
                                k_real: int, block_p: int = 256,
                                block_c: int = 128,
                                interpret: bool | None = None,
                                precision: str = "f32"):
    if interpret is None:
        interpret = default_interpret()
    n, d = points.shape
    k = centers.shape[0]
    _check_tiling(n, k, block_p, block_c, "triton_assign_reduce_pallas")
    kernel = functools.partial(_triton_moments_kernel, block_c=block_c,
                               k_real=k_real, precision=precision)
    n_pt = n // block_p
    idx, best, second, partials = pl.pallas_call(
        kernel,
        grid=(n_pt,),
        in_specs=[
            pl.BlockSpec((block_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((1, d + 2, k), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n_pt, d + 2, k), jnp.float32),
        ],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(points, centers, inv2[None, :], weights)
    # split-k reduction of the per-program partials (deterministic XLA sum)
    return idx, best, second, partials.sum(axis=0)


def _pad_inputs(points, centers, influence, block_p, block_c):
    from .ops import _FAR
    n = points.shape[0]
    k = centers.shape[0]
    inv2 = 1.0 / (influence * influence)
    pad_n = (-n) % block_p
    pad_k = (-k) % block_c
    pts = jnp.pad(points, ((0, pad_n), (0, 0))).astype(jnp.float32)
    cts = jnp.pad(centers, ((0, pad_k), (0, 0)),
                  constant_values=_FAR).astype(jnp.float32)
    iv2 = jnp.pad(inv2, (0, pad_k), constant_values=1.0).astype(jnp.float32)
    return pts, cts, iv2


def triton_assign_backend(points, centers, influence, *,
                          chunk: int | None = None, block_p: int = 256,
                          block_c: int = 128, weights=None,
                          return_moments: bool = False,
                          precision: str = "f32"):
    """Registry adapter (``chunk`` ignored: the grid's point tiling bounds
    fast-memory use). Unlike the ``pallas`` backend there is no center
    sort, so indices and moments come out in original center order."""
    del chunk
    from .ops import _interpret_mode
    n = points.shape[0]
    k = centers.shape[0]
    pts, cts, iv2 = _pad_inputs(points, centers, influence, block_p,
                                block_c)
    if return_moments:
        if weights is None:
            raise ValueError("return_moments=True requires weights")
        w = jnp.pad(weights, (0, pts.shape[0] - n)).astype(jnp.float32)
        idx, best, second, m = triton_assign_reduce_pallas(
            pts, cts, iv2, w, k_real=k, block_p=block_p, block_c=block_c,
            interpret=_interpret_mode(), precision=precision)
        return (idx[:n], best[:n], second[:n],
                m.T[:k, :points.shape[1]], m[points.shape[1], :k],
                m[points.shape[1] + 1, :k])
    idx, best, second = triton_assign_pallas(
        pts, cts, iv2, k_real=k, block_p=block_p, block_c=block_c,
        interpret=_interpret_mode(), precision=precision)
    return idx[:n], best[:n], second[:n]


def _register():
    from .ops import register_assign_backend
    register_assign_backend("triton",
                            supports_moments=True)(triton_assign_backend)


_register()
