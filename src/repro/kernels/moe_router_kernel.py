"""Pallas TPU kernel: fused balanced-k-means MoE router (top-k).

The paper's assignment step specialized to expert routing: for each token
``t``, compute the effective squared distance to every expert centroid
``sqdist(x_t, c_e) / influence_e^2`` (MXU matmul per token-tile) and
extract the top-k closest experts in-register — one kernel instead of a
distance matmul + k passes of argmin over HBM.

E (number of experts, padded to a lane multiple) fits a single VMEM tile
for every assigned arch (<= 128 experts), so the grid is 1-D over token
tiles and k extraction is a static unrolled loop of (min, mask).

Grid: ``(T/bt,)``, VMEM per step: bt*D + E*D + bt*E floats
(bt=256, D<=8192, E<=128 -> ~10 MB at the llama4 scale; drop bt to 128
for d_model=8192).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

FAR = 1e30


def _router_kernel(x_ref, c_ref, inv2_ref, idx_ref, eff_ref, *, top_k: int):
    x = x_ref[...].astype(jnp.float32)                  # [bt, D]
    c = c_ref[...].astype(jnp.float32)                  # [E, D]
    inv2 = inv2_ref[...]                                # [1, E]
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    sq = xn + cn - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    eff = jnp.maximum(sq, 0.0) * inv2                    # [bt, E]
    E = eff.shape[1]
    for ki in range(top_k):
        best = jnp.argmin(eff, axis=1).astype(jnp.int32)
        val = jnp.min(eff, axis=1)
        idx_ref[:, ki] = best
        eff_ref[:, ki] = val
        taken = jax.nn.one_hot(best, E, dtype=jnp.bool_)
        eff = jnp.where(taken, FAR, eff)


@functools.partial(jax.jit,
                   static_argnames=("top_k", "bt", "interpret"))
def router_topk_pallas(x, centroids, inv2, top_k: int, bt: int = 256,
                       interpret: bool = True):
    """x: [T, D] (T % bt == 0), centroids: [E, D], inv2: [E].
    Returns (idx [T, top_k] int32, eff [T, top_k] f32)."""
    T, D = x.shape
    E = centroids.shape[0]
    assert T % bt == 0
    kernel = functools.partial(_router_kernel, top_k=top_k)
    return pl.pallas_call(
        kernel,
        grid=(T // bt,),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i: (i, 0)),
            pl.BlockSpec((E, D), lambda i: (0, 0)),
            pl.BlockSpec((1, E), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, top_k), lambda i: (i, 0)),
            pl.BlockSpec((bt, top_k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
            jax.ShapeDtypeStruct((T, top_k), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, centroids, inv2[None, :])
