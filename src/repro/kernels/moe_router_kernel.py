"""Pallas TPU kernel: fused balanced-k-means MoE router (top-k).

The paper's assignment step specialized to expert routing: for each token
``t``, compute the effective squared distance to every expert centroid
``sqdist(x_t, c_e) / influence_e^2`` (MXU matmul per token-tile) and
extract the top-k closest experts in-register — one kernel instead of a
distance matmul + k passes of argmin over HBM.

E no longer has to fit one VMEM tile: the kernel is routed through the
same center-tiling scheme as the assignment kernel (DESIGN.md §4c) — a
second grid dimension sweeps ``block_e``-expert tiles sequentially while
the ``[bt, top_k]`` output blocks are revisited as running top-k
accumulators. Each tile's effective distances are concatenated with the
running top-k and the top-k re-extracted by a static unrolled (min, mask)
loop over the ``[bt, top_k + block_e]`` candidate row. Padded experts
(``e_real`` mask) are held at ``FAR`` *before* the merge, so they can
never displace a real expert and the large-coordinate ``inf - inf`` NaN
hazard of trusting FAR-row distance math is gone (same fix as the
assignment kernel's ``k_real`` mask).

Grid: ``(T/bt, E_pad/block_e)``, VMEM per step: bt*D + block_e*D +
bt*block_e + 2*bt*top_k floats (bt=256, D<=8192, block_e=128 -> ~10 MB at
the llama4 scale; drop bt to 128 for d_model=8192).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x ships TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

FAR = 1e30


def _router_kernel(x_ref, c_ref, inv2_ref, idx_ref, eff_ref, *, top_k: int,
                   block_e: int, e_real: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        idx_ref[...] = jnp.full_like(idx_ref, -1)
        eff_ref[...] = jnp.full_like(eff_ref, FAR)

    x = x_ref[...].astype(jnp.float32)                  # [bt, D]
    c = c_ref[...].astype(jnp.float32)                  # [block_e, D]
    inv2 = inv2_ref[...]                                # [1, block_e]
    xn = jnp.sum(x * x, axis=1, keepdims=True)
    cn = jnp.sum(c * c, axis=1)[None, :]
    sq = xn + cn - 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    eff = jnp.maximum(sq, 0.0) * inv2                   # [bt, block_e]
    # mask padded experts BEFORE the merge (k_real-style NaN/FAR guard)
    cols = j * block_e + jax.lax.broadcasted_iota(jnp.int32, eff.shape, 1)
    eff = jnp.where(cols < e_real, eff, FAR)

    # merge this tile into the running top-k: candidates = running top-k
    # (positions 0..top_k-1, so earlier tiles win ties) + the tile row
    cand_eff = jnp.concatenate([eff_ref[...], eff], axis=1)
    cand_idx = jnp.concatenate([idx_ref[...], cols], axis=1)
    width = top_k + block_e
    for ki in range(top_k):
        best = jnp.argmin(cand_eff, axis=1).astype(jnp.int32)
        taken = jax.nn.one_hot(best, width, dtype=jnp.bool_)
        idx_ref[:, ki] = jnp.sum(
            jnp.where(taken, cand_idx, 0), axis=1).astype(jnp.int32)
        eff_ref[:, ki] = jnp.min(cand_eff, axis=1)
        cand_eff = jnp.where(taken, FAR, cand_eff)


@functools.partial(jax.jit,
                   static_argnames=("top_k", "bt", "block_e", "e_real",
                                    "interpret"))
def router_topk_pallas(x, centroids, inv2, top_k: int, bt: int = 256,
                       block_e: int = 128, e_real: int | None = None,
                       interpret: bool = True):
    """x: [T, D] (T % bt == 0), centroids: [E, D] (E % block_e == 0),
    inv2: [E]. ``e_real`` = number of real (non-padded) experts.
    Returns (idx [T, top_k] int32, eff [T, top_k] f32)."""
    T, D = x.shape
    E = centroids.shape[0]
    if e_real is None:
        e_real = E
    if T % bt != 0:
        raise ValueError(
            f"router_topk_pallas: token axis T={T} is not a multiple of "
            f"bt={bt}; pad the token array (ops.router_topk does this)")
    if E % block_e != 0:
        raise ValueError(
            f"router_topk_pallas: expert axis E={E} is not a multiple of "
            f"block_e={block_e}; pad the centroid array (ops.router_topk "
            "does this)")
    kernel = functools.partial(_router_kernel, top_k=top_k,
                               block_e=block_e, e_real=e_real)
    return pl.pallas_call(
        kernel,
        grid=(T // bt, E // block_e),
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, D), lambda i, j: (j, 0)),
            pl.BlockSpec((1, block_e), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bt, top_k), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, top_k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, top_k), jnp.int32),
            jax.ShapeDtypeStruct((T, top_k), jnp.float32),
        ],
        # outputs are revisited running accumulators along the expert-tile
        # dimension -> it must be sequential; token tiles stay parallel
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, centroids, inv2[None, :])
