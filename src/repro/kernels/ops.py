"""Jit'd wrappers around the Pallas kernels + the assignment-backend
registry.

``assign_backend(name)`` is the single dispatch point for the balanced
k-means hot loop (effective-distance argmin). Every backend has the same
contract::

    fn(points [n,d], centers [k,d], influence [k], *,
       chunk, block_p, block_c) -> (idx [n] int32,
                                    best_eff_sq [n], second_eff_sq [n])

Backends registered with ``supports_moments=True`` additionally accept the
fused assign+reduce mode (the paper's whole movement-iteration hot loop in
ONE pass over the points)::

    fn(..., weights=[n], return_moments=True)
        -> (idx, best_eff_sq, second_eff_sq,
            csum [k,d], cw [k], rad2 [k])

where ``csum[c] = sum_{idx==c} w*p`` (weighted coordinate sums),
``cw[c] = sum w`` (weighted counts == cluster sizes) and
``rad2[c] = sum w*best_eff_sq`` (weighted best effective-sq distances, the
erosion radius numerator before the ``influence^2`` rescale). The core
falls back to ``segment_moments`` for backends without moment support;
that helper shares the per-chunk one-hot reduction of the ``jnp`` fused
path, so for the ``jnp`` backend fused and unfused results are
**bit-for-bit identical** by construction. The Pallas kernel accumulates
its moments in an f32 VMEM block across point tiles (TPUs have no f64), so
its fused moments match the reference to float tolerance, not bitwise.

Registered backends:

* ``jnp``    — chunked dense matmul (|p|^2 + |c|^2 - 2 p.c^T) with the
               point axis tiled by ``chunk`` to bound the n*k scratch;
               fused moments fold into the same chunk loop.
* ``pallas`` — the fused TPU kernel (assign_kernel.py): tile-level
               Hamerly/bbox pruning, centers pre-sorted by bbox distance,
               moments accumulated in VMEM across point tiles,
               double-buffered point-tile DMA when compiled.
* ``triton`` — the GPU-portable variant (triton_assign.py): 1-D grid over
               point tiles, in-kernel loop over center tiles, split-k
               moment partials — no TPU-only primitives, so the same body
               is Mosaic-GPU/Triton lowerable; interpret-verified on CPU.
* ``auto``   — per-platform resolution, in order: the
               ``REPRO_ASSIGN_BACKEND`` env override; ``pallas`` whenever
               ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode (the CI
               switch that exercises the kernel path on CPU); ``pallas``
               on real TPUs (``jnp`` for sub-tile shard_map shards);
               ``triton`` on GPUs; ``jnp`` on CPU.

All backends accept ``precision`` ("f32" default, "bf16" = bf16 distance
matmul with f32 accumulation — DESIGN.md §4c documents the tolerance) and
``chunk=None`` meaning ``default_chunk(k)``: the point-axis tile sized so
the [chunk, k] effective-distance scratch stays cache/VMEM-resident
(the roofline analysis in launch/kernel_roofline.py showed the former
fixed 65536 default spilling the scratch at k>=16 on bandwidth-bound
hosts, costing ~1.35x at the gate shape n=2^20 k=64).

Third-party backends can be added with ``@register_assign_backend(name)``
(e.g. a CUDA Triton port); ``BKMConfig.backend`` then selects them by
name. Pallas kernels themselves auto-detect compiled-vs-interpret from the
jax backend (assign_kernel.default_interpret); set
``REPRO_PALLAS_INTERPRET=0/1`` to force either mode, and
``REPRO_ASSIGN_BACKEND=<name>`` to pin what ``auto`` resolves to.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .assign_kernel import (assign_argmin_pallas, assign_reduce_pallas,
                            default_interpret)

_env = os.environ.get("REPRO_PALLAS_INTERPRET")
_INTERPRET: bool | None = None if _env is None else _env != "0"
_FAR = 1e30   # padded-center coordinate; masked out by k_real in-kernel


def _interpret_mode() -> bool:
    return default_interpret() if _INTERPRET is None else _INTERPRET


def default_chunk(k: int) -> int:
    """Point-axis chunk for the dense backends when the caller passes
    ``chunk=None``: size the [chunk, k] f32 effective-distance scratch to
    ~2 MB so it stays cache-resident on bandwidth-bound hosts (measured
    1.35x at n=2^20 k=64 vs the former fixed 65536 — see the roofline
    notes in launch/kernel_roofline.py), clamped to [2048, 65536].
    Chunking only tiles the point axis, so per-point results (labels,
    best/second) are bit-identical across chunk sizes; only the cross-
    chunk *moment* summation order changes."""
    return max(2048, min(65536, (1 << 19) // max(k, 1)))


# ---------------------------------------------------------------------------
# assignment-backend registry
# ---------------------------------------------------------------------------

_ASSIGN_BACKENDS: dict = {}
_ASSIGN_MOMENTS: set = set()   # backends accepting return_moments=True


def register_assign_backend(name: str, *, supports_moments: bool = False):
    """Decorator: register an effective-distance assignment backend.

    ``supports_moments=True`` declares that the backend implements the
    fused assign+reduce contract (``weights=``/``return_moments=`` keyword
    arguments, see the module docstring); backends without it fall back to
    a separate ``segment_moments`` sweep in the k-means core.
    """
    def deco(fn):
        _ASSIGN_BACKENDS[name] = fn
        if supports_moments:
            _ASSIGN_MOMENTS.add(name)
        return fn
    return deco


def available_assign_backends() -> list[str]:
    return sorted(_ASSIGN_BACKENDS) + ["auto"]


def resolve_assign_backend(name: str = "auto", *, sharded: bool = False,
                           n_local: int | None = None) -> str:
    """Map ``auto`` to a concrete backend for the current jax platform.

    Resolution order for ``auto`` (DESIGN.md §4c):

    1. ``REPRO_ASSIGN_BACKEND=<name>`` — env override, read per call so a
       test/CI leg can pin the resolution without re-importing. Only
       ``auto`` is overridden: an explicitly named backend always wins,
       so suites that exercise a specific backend stay meaningful under
       the override.
    2. forced interpret (``REPRO_PALLAS_INTERPRET=1``) → ``pallas`` —
       the CI switch that exercises the kernel code path (including the
       fused moment accumulators) on CPU-only runners.
    3. real TPU → ``pallas`` (but ``jnp`` for sub-tile shard_map shards,
       see below).
    4. GPU → ``triton`` (the portable 1-D-grid kernel; no TPU-only
       primitives, Mosaic-GPU lowerable).
    5. otherwise (CPU) → ``jnp``.

    Keyed off ``default_interpret()`` so the backend choice and the
    kernel's compiled-vs-interpret decision share one predicate.

    ``sharded=True`` marks resolution for a ``shard_map`` body (the
    distributed partitioner): the choice is pinned *before* tracing —
    ``jax.default_backend()`` is process-global, not trace-local — and
    because the Pallas kernel's tile pruning only pays off once the local
    shard spans at least one full point tile, shards smaller than
    ``n_local < 1024`` (the default ``block_p``) resolve to the chunked
    jnp path even on TPU hosts.
    """
    if name == "auto":
        env = os.environ.get("REPRO_ASSIGN_BACKEND")
        if env:
            if env not in _ASSIGN_BACKENDS:
                raise KeyError(
                    f"REPRO_ASSIGN_BACKEND={env!r} is not a registered "
                    f"assign backend; available: "
                    f"{available_assign_backends()}")
            return env
        if _INTERPRET:                 # forced interpret: cover the kernel
            return "pallas"
        if not default_interpret():    # real TPU
            if sharded and n_local is not None and n_local < 1024:
                return "jnp"
            return "pallas"
        if jax.default_backend() == "gpu":
            return "triton"
        return "jnp"
    if name not in _ASSIGN_BACKENDS:
        raise KeyError(f"unknown assign backend {name!r}; "
                       f"available: {available_assign_backends()}")
    return name


def assign_backend(name: str = "auto"):
    """Return the assignment callable for ``name`` (resolving ``auto``)."""
    return _ASSIGN_BACKENDS[resolve_assign_backend(name)]


def backend_supports_moments(name: str = "auto") -> bool:
    """True when ``name`` (resolved) implements fused assign+reduce."""
    return resolve_assign_backend(name) in _ASSIGN_MOMENTS


def _chunk_assign(p, cn, centers, inv2, precision: str = "f32"):
    """One dense chunk of the effective-distance argmin. Returns
    (idx, best, second, onehot) — ``onehot`` [C, k] bool marks each
    point's winning center and is reused by the fused moment reduction.
    ``precision="bf16"`` casts only the cross-term matmul operands to
    bfloat16 (f32 accumulation); norms and the epilogue stay f32."""
    pn = jnp.sum(p * p, axis=1, keepdims=True)
    if precision == "bf16":
        cross2 = 2.0 * jax.lax.dot_general(
            p.astype(jnp.bfloat16), centers.astype(jnp.bfloat16),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    else:
        cross2 = 2.0 * p @ centers.T    # == (2p) @ c.T, the legacy form
    sq = pn + cn[None, :] - cross2
    eff = jnp.maximum(sq, 0.0) * inv2[None, :]
    k = eff.shape[1]
    # argmin-free epilogue: XLA CPU lowers arg-reductions to a scalar
    # loop, while plain min/max vectorize. min + exact-equality + an
    # integer max over (k - j) recovers the *first* index attaining the
    # min — bit-identical to jnp.argmin (min returns an element of the
    # row exactly), measured ~1.5x on the n=2^20 hot loop.
    best = jnp.min(eff, axis=1)
    iseq = eff == best[:, None]
    rev = jnp.arange(k, 0, -1, dtype=jnp.int32)
    idx = (k - jnp.max(iseq * rev[None, :], axis=1)).astype(jnp.int32)
    onehot = idx[:, None] == jnp.arange(k)[None, :]
    second = jnp.min(jnp.where(onehot, jnp.inf, eff), axis=1)
    return idx, best, second, onehot


def _chunk_moments(onehot, p, w, best):
    """Per-chunk weighted moment partial as one [k, d+2] matmul:
    columns 0..d-1 = sum w*p, column d = sum w, column d+1 = sum w*best.
    Shared by the fused ``jnp`` backend and ``segment_moments`` so both
    accumulate in the identical order (bit-for-bit equal results)."""
    ww = jnp.where(onehot, w[:, None], 0.0)                  # [C, k]
    stacked = jnp.concatenate(
        [p, jnp.ones((p.shape[0], 1), p.dtype), best[:, None]], axis=1)
    return ww.T @ stacked                                    # [k, d+2]


def _split_moments(m, d):
    return m[:, :d], m[:, d], m[:, d + 1]


def segment_moments(points, weights, idx, best_sq, k: int, *,
                    chunk: int | None = None):
    """Per-cluster weighted moments of an existing assignment — the
    unfused fallback for assignment backends without moment support.

    Args:
        points: [n, d] point coordinates.
        weights: [n] nonneg weights (0 marks padded points).
        idx: [n] int32 cluster assignment.
        best_sq: [n] best effective *squared* distances (as returned by
            the assignment backends).
        k: number of clusters.
        chunk: point-axis tile (None = ``default_chunk(k)``); MUST match
            the assignment call's chunk for bit-exact agreement with the
            fused path (both resolve None identically, so leaving both
            unset is safe).

    Returns:
        (csum [k, d], cw [k], rad2 [k]) — weighted coordinate sums,
        weighted counts, and weighted best-eff-sq sums. Uses the same
        per-chunk one-hot matmul partials (and the same cross-chunk
        summation) as the fused ``jnp`` backend, so the results are
        bit-for-bit identical to ``return_moments=True``.
    """
    n, d = points.shape
    if chunk is None:
        chunk = default_chunk(k)
    arange_k = jnp.arange(k)[None, :]

    def one(p, w, ix, b):
        return _chunk_moments(ix[:, None] == arange_k, p, w, b)

    if n <= chunk:
        return _split_moments(one(points, weights, idx, best_sq), d)
    pad = (-n) % chunk
    p = jnp.pad(points, ((0, pad), (0, 0))).reshape(-1, chunk, d)
    w = jnp.pad(weights, (0, pad)).reshape(-1, chunk)
    ix = jnp.pad(idx, (0, pad)).reshape(-1, chunk)
    b = jnp.pad(best_sq, (0, pad)).reshape(-1, chunk)
    m = jax.lax.map(lambda a: one(*a), (p, w, ix, b)).sum(axis=0)
    return _split_moments(m, d)


@register_assign_backend("jnp", supports_moments=True)
def assign_argmin_jnp(points, centers, influence, *,
                      chunk: int | None = None,
                      block_p: int = 1024, block_c: int = 128,
                      weights=None, return_moments: bool = False,
                      precision: str = "f32"):
    """Chunked dense path (the paper's inner loop as one matmul per chunk).
    ``block_p``/``block_c`` are accepted for contract parity and ignored.
    ``chunk=None`` resolves to ``default_chunk(k)`` (cache-resident
    [chunk, k] scratch); per-point results are chunk-invariant, so the
    default change is label-bitexact vs any fixed chunk.

    With ``return_moments=True`` (requires ``weights``) the per-cluster
    moment partials are computed inside the same chunk loop while the
    chunk is hot, so the point array is streamed exactly once; the
    cross-chunk accumulation matches ``segment_moments`` bit-for-bit.
    """
    del block_p, block_c
    if return_moments and weights is None:
        raise ValueError("return_moments=True requires weights")
    if chunk is None:
        chunk = default_chunk(centers.shape[0])
    inv2 = 1.0 / (influence * influence)
    cn = jnp.sum(centers * centers, axis=1)
    n, d = points.shape

    def one_chunk(p):
        return _chunk_assign(p, cn, centers, inv2, precision)[:3]

    def one_chunk_fused(p, w):
        idx, best, second, onehot = _chunk_assign(p, cn, centers, inv2,
                                                  precision)
        return idx, best, second, _chunk_moments(onehot, p, w, best)

    if n <= chunk:
        if not return_moments:
            return one_chunk(points)
        idx, b, s, m = one_chunk_fused(points, weights)
        return (idx, b, s) + _split_moments(m, d)
    pad = (-n) % chunk
    pts = jnp.pad(points, ((0, pad), (0, 0))).reshape(-1, chunk, d)
    if not return_moments:
        idx, b, s = jax.lax.map(one_chunk, pts)
        return idx.reshape(-1)[:n], b.reshape(-1)[:n], s.reshape(-1)[:n]
    w = jnp.pad(weights, (0, pad)).reshape(-1, chunk)
    idx, b, s, m = jax.lax.map(lambda a: one_chunk_fused(*a), (pts, w))
    return ((idx.reshape(-1)[:n], b.reshape(-1)[:n], s.reshape(-1)[:n])
            + _split_moments(m.sum(axis=0), d))


def _tile_bounds(points, centers, inv2, block_p, block_c):
    """Lower bound of effective sqdist between each point-tile's bbox and
    each center tile: max(0, bbox-distance)^2 * max tile inv2."""
    n, d = points.shape
    k = centers.shape[0]
    pt = points.reshape(n // block_p, block_p, d)
    lo = jnp.min(pt, axis=1)                       # [nPT, d]
    hi = jnp.max(pt, axis=1)
    ct = centers.reshape(k // block_c, block_c, d)  # [nCT, BC, d]
    # distance of each center to each tile bbox
    cexp = ct[None]                                 # [1, nCT, BC, d]
    gap = jnp.maximum(jnp.maximum(lo[:, None, None, :] - cexp,
                                  cexp - hi[:, None, None, :]), 0.0)
    d2 = jnp.sum(gap * gap, axis=-1)                # [nPT, nCT, BC]
    inv2_t = inv2.reshape(k // block_c, block_c)    # [nCT, BC]
    eff = d2 * inv2_t[None]                         # per-center bound
    return jnp.min(eff, axis=-1)                    # [nPT, nCT]


@functools.partial(jax.jit, static_argnames=("block_p", "block_c",
                                             "return_moments", "precision",
                                             "double_buffer"))
def assign_argmin(points, centers, influence, block_p: int = 1024,
                  block_c: int = 128, weights=None,
                  return_moments: bool = False, precision: str = "f32",
                  double_buffer: bool | None = None):
    """Drop-in replacement for ref.assign_argmin_ref (same returns).

    ``return_moments=True`` (requires ``weights``) runs the fused
    assign+reduce kernel: the per-cluster weighted moments are accumulated
    in VMEM across point tiles and un-sorted back to original center ids
    here, so the [n, d] point array is streamed exactly once.
    ``precision``/``double_buffer`` pass through to the kernel (DESIGN.md
    §4c): bf16 distance matmul and manual two-slot point-tile DMA.
    """
    n, d = points.shape
    k = centers.shape[0]
    inv2 = 1.0 / (influence * influence)

    # sort centers by effective distance to the global point bbox so that
    # prunable center tiles appear late in the sequential grid dimension
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    gap = jnp.maximum(jnp.maximum(lo[None] - centers, centers - hi[None]), 0.0)
    key = jnp.sum(gap * gap, axis=1) * inv2
    order = jnp.argsort(key)
    centers_s = centers[order]
    inv2_s = inv2[order]

    pad_n = (-n) % block_p
    pad_k = (-k) % block_c
    pts = jnp.pad(points, ((0, pad_n), (0, 0))).astype(jnp.float32)
    cts = jnp.pad(centers_s, ((0, pad_k), (0, 0)),
                  constant_values=_FAR).astype(jnp.float32)
    iv2 = jnp.pad(inv2_s, (0, pad_k), constant_values=1.0).astype(jnp.float32)

    bounds = _tile_bounds(pts, cts, iv2, block_p, block_c)
    if return_moments:
        if weights is None:
            raise ValueError("return_moments=True requires weights")
        w = jnp.pad(weights, (0, pad_n)).astype(jnp.float32)
        idx_s, best, second, m = assign_reduce_pallas(
            pts, cts, iv2, bounds, w, k_real=k, block_p=block_p,
            block_c=block_c, interpret=_interpret_mode(),
            precision=precision, double_buffer=double_buffer)
        # un-sort the [d+2, K_pad] moment block: sorted column j belongs
        # to original center order[j]; padded columns carry no weight
        m_orig = jnp.zeros((k, d + 2), jnp.float32).at[order].set(m.T[:k])
        idx_s, best, second = idx_s[:n], best[:n], second[:n]
        idx = order[jnp.clip(idx_s, 0, k - 1)].astype(jnp.int32)
        return (idx, best, second,
                m_orig[:, :d], m_orig[:, d], m_orig[:, d + 1])
    idx_s, best, second = assign_argmin_pallas(
        pts, cts, iv2, bounds, k_real=k, block_p=block_p, block_c=block_c,
        interpret=_interpret_mode(), precision=precision,
        double_buffer=double_buffer)
    idx_s, best, second = idx_s[:n], best[:n], second[:n]
    # map sorted-center index back to the original center id
    idx = order[jnp.clip(idx_s, 0, k - 1)].astype(jnp.int32)
    return idx, best, second


@register_assign_backend("pallas", supports_moments=True)
def assign_argmin_pallas_backend(points, centers, influence, *,
                                 chunk: int | None = None,
                                 block_p: int = 1024,
                                 block_c: int = 128, weights=None,
                                 return_moments: bool = False,
                                 precision: str = "f32"):
    """Registry adapter for the Pallas kernel (``chunk`` is ignored: the
    kernel's own point tiling bounds VMEM)."""
    del chunk
    return assign_argmin(points, centers, influence,
                         block_p=block_p, block_c=block_c,
                         weights=weights, return_moments=return_moments,
                         precision=precision)


def tile_prune_fraction(points, centers, influence, second_sq,
                        block_p: int = 1024, block_c: int = 128):
    """Host-side estimate of the fraction of (point-tile × center-tile)
    grid steps the Pallas kernel's ``pl.when`` bbox bound prunes, for
    ``stats["tiles_pruned_frac"]`` (useful-vs-wasted compute in the
    roofline table).

    Mirrors the kernel's setup — centers sorted by bbox distance, point
    and center axes padded to tile multiples (edge-replicated points so
    tile bboxes stay tight) — then counts pairs whose bound cannot beat
    the point tile's worst *converged* second-best (``second_sq``, in
    effective-squared space, e.g. ``lb**2`` after a balance pass). The
    first center tile is never pruned (the kernel unconditionally
    computes j == 0 to initialize its accumulators). This is the
    steady-state bound — inside one sweep the kernel's running
    second-best starts at +inf, so the realized fraction converges to
    this value from below. Traceable; psum the numerator under shard_map
    (balanced_kmeans averages it over shards).
    """
    n, d = points.shape
    k = centers.shape[0]
    inv2 = 1.0 / (influence * influence)
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    gap = jnp.maximum(jnp.maximum(lo[None] - centers, centers - hi[None]),
                      0.0)
    key = jnp.sum(gap * gap, axis=1) * inv2
    order = jnp.argsort(key)
    pad_n = (-n) % block_p
    pad_k = (-k) % block_c
    pts = jnp.pad(points, ((0, pad_n), (0, 0)), mode="edge")
    cts = jnp.pad(centers[order], ((0, pad_k), (0, 0)),
                  constant_values=_FAR)
    iv2 = jnp.pad(inv2[order], (0, pad_k), constant_values=1.0)
    bounds = _tile_bounds(pts.astype(jnp.float32), cts.astype(jnp.float32),
                          iv2.astype(jnp.float32), block_p, block_c)
    sec = jnp.pad(second_sq, (0, pad_n), mode="edge")
    # a tile prunes only when the bound beats its WORST second-best; an
    # infinite second (k == 1) makes the tile unprunable, as in-kernel
    worst = jnp.max(sec.reshape(-1, block_p), axis=1)     # [nPT]
    prunable = bounds >= worst[:, None]
    prunable = prunable.at[:, 0].set(False)               # j == 0 runs
    return jnp.mean(prunable.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("bq", "bk", "softcap"))
def flash_attention(q, k, v, bq: int = 512, bk: int = 512,
                    softcap: float = 0.0):
    """Causal flash attention. q: [B, S, H, dh], k/v: [B, S, KV, dh]
    (H % KV == 0). Pads S to the tile size; padded keys sit above the
    causal diagonal of every real query, so no extra masking is needed.
    Returns [B, S, H, dh]."""
    from .flash_attention import flash_attention_pallas
    B, S, H, dh = q.shape
    KV = k.shape[2]
    bq = min(bq, max(128, 1 << (S - 1).bit_length()))
    bk = min(bk, bq)
    pad = (-S) % max(bq, bk)
    qt = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    qh = qt.transpose(0, 2, 1, 3).reshape(B * H, Sp, dh)
    kh = kt.transpose(0, 2, 1, 3).reshape(B * KV, Sp, dh)
    vh = vt.transpose(0, 2, 1, 3).reshape(B * KV, Sp, dh)
    o = flash_attention_pallas(qh, kh, vh, bq=bq, bk=bk, softcap=softcap,
                               interpret=_interpret_mode())
    o = o.reshape(B, H, Sp, dh).transpose(0, 2, 1, 3)
    return o[:, :S]


@functools.partial(jax.jit, static_argnames=("top_k", "bt", "block_e"))
def router_topk(x, centroids, influence, top_k: int, bt: int = 256,
                block_e: int = 128):
    """Fused balanced-k-means MoE routing. x: [T, D], centroids: [E, D],
    influence: [E]. Returns (idx [T, top_k], eff [T, top_k]). E may exceed
    one VMEM tile: the kernel sweeps center tiles of ``block_e`` through
    the shared tiled path, merging a running top-k across tiles."""
    from .moe_router_kernel import router_topk_pallas
    T, D = x.shape
    E = centroids.shape[0]
    inv2 = 1.0 / (influence * influence)
    pad_t = (-T) % bt
    pad_e = (-E) % block_e
    xp = jnp.pad(x, ((0, pad_t), (0, 0))).astype(jnp.float32)
    cp = jnp.pad(centroids, ((0, pad_e), (0, 0)),
                 constant_values=_FAR).astype(jnp.float32)
    ip = jnp.pad(inv2, (0, pad_e), constant_values=1.0).astype(jnp.float32)
    idx, eff = router_topk_pallas(xp, cp, ip, top_k=top_k, bt=bt,
                                  block_e=block_e, e_real=E,
                                  interpret=_interpret_mode())
    return idx[:T], eff[:T]


# registering the triton-shaped backend imports this module back, so the
# import must sit after every name it needs is defined
from . import triton_assign as _triton_assign  # noqa: E402,F401
