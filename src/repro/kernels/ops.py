"""Jit'd wrappers around the Pallas kernels + the assignment-backend
registry.

``assign_backend(name)`` is the single dispatch point for the balanced
k-means hot loop (effective-distance argmin). Every backend has the same
contract::

    fn(points [n,d], centers [k,d], influence [k], *,
       chunk, block_p, block_c) -> (idx [n] int32,
                                    best_eff_sq [n], second_eff_sq [n])

Registered backends:

* ``jnp``    — chunked dense matmul (|p|^2 + |c|^2 - 2 p.c^T) with the
               point axis tiled by ``chunk`` to bound the n*k scratch.
* ``pallas`` — the fused TPU kernel (assign_kernel.py): tile-level
               Hamerly/bbox pruning, centers pre-sorted by bbox distance.
* ``auto``   — resolves to ``pallas`` on TPU hosts and ``jnp`` elsewhere.

Third-party backends can be added with ``@register_assign_backend(name)``
(e.g. a CUDA Triton port); ``BKMConfig.backend`` then selects them by
name. Pallas kernels themselves auto-detect compiled-vs-interpret from the
jax backend (assign_kernel.default_interpret); set
``REPRO_PALLAS_INTERPRET=0/1`` to force either mode.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .assign_kernel import assign_argmin_pallas, default_interpret

_env = os.environ.get("REPRO_PALLAS_INTERPRET")
_INTERPRET: bool | None = None if _env is None else _env != "0"
_FAR = 1e30   # padded-center coordinate; effective distance ~1e60, never wins


def _interpret_mode() -> bool:
    return default_interpret() if _INTERPRET is None else _INTERPRET


# ---------------------------------------------------------------------------
# assignment-backend registry
# ---------------------------------------------------------------------------

_ASSIGN_BACKENDS: dict = {}


def register_assign_backend(name: str):
    """Decorator: register an effective-distance assignment backend."""
    def deco(fn):
        _ASSIGN_BACKENDS[name] = fn
        return fn
    return deco


def available_assign_backends() -> list[str]:
    return sorted(_ASSIGN_BACKENDS) + ["auto"]


def resolve_assign_backend(name: str = "auto", *, sharded: bool = False,
                           n_local: int | None = None) -> str:
    """Map ``auto`` to a concrete backend for the current jax platform.
    Keyed off ``default_interpret()`` so the backend choice and the
    kernel's compiled-vs-interpret decision share one predicate.

    ``sharded=True`` marks resolution for a ``shard_map`` body (the
    distributed partitioner): the choice is pinned *before* tracing —
    ``jax.default_backend()`` is process-global, not trace-local — and
    because the Pallas kernel's tile pruning only pays off once the local
    shard spans at least one full point tile, shards smaller than
    ``n_local < 1024`` (the default ``block_p``) resolve to the chunked
    jnp path even on TPU hosts.
    """
    if name == "auto":
        if default_interpret():
            return "jnp"
        if sharded and n_local is not None and n_local < 1024:
            return "jnp"
        return "pallas"
    if name not in _ASSIGN_BACKENDS:
        raise KeyError(f"unknown assign backend {name!r}; "
                       f"available: {available_assign_backends()}")
    return name


def assign_backend(name: str = "auto"):
    """Return the assignment callable for ``name`` (resolving ``auto``)."""
    return _ASSIGN_BACKENDS[resolve_assign_backend(name)]


@register_assign_backend("jnp")
def assign_argmin_jnp(points, centers, influence, *, chunk: int = 65536,
                      block_p: int = 1024, block_c: int = 128):
    """Chunked dense path (the paper's inner loop as one matmul per chunk).
    ``block_p``/``block_c`` are accepted for contract parity and ignored."""
    del block_p, block_c
    inv2 = 1.0 / (influence * influence)
    cn = jnp.sum(centers * centers, axis=1)

    def one_chunk(p):
        pn = jnp.sum(p * p, axis=1, keepdims=True)
        sq = pn + cn[None, :] - 2.0 * p @ centers.T
        eff = jnp.maximum(sq, 0.0) * inv2[None, :]
        idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
        best = jnp.take_along_axis(eff, idx[:, None], axis=1)[:, 0]
        masked = eff.at[jnp.arange(p.shape[0]), idx].set(jnp.inf)
        second = jnp.min(masked, axis=1)
        return idx, best, second

    n = points.shape[0]
    if n <= chunk:
        return one_chunk(points)
    pad = (-n) % chunk
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    pts = pts.reshape(-1, chunk, points.shape[1])
    idx, b, s = jax.lax.map(one_chunk, pts)
    return idx.reshape(-1)[:n], b.reshape(-1)[:n], s.reshape(-1)[:n]


def _tile_bounds(points, centers, inv2, block_p, block_c):
    """Lower bound of effective sqdist between each point-tile's bbox and
    each center tile: max(0, bbox-distance)^2 * max tile inv2."""
    n, d = points.shape
    k = centers.shape[0]
    pt = points.reshape(n // block_p, block_p, d)
    lo = jnp.min(pt, axis=1)                       # [nPT, d]
    hi = jnp.max(pt, axis=1)
    ct = centers.reshape(k // block_c, block_c, d)  # [nCT, BC, d]
    # distance of each center to each tile bbox
    cexp = ct[None]                                 # [1, nCT, BC, d]
    gap = jnp.maximum(jnp.maximum(lo[:, None, None, :] - cexp,
                                  cexp - hi[:, None, None, :]), 0.0)
    d2 = jnp.sum(gap * gap, axis=-1)                # [nPT, nCT, BC]
    inv2_t = inv2.reshape(k // block_c, block_c)    # [nCT, BC]
    eff = d2 * inv2_t[None]                         # per-center bound
    return jnp.min(eff, axis=-1)                    # [nPT, nCT]


@functools.partial(jax.jit, static_argnames=("block_p", "block_c"))
def assign_argmin(points, centers, influence, block_p: int = 1024,
                  block_c: int = 128):
    """Drop-in replacement for ref.assign_argmin_ref (same returns)."""
    n, d = points.shape
    k = centers.shape[0]
    inv2 = 1.0 / (influence * influence)

    # sort centers by effective distance to the global point bbox so that
    # prunable center tiles appear late in the sequential grid dimension
    lo = jnp.min(points, axis=0)
    hi = jnp.max(points, axis=0)
    gap = jnp.maximum(jnp.maximum(lo[None] - centers, centers - hi[None]), 0.0)
    key = jnp.sum(gap * gap, axis=1) * inv2
    order = jnp.argsort(key)
    centers_s = centers[order]
    inv2_s = inv2[order]

    pad_n = (-n) % block_p
    pad_k = (-k) % block_c
    pts = jnp.pad(points, ((0, pad_n), (0, 0))).astype(jnp.float32)
    cts = jnp.pad(centers_s, ((0, pad_k), (0, 0)),
                  constant_values=_FAR).astype(jnp.float32)
    iv2 = jnp.pad(inv2_s, (0, pad_k), constant_values=1.0).astype(jnp.float32)

    bounds = _tile_bounds(pts, cts, iv2, block_p, block_c)
    idx_s, best, second = assign_argmin_pallas(
        pts, cts, iv2, bounds, block_p=block_p, block_c=block_c,
        interpret=_interpret_mode())
    idx_s, best, second = idx_s[:n], best[:n], second[:n]
    # map sorted-center index back to the original center id
    idx = order[jnp.clip(idx_s, 0, k - 1)].astype(jnp.int32)
    return idx, best, second


@register_assign_backend("pallas")
def assign_argmin_pallas_backend(points, centers, influence, *,
                                 chunk: int = 65536, block_p: int = 1024,
                                 block_c: int = 128):
    """Registry adapter for the Pallas kernel (``chunk`` is ignored: the
    kernel's own point tiling bounds VMEM)."""
    del chunk
    return assign_argmin(points, centers, influence,
                         block_p=block_p, block_c=block_c)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "softcap"))
def flash_attention(q, k, v, bq: int = 512, bk: int = 512,
                    softcap: float = 0.0):
    """Causal flash attention. q: [B, S, H, dh], k/v: [B, S, KV, dh]
    (H % KV == 0). Pads S to the tile size; padded keys sit above the
    causal diagonal of every real query, so no extra masking is needed.
    Returns [B, S, H, dh]."""
    from .flash_attention import flash_attention_pallas
    B, S, H, dh = q.shape
    KV = k.shape[2]
    bq = min(bq, max(128, 1 << (S - 1).bit_length()))
    bk = min(bk, bq)
    pad = (-S) % max(bq, bk)
    qt = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kt = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vt = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    qh = qt.transpose(0, 2, 1, 3).reshape(B * H, Sp, dh)
    kh = kt.transpose(0, 2, 1, 3).reshape(B * KV, Sp, dh)
    vh = vt.transpose(0, 2, 1, 3).reshape(B * KV, Sp, dh)
    o = flash_attention_pallas(qh, kh, vh, bq=bq, bk=bk, softcap=softcap,
                               interpret=_interpret_mode())
    o = o.reshape(B, H, Sp, dh).transpose(0, 2, 1, 3)
    return o[:, :S]


@functools.partial(jax.jit, static_argnames=("top_k", "bt"))
def router_topk(x, centroids, influence, top_k: int, bt: int = 256):
    """Fused balanced-k-means MoE routing. x: [T, D], centroids: [E, D],
    influence: [E]. Returns (idx [T, top_k], eff [T, top_k])."""
    from .moe_router_kernel import router_topk_pallas
    T, D = x.shape
    E = centroids.shape[0]
    inv2 = 1.0 / (influence * influence)
    pad_t = (-T) % bt
    pad_e = (-E) % 128
    xp = jnp.pad(x, ((0, pad_t), (0, 0))).astype(jnp.float32)
    cp = jnp.pad(centroids, ((0, pad_e), (0, 0)),
                 constant_values=_FAR).astype(jnp.float32)
    ip = jnp.pad(inv2, (0, pad_e), constant_values=1.0).astype(jnp.float32)
    idx, eff = router_topk_pallas(xp, cp, ip, top_k=top_k, bt=bt,
                                  interpret=_interpret_mode())
    return idx[:T], eff[:T]
