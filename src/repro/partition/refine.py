"""``refine()`` — sharded label-propagation refinement (DESIGN.md §11).

The paper concedes (§5-6) that graph-based partitioners beat geometric
ones on cut / communication volume. Size-constrained label propagation is
the standard cheap post-pass (Buluc et al., *Recent Advances in Graph
Partitioning*): iteratively move boundary nodes to their neighbor-majority
block as long as the balance constraint allows it. This module is that
pass, grown onto the engine::

    from repro.partition import PartitionProblem, partition, refine

    prob = PartitionProblem.from_mesh(mesh, k=32)
    res  = partition(prob, method="geographer")
    ref  = refine(prob, res)                       # host reference
    ref  = refine(prob, res, devices=8)            # sharded, bit-identical
    ref  = partition(prob, method="rcb", refine=True)   # composed

Algorithm (one synchronous round, identical on host and shards):

1.  Resolve the global label vector: each shard scatters its labels into
    an [n] zero vector at its own global positions; the psum of those
    partials IS the replicated vector (``repro.eval.sharded``'s one-[n]-
    psum neighbor-label discipline — no all_gather).
2.  Per-block weight budgets: quantized (fixed-point integer) block
    weights are psum'd as a [k] partial sum; ``budget_b = limit - W_b``
    where ``limit = floor((1+eps) * W / k) - margin`` is a static int.
3.  Every node builds its neighbor-label histogram H[v, :] (unit edge
    weights) and picks the best *admissible* target: the argmax of H over
    blocks whose budget fits the node's weight, ties broken by lowest
    block id (``argmax`` returns the first maximum on host numpy and
    under XLA alike). A node is a candidate when that target's gain
    ``H[v, t] - H[v, label(v)]`` is positive.
4.  Independent-set filter: a candidate moves only if no neighboring
    candidate has strictly higher priority ``(gain, then lower node
    key)``. Accepted moves therefore never touch two adjacent nodes in
    one round, so each frozen-label gain is exact and the edge cut
    decreases by the sum of accepted gains — refinement can NEVER
    increase the cut.
5.  Budget acceptance: surviving candidates are ordered globally by
    (target block, gain desc, node key asc) and accepted per block while
    the running quantized weight stays within the budget. All arithmetic
    is integer, so every device — and the host reference — computes the
    same accepted set bit for bit.
6.  Rounds repeat under ``lax.while_loop`` until a round accepts no move
    (or ``max_rounds``). Zero accepted moves <=> zero candidates (the
    first survivor of every target segment always fits its budget), so
    natural convergence certifies local optimality: no admissible single
    positive-gain move remains (property- and oracle-tested in
    tests/test_refinement_properties.py).

Determinism rules:

* All tie-breaks are total orders over integers: block id for target
  selection, the node key for move priority. Keys default to the original
  point order (``arange(n)``) and can be overridden via ``node_order`` —
  passing permutation-consistent keys makes refinement exactly
  equivariant under point permutations.
* Block ids are canonicalized on entry (rank of each block's minimum
  member key) and mapped back on exit, so refinement is exactly
  equivariant under block relabelings.
* Node weights go through ``core.metrics.quantize_weights`` fixed-point
  integers; the budget ``limit`` subtracts a margin of n quantization
  units (0 for unit weights), which over-covers the worst-case rounding
  drift so the *real*-weight imbalance never exceeds eps either.
* The sharded path is **bit-for-bit equal** to the host numpy reference
  at every device count: every decision is made from replicated vectors
  assembled by integer psums, and integer additions commute exactly.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

from repro.core.metrics import edge_cut, imbalance, quantize_weights

from .problem import PartitionProblem, PartitionResult

#: rounds cap — cut strictly decreases every effective round, so this is
#: a static trace bound, not a tuning knob (convergence is usually O(10))
DEFAULT_MAX_ROUNDS = 128

_REFINERS: dict[str, Callable] = {}
_ALIASES: dict[str, str] = {}
_SHORT: dict[str, str] = {}


class UnknownRefinerError(KeyError):
    pass


def register_refiner(name: str, aliases: tuple[str, ...] = (),
                     short: str | None = None):
    """Decorator: register a refinement pass under ``name`` (+ aliases) —
    the refiner registry sits next to the solver registry so
    ``partition(..., refine=...)`` resolves through the same front-door
    discipline (typos fail loudly, aliases resolve).

    Args:
        name: canonical registry key.
        aliases: extra names resolving to ``name``.
        short: suffix used in composed method names / benchmark tool
            columns (default: the canonical name).
    """
    def deco(fn: Callable) -> Callable:
        if name in _REFINERS:
            raise ValueError(f"refiner {name!r} already registered")
        _REFINERS[name] = fn
        _SHORT[name] = short or name
        for a in aliases:
            _ALIASES[a] = name
        return fn
    return deco


def resolve_refiner(name) -> str:
    """Canonical refiner name (aliases resolve; True means the default)."""
    if name is True:
        name = "label_prop"
    name = _ALIASES.get(name, name)
    if name not in _REFINERS:
        raise UnknownRefinerError(
            f"unknown refinement method {name!r}; available: "
            f"{available_refiners()} (aliases: {sorted(_ALIASES)})")
    return name


def available_refiners() -> list[str]:
    """Sorted canonical names of every registered refinement pass."""
    return sorted(_REFINERS)


def refiner_short_name(name) -> str:
    """Suffix for composed method names, e.g. ``'lp'`` -> "geographer+lp"."""
    return _SHORT[resolve_refiner(name)]


# ---------------------------------------------------------------------------
# balance-budget protocol (shared by host, shards, and the test oracle)

def refinement_quantization(problem: PartitionProblem,
                            eps: float | None = None
                            ) -> tuple[np.ndarray, int]:
    """The fixed-point balance protocol of one refinement call.

    Args:
        problem: the partitioning instance.
        eps: balance slack (None = ``problem.epsilon``).

    Returns:
        (iw [n] int64 quantized node weights, limit int) — a block may
        never be filled past ``limit`` quantized units. ``limit`` shaves
        a margin of n units off ``floor((1+eps) * sum(iw) / k)`` for
        float weights (absorbing worst-case 0.5/node rounding drift so
        the real-weight imbalance stays <= eps too); unit weights
        quantize exactly, so their margin is 0.
    """
    eps = problem.epsilon if eps is None else float(eps)
    if eps < 0:
        raise ValueError(f"eps must be >= 0, got {eps}")
    iw = quantize_weights(problem.weights, problem.n)
    margin = 0 if problem.weights is None else problem.n
    W = int(iw.sum())
    limit = int(np.floor((1.0 + eps) * W / problem.k)) - margin
    # a block can never hold more than the total weight, so clamping the
    # limit at W is semantics-preserving and keeps every budget value
    # int32-safe on device (W <= 2^30 - 1 by the quantization scale)
    return iw, min(max(limit, 0), W)


def refinement_budgets(problem: PartitionProblem, labels: np.ndarray,
                       eps: float | None = None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Round-start admissibility budgets for ``labels`` — the quantity
    the in-graph rounds psum, exposed host-side for the oracle tests.

    Args:
        problem: the partitioning instance.
        labels: [n] block ids.
        eps: balance slack (None = ``problem.epsilon``).

    Returns:
        (iw [n] int64, budget [k] int64): a move of node v into block b
        is admissible iff ``iw[v] <= budget[b]``.
    """
    iw, limit = refinement_quantization(problem, eps)
    W = np.bincount(np.asarray(labels), weights=iw,
                    minlength=problem.k).astype(np.int64)
    return iw, np.maximum(limit - W, 0)


def _canonicalize(labels: np.ndarray, keys: np.ndarray,
                  k: int) -> tuple[np.ndarray, np.ndarray]:
    """Map block ids to their canonical order (rank of each block's
    minimum member key; empty blocks trail). Returns (canonical labels,
    order) with ``order[canonical_id] = original_id`` — the inverse map.

    Because the canonical space depends only on *which nodes share a
    block* (never on the id values), running the rounds in canonical
    space makes refinement exactly equivariant under block relabelings.
    Empty blocks are never move targets (their histogram column is all
    zero, so no positive gain exists), so their trailing placement never
    influences a decision.
    """
    first = np.full(k, np.iinfo(np.int64).max, np.int64)
    np.minimum.at(first, labels, keys.astype(np.int64))
    order = np.lexsort((np.arange(k), first))
    canon = np.empty(k, np.int64)
    canon[order] = np.arange(k)
    return canon[labels], order


# ---------------------------------------------------------------------------
# host reference (the bit-exactness anchor)

def _lp_rounds_host(labels: np.ndarray, indptr: np.ndarray,
                    indices: np.ndarray, iw: np.ndarray, keys: np.ndarray,
                    k: int, limit: int, max_rounds: int):
    """Pure-numpy synchronous rounds — the reference the sharded kernel
    must match bit for bit. Returns (labels, rounds, moves, last_moved).
    """
    n = labels.shape[0]
    labels = labels.astype(np.int64).copy()
    src = np.repeat(np.arange(n), np.diff(indptr))
    arange_n = np.arange(n)
    rounds, moves_total, moved = 0, 0, 1
    while rounds < max_rounds and moved > 0:
        W = np.bincount(labels, weights=iw, minlength=k).astype(np.int64)
        budget = np.maximum(limit - W, 0)
        nb = labels[indices]
        H = np.zeros((n, k), np.int64)
        np.add.at(H, (src, nb), 1)
        own = H[arange_n, labels]
        adm = budget[None, :] >= iw[:, None]
        Hm = np.where(adm, H, -1)
        tgt = np.argmax(Hm, axis=1)
        gain = np.where(Hm[arange_n, tgt] > own,
                        Hm[arange_n, tgt] - own, 0)
        # independent-set filter: a candidate yields to any neighboring
        # candidate of strictly higher (gain, lower-key) priority
        myg, nbg = gain[src], gain[indices]
        myk, nbk = keys[src], keys[indices]
        dom_e = (nbg > myg) | ((nbg == myg) & (nbk < myk))
        dom = np.zeros(n, bool)
        np.logical_or.at(dom, src, dom_e)
        acc0 = (gain > 0) & ~dom
        # per-target budget acceptance in (gain desc, key asc) order
        stgt = np.where(acc0, tgt, k)
        order = np.lexsort((keys, -gain, stgt))
        st = stgt[order]
        siw = np.where(acc0, iw, 0)[order]
        csum = np.cumsum(siw)
        is_start = np.ones(n, bool)
        is_start[1:] = st[1:] != st[:-1]
        base = np.maximum.accumulate(np.where(is_start, csum - siw, 0))
        ok = (st < k) & (csum - base <= budget[np.minimum(st, k - 1)])
        accept = np.zeros(n, bool)
        accept[order] = ok
        moved = int(accept.sum())
        labels = np.where(accept, tgt, labels)
        rounds += 1
        moves_total += moved
    return labels, rounds, moves_total, moved


# ---------------------------------------------------------------------------
# sharded path (shard_map + psum, bit-identical to the host rounds)

@functools.lru_cache(maxsize=64)
def _build_lp_runner(devices: int, cap: int, ecap: int, n: int, k: int,
                     limit: int, max_rounds: int):
    """Compile-cached shard_map refinement kernel for one shape combo.

    Returns a jitted fn(labels [P,cap] i32, gidx [P,cap] i32, lvalid
    [P,cap] bool, src [P,ecap] i32, dst [P,ecap] i32, evalid [P,ecap]
    bool, giw [n] i32 replicated, gkey [n] i32 replicated) ->
    (labels [P,cap] i32, rounds, moves, last_moved).

    Per round the kernel communicates: one [n] psum of label partials
    (the eval/sharded neighbor-label discipline), one [k] psum of
    quantized block-weight partials (the balance budgets), one [n] psum
    of candidate gains and one [n] psum of packed (accepted, target)
    flags. No all_gather, no point-to-point halo. Every decision is then
    made from replicated integer vectors, so all devices stay bitwise in
    lockstep with each other AND with the host reference.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.rules import PARTITION_AXIS, partition_mesh

    mesh = partition_mesh(devices)
    axis = PARTITION_AXIS
    i32 = jnp.int32

    def local(labels, gidx, lvalid, src, dst, evalid, giw, gkey):
        labels = labels.reshape(cap)
        gidx = gidx.reshape(cap)
        lvalid = lvalid.reshape(cap)
        src = src.reshape(ecap)
        dst = dst.reshape(ecap)
        evalid = evalid.reshape(ecap)
        liw = jnp.where(lvalid, giw[gidx], 0)
        lkey = gkey[gidx]
        evalid_i = evalid.astype(i32)
        arange_cap = jnp.arange(cap)

        def scatter_psum(vals):
            # non-owners (and padded slots) contribute 0; the owner adds
            # the value itself, so the psum IS the replicated [n] vector
            return jax.lax.psum(
                jnp.zeros(n, i32).at[gidx].add(jnp.where(lvalid, vals, 0)),
                axis)

        def cond(state):
            rounds, moved, _, _ = state
            return (rounds < max_rounds) & (moved > 0)

        def body(state):  # spmdlint: psum-budget=4
            rounds, _, moves_total, labels = state
            glabels = scatter_psum(labels)
            W = jax.lax.psum(jnp.zeros(k, i32).at[labels].add(liw), axis)
            budget = jnp.maximum(limit - W, 0)
            nb = glabels[dst]
            H = jnp.zeros((cap, k), i32).at[src, nb].add(evalid_i)
            own = H[arange_cap, labels]
            adm = budget[None, :] >= liw[:, None]
            Hm = jnp.where(adm, H, -1)
            tgt = jnp.argmax(Hm, axis=1).astype(i32)
            gain = jnp.where(Hm[arange_cap, tgt] > own,
                             Hm[arange_cap, tgt] - own, 0)
            ggain = scatter_psum(gain)
            myg, nbg = gain[src], ggain[dst]
            myk, nbk = lkey[src], gkey[dst]
            dom_e = evalid & ((nbg > myg) | ((nbg == myg) & (nbk < myk)))
            dom = jnp.zeros(cap, bool).at[src].max(dom_e)
            acc0 = (gain > 0) & ~dom
            gpack = scatter_psum(jnp.where(acc0, tgt + 1, 0))
            gtgt = gpack - 1
            gacc = gpack > 0
            stgt = jnp.where(gacc, gtgt, k)
            order = jnp.lexsort((gkey, -ggain, stgt))
            st = stgt[order]
            siw = jnp.where(gacc, giw, 0)[order]
            csum = jnp.cumsum(siw)
            is_start = jnp.concatenate(
                [jnp.ones(1, bool), st[1:] != st[:-1]])
            base = jax.lax.cummax(jnp.where(is_start, csum - siw, 0))
            ok = (st < k) & (csum - base <= budget[jnp.minimum(st, k - 1)])
            accept = jnp.zeros(n, bool).at[order].set(ok)
            moved = jnp.sum(accept.astype(i32))
            # padded slots follow their aliased real point (same
            # discipline as ShardedPartitionProblem.deal)
            labels = jnp.where(accept[gidx], gtgt[gidx], labels)
            return rounds + 1, moved, moves_total + moved, labels

        rounds, moved, moves_total, labels = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), jnp.int32(1), jnp.int32(0), labels.astype(i32)))
        return labels[None], rounds, moves_total, moved

    inner = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                  P(), P()),
        out_specs=(P(axis), P(), P(), P()),
        check_rep=False)
    return jax.jit(inner)


def _lp_rounds_sharded(graph, labels: np.ndarray, iw: np.ndarray,
                       keys: np.ndarray, limit: int, max_rounds: int):
    """Run the shard_map kernel over ``graph``'s layout. Same returns as
    ``_lp_rounds_host`` (labels come back in original point order)."""
    import jax
    import jax.numpy as jnp

    sp = graph.sharded
    run = _build_lp_runner(sp.devices, sp.cap, graph.ecap, sp.problem.n,
                           sp.problem.k, int(limit), int(max_rounds))
    A, rounds, moves, last = run(
        jnp.asarray(sp.deal(labels.astype(np.int32))),
        jnp.asarray(sp.gather.astype(np.int32)),
        jnp.asarray(sp.valid),
        jnp.asarray(graph.src),
        jnp.asarray(graph.dst.astype(np.int32)),
        jnp.asarray(graph.edge_valid),
        jnp.asarray(iw.astype(np.int32)),
        jnp.asarray(keys.astype(np.int32)))
    A, rounds, moves, last = jax.device_get((A, rounds, moves, last))
    return (sp.scatter_labels(np.asarray(A)), int(rounds), int(moves),
            int(last))


# ---------------------------------------------------------------------------
# front door

def _node_keys(problem: PartitionProblem, node_order) -> np.ndarray:
    if node_order is None:
        return np.arange(problem.n, dtype=np.int64)
    keys = np.asarray(node_order, np.int64)
    if keys.shape != (problem.n,):
        raise ValueError(f"node_order must be [{problem.n}] unique ints, "
                         f"got shape {keys.shape}")
    if np.unique(keys).size != problem.n:
        raise ValueError("node_order keys must be unique (they are the "
                         "deterministic move-priority tie-break)")
    lo, hi = np.iinfo(np.int32).min, np.iinfo(np.int32).max
    if keys.min() < lo or keys.max() > hi:
        raise ValueError("node_order keys must fit int32 (the sharded "
                         "path compares them as int32)")
    return keys


@register_refiner("label_prop", aliases=("lp", "labelprop"), short="lp")
def label_prop_refine(problem: PartitionProblem, labels: np.ndarray, *,
                      devices: int | None = None, eps: float | None = None,
                      max_rounds: int = DEFAULT_MAX_ROUNDS,
                      node_order=None, graph=None
                      ) -> tuple[np.ndarray, dict]:
    """Size-constrained label-propagation rounds over ``labels``.

    Args:
        problem: the instance (must carry a CSR graph).
        labels: [n] block ids in original point order.
        devices: None runs the host numpy reference; P >= 1 runs the
            shard_map kernel over P shards (bit-for-bit equal).
        eps: balance slack (None = ``problem.epsilon``).
        max_rounds: static round cap.
        node_order: [n] unique int priority keys (None = point order).
        graph: optional pre-built ``repro.eval.ShardedGraph`` to reuse
            (devices path only; must match ``problem`` and ``devices``).

    Returns:
        (labels [n] int64, info dict with ``rounds`` / ``moves`` /
        ``converged``).
    """
    if not problem.has_graph:
        raise ValueError(
            "problem carries no CSR graph (indptr/indices); label "
            "propagation moves boundary nodes along edges — build the "
            "PartitionProblem via from_mesh or pass indptr/indices")
    labels = np.asarray(labels)
    if labels.shape != (problem.n,):
        raise ValueError(f"labels must be [{problem.n}], "
                         f"got {labels.shape}")
    if max_rounds < 1:
        raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
    keys = _node_keys(problem, node_order)
    iw, limit = refinement_quantization(problem, eps)
    labels_c, order = _canonicalize(labels.astype(np.int64), keys,
                                    problem.k)
    if devices is None:
        out_c, rounds, moves, last = _lp_rounds_host(
            labels_c, np.asarray(problem.indptr, np.int64),
            np.asarray(problem.indices, np.int64), iw, keys,
            problem.k, limit, max_rounds)
    else:
        from repro.eval.sharded import ShardedGraph
        if graph is None:
            graph = ShardedGraph.from_problem(problem, devices)
        elif graph.problem is not problem or graph.devices != devices:
            raise ValueError(
                "graph was built for a different problem/devices")
        out_c, rounds, moves, last = _lp_rounds_sharded(
            graph, labels_c, iw, keys, limit, max_rounds)
    info = {"rounds": rounds, "moves": moves,
            "converged": bool(last == 0)}
    return order[out_c], info


def refine(problem: PartitionProblem, result, method="label_prop", *,
           devices: int | None = None, eps: float | None = None,
           evaluate: bool = False, **opts) -> PartitionResult:
    """Refine a partition — the quality-recovery front door next to
    ``partition()`` / ``repartition()``.

    Args:
        problem: the instance (must carry a CSR graph; the geometric
            solvers never read it, the refiner does).
        result: the ``PartitionResult`` to refine, or a raw [n] label
            array.
        method: refiner registry name (``available_refiners()``; aliases
            resolve, unknown names raise ``UnknownRefinerError``). True
            selects the default ``"label_prop"``.
        devices: None = host reference; P >= 1 = the shard_map path
            (bit-for-bit equal at every device count).
        eps: balance slack for the refinement budgets (None =
            ``problem.epsilon``). Refined block weights never exceed
            ``(1 + eps) * W / k``, so a balanced input stays balanced.
        evaluate: fill ``result.quality`` with the paper metric set.
        **opts: forwarded to the refiner (``max_rounds`` /
            ``node_order`` / ``graph`` for label_prop).

    Returns:
        A new ``PartitionResult``: refined labels, ``method`` suffixed
        with the refiner's short name (e.g. ``"geographer+lp"``), the
        base result's centers/influence carried over (still the warm
        state ``repartition()`` resumes from), and
        ``stats["refine"]`` = {method, rounds, moves, converged,
        cut_before, cut_after, devices, eps}.
    """
    if not isinstance(problem, PartitionProblem):
        raise TypeError(
            f"refine() takes a PartitionProblem, got {type(problem)}")
    name = resolve_refiner(method)
    if isinstance(result, PartitionResult):
        base = result
        labels_in = np.asarray(base.labels)
    else:
        base = None
        labels_in = np.asarray(result)
    labels_out, info = _REFINERS[name](problem, labels_in,
                                       devices=devices, eps=eps, **opts)
    cut_before = edge_cut(labels_in, problem.indptr, problem.indices)
    cut_after = edge_cut(labels_out, problem.indptr, problem.indices)
    stats = dict(base.stats) if base is not None else {}
    stats["refine"] = {
        "method": name, "rounds": info["rounds"], "moves": info["moves"],
        "converged": info["converged"], "cut_before": cut_before,
        "cut_after": cut_after,
        "devices": None if devices is None else int(devices),
        "eps": problem.epsilon if eps is None else float(eps)}
    stats["final_imbalance"] = imbalance(labels_out, problem.k,
                                         problem.weights)
    base_method = base.method if base is not None else "labels"
    out = PartitionResult(
        labels=labels_out, k=problem.k,
        method=f"{base_method}+{_SHORT[name]}", problem=problem,
        centers=None if base is None else base.centers,
        influence=None if base is None else base.influence,
        stats=stats)
    if evaluate:
        out.evaluate()
    return out
