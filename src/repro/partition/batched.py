"""Batched balanced k-means: many independent subproblems, one dispatch.

The paper's algorithm is a fixed-point loop over static-shape arrays, so a
batch of B subproblems (the k1 refinement blocks of a hierarchical
partition, or B independent meshes) vmaps cleanly: every subproblem is
padded to a common ``cap`` point count and carries a validity mask encoded
the same way as the warm-up sampling in ``core.balanced_kmeans`` — padded
slots *replicate real points with weight zero*, so they influence neither
the bounding box nor any weighted sum, and the nested while_loops batch
via jax's select-based rule (finished subproblems coast).

``batched_balanced_kmeans`` runs all B subproblems in ONE jitted device
dispatch and is bit-for-bit identical to calling ``balanced_kmeans`` per
subproblem (verified by tests/test_partition_engine.py);
``sequential_balanced_kmeans`` is that reference loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans


@functools.partial(jax.jit, static_argnames=("cfg",))
def _batched_jit(points, weights, centers0, target_weight, cfg: BKMConfig):
    def one(p, w, c0, tw):
        return balanced_kmeans(p, cfg, w, c0, target_weight=tw)
    return jax.vmap(one)(points, weights, centers0, target_weight)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _single_jit(points, weights, centers0, target_weight, cfg: BKMConfig):
    return balanced_kmeans(points, cfg, weights, centers0,
                           target_weight=target_weight)


def _prep(points, weights, centers0, cfg, target_weight):
    points = jnp.asarray(points, cfg.dtype)
    B, n, _ = points.shape
    weights = (jnp.ones((B, n), cfg.dtype) if weights is None
               else jnp.asarray(weights, cfg.dtype))
    centers0 = jnp.asarray(centers0, cfg.dtype)
    if target_weight is None:
        target_weight = jnp.sum(weights, axis=1) / cfg.k
    else:
        target_weight = jnp.broadcast_to(
            jnp.asarray(target_weight, cfg.dtype), (B,))
    return points, weights, centers0, target_weight


def batched_balanced_kmeans(points, weights, centers0, cfg: BKMConfig,
                            target_weight=None):
    """Solve B balanced-k-means subproblems in one jitted vmap dispatch.

    points [B, n, d]; weights [B, n] (0 marks padded slots — pad with
    *copies of real points* so bounding boxes stay tight); centers0
    [B, k, d]. ``target_weight``: scalar or [B] per-subproblem balance
    target (default: each subproblem's total weight / k).

    Returns (labels [B, n] int32, centers [B, k, d], influence [B, k],
    stats pytree with leading batch axis).
    """
    args = _prep(points, weights, centers0, cfg, target_weight)
    return _batched_jit(*args, cfg)


@functools.lru_cache(maxsize=64)
def _build_refine_runner(p1: int, p2: int, cfg: BKMConfig):
    """Compile-cached shard_map driver batching refinement blocks over the
    REFINE axis of the 2-D hierarchical mesh (dist.rules.partition_mesh2d).

    The blocks shard over ``REFINE_AXIS`` alone and are replicated over
    ``COARSE_AXIS`` (every coarse row computes the same block set — the
    blocks are tiny, 1/k1 of the data each, so the redundancy is cheap
    and keeps the body collective-free). ``check_rep=False`` because the
    replication is by construction, not by collective.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.rules import REFINE_AXIS, partition_mesh2d

    mesh = partition_mesh2d(p1, p2)

    # every block solves locally on its refine-axis device — the
    # refinement phase of the 2-D mesh is communication-free by design
    # (the coarse pass owns the psum traffic), and the budget directive
    # pins that: a refactor that adds a collective here fails lint
    def local_blocks(p, w, c0, tw):   # spmdlint: psum-budget=0
        def one(pp, ww, cc, tt):
            return balanced_kmeans(pp, cfg, ww, cc, target_weight=tt)
        return jax.vmap(one)(p, w, c0, tw)

    spec = P(REFINE_AXIS)
    return jax.jit(shard_map(local_blocks, mesh=mesh,
                             in_specs=(spec, spec, spec, spec),
                             out_specs=(spec, spec, spec, spec),
                             check_rep=False))


def sharded_batched_balanced_kmeans(points, weights, centers0,
                                    cfg: BKMConfig, *, devices,
                                    target_weight=None):
    """Solve B refinement subproblems sharded over the refine axis of the
    2-D ``(COARSE_AXIS, REFINE_AXIS)`` device mesh.

    Same contract as ``batched_balanced_kmeans`` plus ``devices=(P1, P2)``;
    the B blocks are padded to a multiple of P2 with copies of block 0
    (their outputs are dropped), dealt P(REFINE_AXIS)-sharded, and each
    device runs the plain local vmap. Every block still solves exactly
    the same trace as the host vmap, so the results are *bit-for-bit
    identical* to ``batched_balanced_kmeans`` (asserted by
    tests/test_hierarchical_2d.py).
    """
    p1, p2 = (int(d) for d in devices)
    pts, w, c0, tw = _prep(points, weights, centers0, cfg, target_weight)
    B = pts.shape[0]
    Bp = -(-B // p2) * p2                  # pad B to a multiple of P2
    if Bp != B:
        idx = jnp.concatenate([jnp.arange(B),
                               jnp.zeros(Bp - B, jnp.int32)])
        pts, w, c0, tw = (x[idx] for x in (pts, w, c0, tw))
    run = _build_refine_runner(p1, p2, cfg)
    A, C, infl, stats = run(pts, w, c0, tw)
    if Bp != B:
        A, C, infl = A[:B], C[:B], infl[:B]
        stats = jax.tree.map(lambda x: x[:B], stats)
    return A, C, infl, stats


def sequential_balanced_kmeans(points, weights, centers0, cfg: BKMConfig,
                               target_weight=None):
    """Reference loop: same subproblems, one dispatch each. Bit-for-bit
    equal to ``batched_balanced_kmeans`` — kept for parity testing and for
    hosts where one giant dispatch is undesirable."""
    pts, w, c0, tw = _prep(points, weights, centers0, cfg, target_weight)
    outs = [_single_jit(pts[b], w[b], c0[b], tw[b], cfg)
            for b in range(pts.shape[0])]
    A = jnp.stack([o[0] for o in outs])
    C = jnp.stack([o[1] for o in outs])
    infl = jnp.stack([o[2] for o in outs])
    stats = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[3] for o in outs])
    return A, C, infl, stats


@functools.partial(jax.jit, static_argnames=("cfg", "warm"))
def _bucket_jit(points, weights, centers0, influence0, prev_assignment,
                target_weight, cfg: BKMConfig, warm: bool):
    if warm:
        def one(p, w, c0, i0, pa, tw):
            return balanced_kmeans(p, cfg, w, c0, target_weight=tw,
                                   influence0=i0, warm_start=True,
                                   prev_assignment=pa)
        A, C, infl, stats = jax.vmap(one)(points, weights, centers0,
                                          influence0, prev_assignment,
                                          target_weight)
    else:
        def one(p, w, c0, tw):
            return balanced_kmeans(p, cfg, w, c0, target_weight=tw)
        A, C, infl, stats = jax.vmap(one)(points, weights, centers0,
                                          target_weight)
    # per-slot request metrics ride in the same dispatch: imbalance on the
    # padded batch always, migration vs the warm-start assignment when warm
    stats = dict(stats)
    stats["imbalance"] = metrics.batch_imbalance(A, cfg.k, weights)
    if warm:
        stats["migration_fraction"] = metrics.batch_migration_fraction(
            prev_assignment, A, weights)
    return A, C, infl, stats


def bucket_balanced_kmeans(points, weights, centers0, cfg: BKMConfig, *,
                           counts=None, valid=None, target_weight=None,
                           influence0=None, prev_assignment=None,
                           warm: bool = False):
    """Solve one serving *bucket* — S fixed slots padded to a common point
    cap — in a single jitted vmap dispatch.

    This is the static-shape entry the multi-tenant ``PartitionServer``
    (repro.serve) drives: every slot is an independent subproblem padded
    with *copies of its own real points at weight zero* (the engine-wide
    padding discipline — bounding boxes stay tight, weighted sums are
    exact), and slots past the end of a request group are filler copies
    flagged invalid.

    Args:
        points:   [S, cap, d] padded per-slot coordinates.
        weights:  [S, cap] weights, 0 on padded entries (None = ones; only
            meaningful when every slot is full, i.e. counts == cap).
        centers0: [S, k, d] initial centers (SFC bootstrap for cold slots,
            cached warm centers for warm slots).
        cfg: shared ``BKMConfig`` (k/epsilon static across the bucket).
        counts:   optional [S] real point counts per slot (<= cap),
            recorded in ``stats["counts"]``.
        valid:    optional [S] bool slot-validity mask (False = filler
            slot whose outputs must be discarded), recorded in
            ``stats["valid"]``.
        target_weight: scalar or [S] balance target override.
        influence0: [S, k] warm influence (warm only; None = ones).
        prev_assignment: [S, cap] int32 previous labels in the padded
            order (warm only; enables no-op detection per slot).
        warm: resume every slot from (centers0, influence0) with
            ``warm_start=True`` instead of cold-starting.

    Returns:
        (labels [S, cap] int32, centers [S, k, d], influence [S, k],
        stats) — ``stats`` carries the solver pytree with a leading slot
        axis plus ``"imbalance"`` [S] (and ``"migration_fraction"`` [S]
        when warm) computed in-graph on the padded batch, and the
        host-side ``"counts"`` / ``"valid"`` passthroughs.

    Raises:
        ValueError: shape mismatches, counts exceeding the cap, or warm
            state missing/present on the wrong path.
    """
    pts, w, c0, tw = _prep(points, weights, centers0, cfg, target_weight)
    S, cap, _ = pts.shape
    if counts is not None:
        counts = np.asarray(counts)
        if counts.shape != (S,):
            raise ValueError(f"counts must be [{S}], got {counts.shape}")
        if counts.max() > cap or counts.min() < 1:
            raise ValueError(f"counts must lie in [1, cap={cap}], got "
                             f"range [{counts.min()}, {counts.max()}]")
    if valid is not None:
        valid = np.asarray(valid, bool)
        if valid.shape != (S,):
            raise ValueError(f"valid must be [{S}], got {valid.shape}")
    if warm:
        if influence0 is None:
            influence0 = jnp.ones((S, cfg.k), cfg.dtype)
        else:
            influence0 = jnp.asarray(influence0, cfg.dtype)
        if prev_assignment is None:
            raise ValueError("warm bucket solves need prev_assignment "
                             "(the [S, cap] warm-start labels)")
        prev_assignment = jnp.asarray(prev_assignment, jnp.int32)
        if influence0.shape != (S, cfg.k):
            raise ValueError(f"influence0 must be [{S}, {cfg.k}], got "
                             f"{influence0.shape}")
        if prev_assignment.shape != (S, cap):
            raise ValueError(f"prev_assignment must be [{S}, {cap}], got "
                             f"{prev_assignment.shape}")
    elif influence0 is not None or prev_assignment is not None:
        raise ValueError("influence0/prev_assignment are warm-start "
                         "state; pass warm=True")
    A, C, infl, stats = _bucket_jit(pts, w, c0, influence0,
                                    prev_assignment, tw, cfg, warm)
    stats = dict(stats)
    if counts is not None:
        stats["counts"] = counts
    if valid is not None:
        stats["valid"] = valid
    return A, C, infl, stats


def build_refinement_batch(points: np.ndarray, weights: np.ndarray | None,
                           labels: np.ndarray, k1: int):
    """Gather the k1 coarse blocks into static-shape refinement inputs.

    Every block is padded to ``cap = max block count`` by cycling its own
    point indices (real coordinates, zero weight), which keeps per-block
    bounding boxes exact and never introduces phantom geometry.

    Returns (bpts [k1, cap, d], bw [k1, cap], gather [k1, cap] int64,
    counts [k1]): ``gather[b, :counts[b]]`` are the original point ids of
    block b (so sub-labels scatter back losslessly), the rest is padding.
    """
    n = points.shape[0]
    labels = np.asarray(labels)
    counts = np.bincount(labels, minlength=k1)
    if counts.min() == 0:
        raise ValueError("empty coarse block; cannot refine")
    cap = int(counts.max())
    order = np.argsort(labels, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])
    gather = np.empty((k1, cap), np.int64)
    for b in range(k1):
        ids = order[starts[b]:starts[b + 1]]
        reps = -(-cap // len(ids))          # ceil
        gather[b] = np.tile(ids, reps)[:cap]
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    valid = np.arange(cap)[None, :] < counts[:, None]
    bpts = points[gather]                                 # [k1, cap, d]
    bw = np.where(valid, w[gather], 0.0)                  # [k1, cap]
    return bpts, bw, gather, counts
