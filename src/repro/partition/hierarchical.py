"""Hierarchical (k = k1 x k2) recursive partitioning.

Maps the partition onto a machine hierarchy (k1 nodes x k2 cores, racks x
hosts, pods x chips): a *coarse* pass cuts the points into k1 blocks, then
every block is refined into k2 sub-blocks. Block b owns the final label
range [b*k2, (b+1)*k2), so sub-block ids are machine-hierarchy-aligned and
neighbors in label space are neighbors in the hierarchy.

Balance composition (why global imbalance <= epsilon still holds): the
coarse pass runs with the tighter budget eps1 (default epsilon/2), so
every block's weight W_b <= (1 + eps1) * W / k1 — the refinement then
balances each block against the *global* target W / (k1*k2) (via the
``target_weight`` hook in ``core.balanced_kmeans``) with the full epsilon.
Feasibility needs W_b / k2 <= (1 + epsilon) * W / (k1*k2), i.e. eps1 <=
epsilon, which the split guarantees with headroom; every sub-block then
ends <= (1 + epsilon) * W / (k1*k2) directly, no product-of-epsilons
slack.

Refinement with ``refine_method="geographer"`` runs all k1 subproblems as
ONE batched vmap dispatch (partition/batched.py); any other registered
method refines block-by-block on the host (the quantile-cutting baselines
are near-exactly balanced per block, so the coarse eps1 dominates).
"""
from __future__ import annotations

import numpy as np

from repro.core.sfc import sfc_initial_centers

from .batched import (batched_balanced_kmeans, build_refinement_batch,
                      sequential_balanced_kmeans,
                      sharded_batched_balanced_kmeans)
from .problem import PartitionProblem, PartitionResult
from .registry import get_algorithm, resolve_method, supports_devices

_KMEANS_METHODS = {"geographer"}


def factor_k(k: int) -> tuple[int, int]:
    """Split k into (k1, k2) with k1 the largest divisor <= sqrt(k)."""
    k1 = max(d for d in range(1, int(np.sqrt(k)) + 1) if k % d == 0)
    return k1, k // k1


def hierarchical_partition(problem: PartitionProblem,
                           k1: int | None = None, k2: int | None = None, *,
                           method: str = "geographer",
                           refine_method: str = "geographer",
                           batched: bool = True,
                           devices: int | tuple[int, int] | None = None,
                           chunk: int | None = None,
                           coarse_epsilon: float | None = None,
                           coarse_opts: dict | None = None,
                           refine_opts: dict | None = None
                           ) -> PartitionResult:
    """Two-level partition of ``problem`` into k = k1*k2 blocks.

    Args:
        problem: instance with ``problem.k == k1*k2``.
        k1, k2: hierarchy factors; None auto-factors via ``factor_k``.
        method: registry name for the coarse k1-way cut.
        refine_method: registry name refining each block into k2
            sub-blocks.
        batched: run all k1 k-means refinements in a single jitted vmap
            dispatch (bit-for-bit equal to the sequential loop).
        devices: run the *coarse* cut on the sharded multi-device path
            (the global pass is where the data is big). An int P keeps
            the per-block refinement a host-side batched vmap; a
            ``(P1, P2)`` tuple lays out the 2-D hierarchical mesh
            (dist.rules.partition_mesh2d): the coarse cut shards its
            points over the *product* of the ("coarse", "refine") axes —
            bit-identical to the flat ``devices=P1*P2`` run — and the k1
            refinement blocks then batch over the refine axis
            (bit-identical to the host vmap), so the whole composition
            matches the flat one label for label.
        chunk: per-shard slots per deal slice of the coarse pass's
            streaming deal (only meaningful with ``devices=``; see
            partition/distributed.py — results are bit-identical).
        coarse_epsilon: balance budget of the coarse pass (default
            epsilon/2 — see the module docstring for why that composes).
        coarse_opts, refine_opts: per-level algorithm options.

    Returns:
        ``PartitionResult`` with k1*k2 blocks, block b owning label range
        [b*k2, (b+1)*k2), and per-level entries in ``stats["levels"]``.

    Raises:
        ValueError: k1*k2 != problem.k, a coarse block too small to
            refine, or ``devices=`` with a non-distributed coarse method.
    """
    if k1 is None or k2 is None:
        k1, k2 = factor_k(problem.k)
    if k1 * k2 != problem.k:
        raise ValueError(f"k1*k2 = {k1}*{k2} != k = {problem.k}")
    coarse_name = resolve_method(method)
    refine_name = resolve_method(refine_method)
    if devices is not None:
        if not supports_devices(coarse_name):
            raise ValueError(
                f"coarse method {coarse_name!r} has no multi-device path; "
                "devices= requires a supports_devices method")
        coarse_opts = dict(coarse_opts or {}, devices=devices)
        if chunk is not None:
            coarse_opts.setdefault("chunk", chunk)
    elif chunk is not None:
        raise ValueError("chunk= streams the sharded deal and needs "
                         "devices=")
    # a (P1, P2) tuple additionally shards the refinement blocks over the
    # refine axis of the 2-D mesh (an int keeps the refinement host-side)
    mesh2d = (tuple(int(d) for d in devices)
              if isinstance(devices, (tuple, list)) else None)
    dev_stat = list(mesh2d) if mesh2d is not None else devices
    eps = problem.epsilon
    # no refinement follows when k2 == 1, so the coarse pass gets the full
    # budget instead of the tightened split
    eps1 = (coarse_epsilon if coarse_epsilon is not None
            else (eps if k2 == 1 else eps / 2.0))

    # ---- level 1: coarse k1 blocks (tighter budget eps1)
    coarse_problem = problem.replace(k=k1, epsilon=eps1)
    coarse = get_algorithm(coarse_name)(coarse_problem,
                                        **(coarse_opts or {}))
    clabels = np.asarray(coarse.labels)
    if k2 == 1:
        result = PartitionResult(
            labels=clabels, k=k1,
            method=f"hierarchical({coarse_name}x{refine_name})",
            problem=problem, centers=coarse.centers,
            influence=coarse.influence)
        result.stats = {
            "k1": k1, "k2": 1,
            "levels": [
                {"method": coarse_name, "k": k1, "epsilon": eps1,
                 "devices": dev_stat, "imbalance": coarse.imbalance()},
                {"method": refine_name, "k": 1, "epsilon": eps,
                 "batched": False, "dispatches": 0},
            ],
            "final_imbalance": result.imbalance(),
        }
        return result

    # ---- level 2: refine every block against the GLOBAL target W/(k1*k2)
    labels = np.empty(problem.n, np.int64)
    refine_opts = dict(refine_opts or {})
    if refine_name in _KMEANS_METHODS:
        from .algorithms import make_bkm_config
        refine_opts.setdefault("warmup", False)
        cfg = make_bkm_config(problem, k=k2, **refine_opts)
        bpts, bw, gather, counts = build_refinement_batch(
            problem.points, problem.weights, clabels, k1)
        if counts.min() < k2:
            raise ValueError(
                f"coarse block with {int(counts.min())} points cannot be "
                f"refined into k2={k2} sub-blocks (n={problem.n} too small "
                f"for k={k1 * k2})")
        w_host = (np.ones(problem.n) if problem.weights is None
                  else np.asarray(problem.weights, np.float64))
        centers0 = np.stack([
            sfc_initial_centers(bpts[b, :counts[b]], k2,
                                w_host[gather[b, :counts[b]]])
            for b in range(k1)])
        target = problem.total_weight / (k1 * k2)
        if mesh2d is not None and batched:
            # 2-D mesh: blocks over the refine axis, bit-for-bit equal to
            # the host vmap (each block runs the identical trace)
            sub, centers, infl, stats = sharded_batched_balanced_kmeans(
                bpts, bw, centers0, cfg, devices=mesh2d,
                target_weight=target)
        else:
            runner = (batched_balanced_kmeans if batched
                      else sequential_balanced_kmeans)
            sub, centers, infl, stats = runner(bpts, bw, centers0, cfg,
                                               target_weight=target)
        sub = np.asarray(sub)
        for b in range(k1):
            ids = gather[b, :counts[b]]
            labels[ids] = b * k2 + sub[b, :counts[b]]
        refine_stats = {
            "imbalance_vs_global_target":
                np.asarray(stats["final_imbalance"]).tolist(),
            "iters": np.asarray(stats["iters"]).tolist(),
            "batched": batched, "dispatches": 1 if batched else k1,
            "refine_devices": (list(mesh2d)
                               if mesh2d is not None and batched
                               else None)}
        centers_out = np.asarray(centers).reshape(k1 * k2, -1)
        infl_out = np.asarray(infl).reshape(k1 * k2)
    else:
        for b in range(k1):
            ids = np.where(clabels == b)[0]
            subp = PartitionProblem(
                points=problem.points[ids], k=k2,
                weights=None if problem.weights is None
                else problem.weights[ids],
                epsilon=eps, seed=problem.seed + b + 1,
                name=f"{problem.name}/block{b}")
            subres = get_algorithm(refine_name)(subp)
            labels[ids] = b * k2 + np.asarray(subres.labels)
        refine_stats = {"batched": False, "dispatches": k1}
        centers_out = infl_out = None

    result = PartitionResult(
        labels=labels, k=k1 * k2,
        method=f"hierarchical({coarse_name}x{refine_name})",
        problem=problem, centers=centers_out, influence=infl_out)
    result.stats = {
        "k1": k1, "k2": k2,
        "levels": [
            {"method": coarse_name, "k": k1, "epsilon": eps1,
             "devices": dev_stat, "imbalance": coarse.imbalance()},
            {"method": refine_name, "k": k2, "epsilon": eps,
             **refine_stats},
        ],
        "final_imbalance": result.imbalance(),
    }
    return result
