"""``partition()`` — the single front door for all partitioning.

    from repro.partition import PartitionProblem, partition

    prob = PartitionProblem.from_mesh(mesh, k=64, epsilon=0.03)
    res = partition(prob, method="geographer")          # flat
    res = partition(prob, method="rcb")                 # any registry name
    res = partition(prob, hierarchy=(8, 8))             # k = 8 x 8 blocks
    res = partition(prob, devices=8)                    # sharded SPMD run
    res.labels, res.imbalance(), res.evaluate()

``hierarchy`` accepts a (k1, k2) tuple or a "k1xk2" string; it routes
through ``hierarchical_partition`` with ``method`` as the coarse cut and
``refine_method`` (default geographer, batched vmap) as the per-block
refinement.

``devices=P`` runs the method's multi-device shard_map path over P
devices (points sharded, centers replicated, psum-only communication —
see partition/distributed.py). Only methods registered with
``supports_devices`` accept it; with ``hierarchy`` the coarse cut runs
distributed and the refinement stays a host-side batched vmap.

``devices=(P1, P2)`` lays out the 2-D hierarchical device mesh instead
(``dist.rules.partition_mesh2d``): the coarse cut shards its points over
the *product* of the ("coarse", "refine") axes — bit-identical to the
flat ``devices=P1*P2`` run — and with ``hierarchy`` the k1 refinements
batch over the refine axis. ``chunk=N`` (a ``**opts`` pass-through to
the geographer adapter) streams the sharded deal in bounded host slices
without changing any result bit.
"""
from __future__ import annotations

from .hierarchical import hierarchical_partition
from .problem import PartitionProblem, PartitionResult
from .registry import (distributed_methods, get_algorithm, resolve_method,
                       supports_devices)


def _parse_hierarchy(hierarchy) -> tuple[int, int]:
    if isinstance(hierarchy, str):
        parts = hierarchy.lower().split("x")
        if len(parts) != 2:
            raise ValueError(f"hierarchy string must be 'k1xk2', "
                             f"got {hierarchy!r}")
        return int(parts[0]), int(parts[1])
    k1, k2 = hierarchy
    return int(k1), int(k2)


def partition(problem: PartitionProblem, method: str = "geographer", *,
              hierarchy=None, devices: int | tuple[int, int] | None = None,
              refine=None, refine_eps: float | None = None,
              evaluate: bool = False,
              with_diameter: bool = False, **opts) -> PartitionResult:
    """Partition ``problem`` with ``method`` (a registry name).

    Args:
        problem: the ``PartitionProblem`` to cut into ``problem.k``
            balanced blocks.
        method: registry name (``available_methods()``); aliases resolve,
            unknown names raise ``UnknownMethodError``.
        hierarchy: ``(k1, k2)`` tuple or ``"k1xk2"`` string — switches to
            two-level recursive partitioning with ``k1*k2 == problem.k``.
        devices: run the sharded multi-device path over P devices (method
            must be registered with ``supports_devices``; with
            ``hierarchy``, the coarse cut is the distributed pass). A
            ``(P1, P2)`` tuple uses the 2-D hierarchical mesh: the
            coarse/flat solve is bit-identical to ``devices=P1*P2`` and
            hierarchical refinement batches over the refine axis.
        refine: quality-recovery post-pass over the solver's labels —
            True (= ``"label_prop"``) or a refiner registry name (see
            ``repro.partition.refine``). Requires the problem to carry a
            CSR graph; runs sharded over ``devices`` when set (bit-for-
            bit equal to the host reference), and the returned result's
            ``method`` gains the refiner suffix (e.g. ``"sfc+lp"``).
        refine_eps: balance slack for the refinement budgets (None =
            ``problem.epsilon``); only meaningful with ``refine``.
        evaluate: fill ``result.quality`` with the paper's metric set
            (graph metrics require the problem to carry a CSR graph).
        with_diameter: include per-block diameters in the evaluation.
        **opts: forwarded to the algorithm — BKMConfig fields for
            geographer, or ``refine_method`` / ``batched`` /
            ``coarse_epsilon`` in hierarchical mode; unknown options
            raise ``TypeError``.

    Returns:
        A ``PartitionResult`` (labels in original point order, optional
        centers/influence warm-start state, per-level ``stats``).

    For incremental re-solves against a previous result, see
    ``repartition()``.
    """
    if not isinstance(problem, PartitionProblem):
        raise TypeError(
            f"partition() takes a PartitionProblem, got {type(problem)}; "
            "wrap raw arrays with PartitionProblem(points=..., k=...)")
    resolve_method(method)                 # fail fast on unknown names
    if devices is not None and not supports_devices(method):
        raise ValueError(
            f"method {method!r} has no multi-device path; devices= is "
            f"supported by: {distributed_methods()}")
    if refine is not None and refine is not False:
        from .refine import resolve_refiner
        refine = resolve_refiner(refine)   # fail fast, before the solve
    else:
        refine = None
    if hierarchy is not None:
        k1, k2 = _parse_hierarchy(hierarchy)
        result = hierarchical_partition(problem, k1, k2, method=method,
                                        devices=devices, **opts)
    else:
        if devices is not None:
            opts["devices"] = devices
        result = get_algorithm(method)(problem, **opts)
    if refine is not None:
        from .refine import refine as _refine
        result = _refine(problem, result, refine, devices=devices,
                         eps=refine_eps)
    if evaluate:
        result.evaluate(with_diameter=with_diameter)
    return result
