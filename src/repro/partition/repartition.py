"""``repartition()`` — dynamic repartitioning through the engine.

Parallel simulations change their load distribution every few timesteps
and must **re**partition cheaply while keeping data migration low. The
geometric formulation of balanced k-means is exactly where this shines:
warm-starting from the previous partition's (centers, influence) state
skips the SFC bootstrap and the sampled warm-up, converges in a handful of
movement iterations, and — because centers barely move — migrates a small
fraction of the weight a cold restart would (DESIGN.md §8)::

    from repro.partition import PartitionProblem, partition, repartition

    prob0 = PartitionProblem(points, k=16, weights=w0)
    prev  = partition(prob0, method="geographer")         # cold start once
    prob1 = prob0.replace(weights=w1)                     # load drifted
    res   = repartition(prob1, prev)                      # warm restart
    res.stats["migration"]["fraction"]                    # weight moved
    res.stats["iters"]                                    # ~0-5, not ~30

Methods without a warm-startable state (sfc/rcb/rib/multijagged — their
partitions are recomputed from scratch) fall back to a **cold start +
relabel matching**: new blocks are greedily matched to the previous blocks
by center correspondence, so block ids stay stable across steps and
migration is measured fairly for every method.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import metrics
from repro.core.partitioner import geographer_repartition

from .engine import partition
from .problem import PartitionProblem, PartitionResult
from .registry import resolve_method, supports_warm_start

# Warm-start movement threshold (x bbox diagonal). Cold starts keep the
# tight default (5e-4) because their centers travel far from the SFC seed;
# a warm start resumes next to a converged state, where the productive
# signal is "centers stopped moving at the scale the workload drifted",
# not the cold threshold that even full runs rarely reach before max_iter.
WARM_DELTA_TOL = 5e-3

# A warm solve whose final balance pass ends above epsilon is re-warmed
# from its own output state (the pre-pass detects the imbalance and forces
# the movement loop to run again) at most this many times.
MAX_BALANCE_RETRIES = 2


@dataclass
class WarmState:
    """The portable warm-start state of a balanced-k-means partition.

    Everything ``balanced_kmeans(warm_start=True)`` resumes from, bundled
    so callers other than ``repartition()`` — the slot cache of
    ``repro.serve.PartitionServer`` in particular — can capture, hold and
    restore warm state without carrying a full ``PartitionResult``:

    Attributes:
        centers:   [k, d] final centers of the producing solve.
        influence: [k] final influence (paper Eq. 1 state), or None for
            all-ones.
        labels:    [n] block ids in the *original* point order (the
            ``prev_assignment`` fed to no-op detection).
    """
    centers: np.ndarray
    influence: np.ndarray | None
    labels: np.ndarray

    def __post_init__(self):
        self.centers = np.asarray(self.centers)
        self.labels = np.asarray(self.labels)
        if self.influence is not None:
            self.influence = np.asarray(self.influence)
        if self.centers.ndim != 2:
            raise ValueError(f"centers must be [k, d], "
                             f"got {self.centers.shape}")
        if (self.influence is not None
                and self.influence.shape != (self.centers.shape[0],)):
            raise ValueError(
                f"influence shape {self.influence.shape} does not match "
                f"k={self.centers.shape[0]}")

    @property
    def n(self) -> int:
        return self.labels.shape[0]

    @property
    def k(self) -> int:
        return self.centers.shape[0]

    @property
    def dim(self) -> int:
        return self.centers.shape[1]

    @classmethod
    def capture(cls, result: PartitionResult) -> "WarmState":
        """Extract the warm-start state from a ``PartitionResult``.

        Raises:
            ValueError: the result carries no centers (produced by a
                method without warm-start state, e.g. sfc/rcb).
        """
        if result.centers is None:
            raise ValueError(
                "result carries no centers to warm-start from (was it "
                "produced by a center-based method?)")
        infl = (None if result.influence is None
                else np.asarray(result.influence))
        return cls(centers=np.asarray(result.centers), influence=infl,
                   labels=np.asarray(result.labels))

    def compatible_with(self, n: int, k: int) -> bool:
        """True when this state can warm-start an (n, k) instance — the
        slot-cache invalidation predicate: a tenant that changed its
        point count or block count must cold-start."""
        return self.n == n and self.k == k

    def influence_or_ones(self) -> np.ndarray:
        """[k] influence, defaulting to all-ones (the solver's default)."""
        if self.influence is None:
            return np.ones(self.k)
        return self.influence


def weighted_centroids(points: np.ndarray, labels: np.ndarray, k: int,
                       weights: np.ndarray | None = None) -> np.ndarray:
    """[k, d] weighted centroid of every block (empty blocks get the
    global centroid so matching never sees NaNs).

    Args:
        points:  [n, d] coordinates.
        labels:  [n] block ids in [0, k).
        k:       number of blocks.
        weights: [n] node weights, or None for unit weights.

    Returns:
        [k, d] float64 centroids.
    """
    pts = np.asarray(points, np.float64)
    lab = np.asarray(labels)
    w = np.ones(len(lab)) if weights is None else np.asarray(weights,
                                                             np.float64)
    csum = np.zeros((k, pts.shape[1]))
    cw = np.zeros(k)
    np.add.at(csum, lab, pts * w[:, None])
    np.add.at(cw, lab, w)
    fallback = pts.mean(axis=0) if len(pts) else np.zeros(pts.shape[1])
    out = np.where(cw[:, None] > 0, csum / np.maximum(cw, 1e-12)[:, None],
                   fallback)
    return out


def greedy_center_match(new_centers: np.ndarray,
                        prev_centers: np.ndarray) -> np.ndarray:
    """Greedy center correspondence: a permutation ``m`` with
    ``m[new_block] = prev_block`` pairing the globally closest unmatched
    (new, prev) center pair first.

    Cold restarts return blocks in an arbitrary id order; relabeling
    through this matching keeps block ids stable across repartition steps
    so migration volume measures *data movement*, not id shuffling.

    Args:
        new_centers:  [k, d] centers/centroids of the new partition.
        prev_centers: [k, d] centers/centroids of the previous partition.

    Returns:
        [k] int64 permutation mapping new block ids to previous block ids.
    """
    new_c = np.asarray(new_centers, np.float64)
    prev_c = np.asarray(prev_centers, np.float64)
    if new_c.shape != prev_c.shape:
        raise ValueError(f"center shape mismatch: {new_c.shape} vs "
                         f"{prev_c.shape}")
    k = new_c.shape[0]
    D = ((new_c[:, None, :] - prev_c[None, :, :]) ** 2).sum(axis=-1)
    mapping = np.full(k, -1, np.int64)
    for _ in range(k):
        i, j = np.unravel_index(np.argmin(D), D.shape)
        mapping[i] = j
        D[i, :] = np.inf
        D[:, j] = np.inf
    return mapping


def _migration_stats(previous: PartitionResult, labels: np.ndarray,
                     weights: np.ndarray | None) -> dict:
    vol = float(metrics.migration_volume(previous.labels, labels, weights))
    frac = float(metrics.migration_fraction(previous.labels, labels,
                                            weights))
    return {"volume": vol, "fraction": frac,
            "retained_fraction": 1.0 - frac}


def _check_previous(problem: PartitionProblem, previous: PartitionResult):
    if not isinstance(previous, PartitionResult):
        raise TypeError(f"previous must be a PartitionResult, got "
                        f"{type(previous)}")
    if previous.k != problem.k:
        raise ValueError(f"previous partition has k={previous.k}, "
                         f"problem has k={problem.k}")
    if len(previous.labels) != problem.n:
        raise ValueError(
            f"previous partition labels {len(previous.labels)} points, "
            f"problem has n={problem.n} (repartition requires the same "
            "point set, possibly moved or re-weighted)")


def _warm_geographer(problem: PartitionProblem, previous: PartitionResult,
                     devices: int | None, **opts) -> PartitionResult:
    """Warm-started balanced k-means (+ balance-retry loop): the engine's
    one warm-start implementation, shared by every method whose registry
    entry declares ``supports_warm_start`` (currently the geographer
    family — a new warm-capable algorithm needs its own branch here)."""
    from .algorithms import make_bkm_config
    from .distributed import repartition_sharded
    opts.setdefault("delta_tol", WARM_DELTA_TOL)
    opts["warmup"] = False
    state = WarmState.capture(previous)
    centers, infl = state.centers, state.influence
    prev_labels = state.labels
    # the solver balances against the caller's effective epsilon (an
    # opts override wins over the problem's), so the retry check must too
    eps_eff = opts.get("epsilon", problem.epsilon)
    total_iters = 0
    for attempt in range(MAX_BALANCE_RETRIES + 1):
        if devices is not None:
            res = repartition_sharded(problem, devices, centers, infl,
                                      prev_labels=prev_labels, **opts)
            iters = res.stats["iters"]
            imb = res.stats["final_imbalance"]
            centers, infl = res.centers, res.influence
            labels = res.labels
        else:
            cfg = make_bkm_config(problem, **opts)
            labels, centers, infl, stats = geographer_repartition(
                problem.points, problem.k, centers, infl,
                weights=problem.weights, cfg=cfg, seed=problem.seed,
                prev_labels=prev_labels)
            iters = int(stats["iters"])
            imb = float(stats["final_imbalance"])
            res = PartitionResult(
                labels=labels, k=problem.k, method="geographer",
                problem=problem, centers=centers, influence=infl,
                stats={"levels": [dict(stats)], "final_imbalance": imb})
        total_iters += iters
        if imb <= eps_eff + 1e-6:
            break
        prev_labels = np.asarray(labels)
    res.stats.update({"warm_start": True, "iters": total_iters,
                      "balance_retries": attempt})   # re-warm solves run
    return res


def _cold_relabel(problem: PartitionProblem, previous: PartitionResult,
                  method: str, devices: int | None,
                  **opts) -> PartitionResult:
    res = partition(problem, method=method, devices=devices, **opts)
    prev_centers = (np.asarray(previous.centers)
                    if previous.centers is not None else
                    weighted_centroids(problem.points, previous.labels,
                                       problem.k, problem.weights))
    new_centers = (np.asarray(res.centers) if res.centers is not None else
                   weighted_centroids(problem.points, res.labels,
                                      problem.k, problem.weights))
    mapping = greedy_center_match(new_centers, prev_centers)
    res.labels = mapping[np.asarray(res.labels)]
    # carry centers/influence into the matched id space too
    if res.centers is not None:
        relabeled = np.empty_like(np.asarray(res.centers))
        relabeled[mapping] = np.asarray(res.centers)
        res.centers = relabeled
    if res.influence is not None:
        relabeled = np.empty_like(np.asarray(res.influence))
        relabeled[mapping] = np.asarray(res.influence)
        res.influence = relabeled
    res.stats.update({"warm_start": False, "relabel_matched": True})
    res.stats.setdefault("iters", _stats_iters(res))
    return res


def _stats_iters(res: PartitionResult):
    """Movement-iteration count of a result, or None for methods without
    an iteration loop (sfc/rcb/...)."""
    if "iters" in res.stats:
        return res.stats["iters"]
    for lvl in res.stats.get("levels", []):
        if lvl.get("iters") is not None:
            v = lvl["iters"]
            return int(np.max(v)) if np.ndim(v) else int(v)
    return None


def repartition(problem: PartitionProblem, previous: PartitionResult,
                method: str = "geographer", *,
                devices: int | None = None, warm: bool | None = None,
                refine=None, refine_eps: float | None = None,
                evaluate: bool = False, with_diameter: bool = False,
                **opts) -> PartitionResult:
    """Repartition ``problem`` starting from ``previous`` — the dynamic
    front door next to ``partition()``.

    Args:
        problem: the perturbed instance — same point count (and point
            identity) as ``previous``, typically with drifted weights
            and/or moved points.
        previous: the ``PartitionResult`` of the last (re)partition call.
        method: registry name. Methods with ``supports_warm_start`` (see
            ``warm_start_methods()``) resume balanced k-means from
            ``previous.centers`` / ``previous.influence``; all others cold
            start and are relabel-matched to ``previous`` by greedy center
            correspondence.
        devices: run the solve on the sharded multi-device path (the
            previous centers/influence are replicated, communication stays
            psum-only; ``devices=1`` is bit-for-bit the single-device
            path).
        warm: force (True) or forbid (False) warm starting; None picks
            warm whenever the method supports it and ``previous`` carries
            centers. ``warm=False`` with a warm-capable method is the
            fair "cold restart" baseline: same algorithm, fresh SFC
            bootstrap, relabel-matched.
        refine: quality-recovery post-pass applied AFTER the warm (or
            cold-relabeled) solve and BEFORE migration accounting — True
            (= ``"label_prop"``) or a refiner registry name; runs over
            ``devices`` shards when set. Migration is then measured on
            the refined labels, since those are what the simulation
            actually redistributes to.
        refine_eps: balance slack for the refinement budgets (None =
            ``problem.epsilon``); only meaningful with ``refine``.
        evaluate: fill ``result.quality`` with the paper metric set.
        with_diameter: include block diameters in the evaluation.
        **opts: forwarded to the algorithm (BKMConfig fields for
            geographer; warm solves default ``delta_tol`` to
            ``WARM_DELTA_TOL`` and force ``warmup=False``).

    Returns:
        PartitionResult whose ``stats`` additionally carry
        ``stats["warm_start"]``, ``stats["iters"]`` (cumulative movement
        iterations; 0 when ``previous`` is still a fixed point) and
        ``stats["migration"]`` = {"volume", "fraction",
        "retained_fraction"} measured against ``previous`` under the NEW
        weights.

    Raises:
        ValueError: k/n mismatch with ``previous``, or ``warm=True`` for
            a method without warm-start support / a previous result
            without centers.
    """
    if not isinstance(problem, PartitionProblem):
        raise TypeError(
            f"repartition() takes a PartitionProblem, got {type(problem)}")
    _check_previous(problem, previous)
    name = resolve_method(method)
    can_warm = supports_warm_start(name) and previous.centers is not None
    if warm is None:
        warm = can_warm
    elif warm and not supports_warm_start(name):
        raise ValueError(
            f"method {name!r} has no warm-start path; warm=True is "
            "supported by methods registered with supports_warm_start")
    elif warm and previous.centers is None:
        raise ValueError(
            "previous result carries no centers to warm-start from "
            "(was it produced by a center-based method?)")

    if refine is not None and refine is not False:
        from .refine import resolve_refiner
        refine = resolve_refiner(refine)   # fail fast, before the solve
    else:
        refine = None
    if warm:
        res = _warm_geographer(problem, previous, devices, **opts)
    else:
        res = _cold_relabel(problem, previous, name, devices, **opts)
    if refine is not None:
        from .refine import refine as _refine
        res = _refine(problem, res, refine, devices=devices,
                      eps=refine_eps)
    res.stats["migration"] = _migration_stats(previous, res.labels,
                                              problem.weights)
    if evaluate:
        res.evaluate(with_diameter=with_diameter)
    return res
