"""Unified partitioning engine: one problem type, one ``partition()`` call,
a pluggable algorithm registry, hierarchical (k1 x k2) recursion, and
batched vmap execution. See DESIGN.md §Partition-engine.
"""
from . import algorithms  # noqa: F401  (populates the registry on import)
from .batched import (batched_balanced_kmeans, build_refinement_batch,
                      sequential_balanced_kmeans)
from .engine import partition
from .hierarchical import factor_k, hierarchical_partition
from .problem import PartitionProblem, PartitionResult
from .registry import (UnknownMethodError, available_methods,
                       get_algorithm, register_algorithm, resolve_method)

__all__ = [
    "PartitionProblem", "PartitionResult", "partition",
    "hierarchical_partition", "factor_k",
    "batched_balanced_kmeans", "sequential_balanced_kmeans",
    "build_refinement_batch",
    "register_algorithm", "get_algorithm", "available_methods",
    "resolve_method", "UnknownMethodError",
]
