"""Unified partitioning engine: one problem type, one ``partition()`` call,
a pluggable algorithm registry, hierarchical (k1 x k2) recursion, batched
vmap execution, a sharded multi-device (shard_map) path via
``partition(problem, devices=P)``, and dynamic repartitioning via
``repartition(problem, previous)`` (warm-started balanced k-means +
migration accounting). See DESIGN.md §Partition-engine / §3b / §8.
"""
from . import algorithms  # noqa: F401  (populates the registry on import)
from .batched import (batched_balanced_kmeans, bucket_balanced_kmeans,
                      build_refinement_batch, sequential_balanced_kmeans)
from .distributed import (ShardedPartitionProblem, partition_sharded,
                          repartition_sharded)
from .engine import partition
from .hierarchical import factor_k, hierarchical_partition
from .problem import PartitionProblem, PartitionResult
from .refine import (UnknownRefinerError, available_refiners, refine,
                     refinement_budgets, refinement_quantization,
                     refiner_short_name, register_refiner, resolve_refiner)
from .registry import (UnknownMethodError, available_methods,
                       distributed_methods, get_algorithm,
                       register_algorithm, resolve_method,
                       supports_devices, supports_warm_start,
                       warm_start_methods)
from .repartition import (WarmState, greedy_center_match, repartition,
                          weighted_centroids)

__all__ = [
    "PartitionProblem", "PartitionResult", "partition", "repartition",
    "refine", "WarmState",
    "available_refiners", "resolve_refiner", "register_refiner",
    "refiner_short_name",
    "UnknownRefinerError", "refinement_budgets", "refinement_quantization",
    "hierarchical_partition", "factor_k",
    "batched_balanced_kmeans", "sequential_balanced_kmeans",
    "bucket_balanced_kmeans", "build_refinement_batch",
    "ShardedPartitionProblem", "partition_sharded", "repartition_sharded",
    "greedy_center_match", "weighted_centroids",
    "register_algorithm", "get_algorithm", "available_methods",
    "resolve_method", "UnknownMethodError",
    "supports_devices", "distributed_methods",
    "supports_warm_start", "warm_start_methods",
]
