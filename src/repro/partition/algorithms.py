"""Registry adapters for the five flat partitioners.

Each adapter maps the unified ``PartitionProblem`` onto the underlying
implementation's native signature and wraps the output in a
``PartitionResult``:

* ``geographer``        — SFC bootstrap + balanced k-means (the paper).
* ``sfc``  (alias hsfc) — Hilbert-curve chunking.
* ``rcb``               — recursive coordinate bisection.
* ``rib``               — recursive inertial bisection.
* ``multijagged`` (mj)  — one-shot multisection.

``**opts`` for ``geographer`` are forwarded into ``BKMConfig`` (epsilon is
taken from the problem unless overridden), so callers can tune
``max_iter`` / ``backend`` / ``warmup`` per call without touching the
problem object.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import baselines
from repro.core.balanced_kmeans import BKMConfig
from repro.core.partitioner import geographer_partition

from .problem import PartitionProblem, PartitionResult
from .registry import register_algorithm

_BKM_FIELDS = {f.name for f in dataclasses.fields(BKMConfig)}


def make_bkm_config(problem: PartitionProblem, k: int | None = None,
                    **opts) -> BKMConfig:
    """BKMConfig for ``problem`` with per-call overrides (unknown keys are
    rejected so typos don't silently fall back to defaults)."""
    bad = set(opts) - _BKM_FIELDS
    if bad:
        raise TypeError(f"unknown BKMConfig options {sorted(bad)}")
    kw = {"k": k if k is not None else problem.k,
          "epsilon": problem.epsilon, **opts}
    return BKMConfig(**kw)


@register_algorithm("geographer", aliases=("balanced_kmeans", "bkm"),
                    supports_devices=True, supports_warm_start=True)
def _geographer(problem: PartitionProblem,
                devices: int | tuple[int, int] | None = None,
                bootstrap: str | None = None, chunk: int | None = None,
                **opts) -> PartitionResult:
    if devices is not None:
        from .distributed import partition_sharded
        return partition_sharded(problem, devices,
                                 bootstrap=bootstrap or "host",
                                 chunk=chunk, **opts)
    if bootstrap is not None:
        raise TypeError("bootstrap= only applies to the multi-device path "
                        "(pass devices=)")
    if chunk is not None:
        raise TypeError("chunk= streams the sharded deal and only applies "
                        "to the multi-device path (pass devices=)")
    cfg = make_bkm_config(problem, **opts)
    labels, centers, infl, stats = geographer_partition(
        problem.points, problem.k, weights=problem.weights, cfg=cfg,
        seed=problem.seed, return_state=True)
    # centers/influence ride on the result so repartition() can warm-start
    # the next solve from this one (DESIGN.md §8)
    return PartitionResult(
        labels=np.asarray(labels, np.int64), k=problem.k,
        method="geographer", problem=problem,
        centers=centers, influence=infl,
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"])})


def _baseline_result(problem, labels, method) -> PartitionResult:
    labels = np.asarray(labels, np.int64)
    res = PartitionResult(labels=labels, k=problem.k, method=method,
                          problem=problem)
    res.stats = {"levels": [{}],
                 "final_imbalance": res.imbalance()}
    return res


@register_algorithm("sfc", aliases=("hsfc", "hilbert"),
                    supports_devices=False, supports_warm_start=False)
def _sfc(problem: PartitionProblem, **opts) -> PartitionResult:
    if opts:
        raise TypeError(f"sfc takes no options, got {sorted(opts)}")
    labels = baselines.sfc_partition(problem.points, problem.k,
                                     problem.weights)
    return _baseline_result(problem, labels, "sfc")


@register_algorithm("rcb", supports_devices=False,
                    supports_warm_start=False)
def _rcb(problem: PartitionProblem, **opts) -> PartitionResult:
    labels = baselines.rcb(problem.points, problem.k, problem.weights,
                           **opts)
    return _baseline_result(problem, labels, "rcb")


@register_algorithm("rib", supports_devices=False,
                    supports_warm_start=False)
def _rib(problem: PartitionProblem, **opts) -> PartitionResult:
    if opts:
        raise TypeError(f"rib takes no options, got {sorted(opts)}")
    labels = baselines.rib(problem.points, problem.k, problem.weights)
    return _baseline_result(problem, labels, "rib")


@register_algorithm("multijagged", aliases=("mj",),
                    supports_devices=False, supports_warm_start=False)
def _multijagged(problem: PartitionProblem, **opts) -> PartitionResult:
    if opts:
        raise TypeError(f"multijagged takes no options, got {sorted(opts)}")
    labels = baselines.multijagged(problem.points, problem.k,
                                   problem.weights)
    return _baseline_result(problem, labels, "multijagged")
