"""Sharded multi-device balanced k-means — `partition(..., devices=P)`.

The paper's scalability story (§4.1) is that every step of Algorithms 1+2
communicates only *global vector sums* over per-process partials: cluster
sizes [k], weighted coordinate sums [k, d], weighted counts [k], and the
bounding box [d]. This module is the actual SPMD driver for that claim:

* ``ShardedPartitionProblem`` — a static-shape sharded view of a
  ``PartitionProblem``: points/weights split round-robin over P devices
  and padded to a common per-device ``cap`` (padding replicates real
  points at weight zero, so it perturbs no weighted sum and no bbox).
  The deal preserves the source dtype (a float32 problem never takes a
  float64 host copy), streams in bounded slot chunks (``chunk=``), and
  can *placement-commit* each shard straight to its device
  (``commit=True``) so the host never holds a full dealt copy of the
  coordinates — peak host staging is O(n/P + chunk) beyond the index
  arrays.
* ``partition_sharded`` — lays the shards on a 1-D device mesh
  (``dist.rules.partition_mesh``), replicates centers/influence, and runs
  ``core.balanced_kmeans`` under ``shard_map`` with ``axis_name`` plumbed
  end-to-end, so every ``_reduce`` in the core becomes a ``psum`` /
  ``pmin`` / ``pmax`` — the paper's communication structure, nothing else.
* ``devices=(P1, P2)`` — the same solve on the 2-D hierarchical mesh
  (``dist.rules.partition_mesh2d``): points shard over the *product* of
  the ``("coarse", "refine")`` axes, every reduction psums over the axis
  tuple. The flattened device order equals the 1-D mesh's, so the run is
  bit-identical to ``devices=P1*P2`` — this is what lets the hierarchical
  engine (partition/hierarchical.py) keep its coarse cut exact while the
  k1 refinements batch over the refine axis alone.

SFC bootstrap (paper Alg. 2 lines 4-7) comes in two flavours:

* ``bootstrap="host"`` (default) — ``core.sfc.sfc_initial_centers`` on the
  gathered points, byte-identical to the single-device path. This is what
  makes the agreement guarantee below possible.
* ``bootstrap="device"`` — fully in-graph distributed bootstrap
  (``core.sfc.sfc_initial_centers_sharded``): per-shard Hilbert keys
  against the psum'd global bbox + global weighted-prefix-sum splitting
  over a psum'd key histogram. O(1)-sized communication, but 30-bit keys
  (vs 62-bit host keys), so centers may differ from the host bootstrap.
  This is also the *out-of-core* bootstrap: no O(n) float64 host copy.

Agreement with the single-device path (tested in
tests/test_sharded_partition.py, documented in DESIGN.md §3b):

* ``devices=1`` is *bit-for-bit identical* to
  ``partition(problem, method="geographer")``: the round-robin layout with
  P=1 is the identity on the permuted order and every psum over a 1-device
  axis is the identity.
* ``devices=P>1`` with ``warmup=False`` differs only by float reduction
  order (per-shard partial sums + psum vs one global ``segment_sum``):
  >= 97% identical labels (100% in most measured configs), asserted by
  the tests.
* ``devices=P>1`` with warm-up (the default) additionally samples a
  per-shard prefix that differs from the global prefix by up to P-1
  points per round; on small problems that can steer k-means to a
  *different but equally balanced* local optimum, so only the imbalance
  bound and block coverage are guaranteed, not label agreement.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans
from repro.core.sfc import sfc_initial_centers, sfc_initial_centers_sharded
from repro.dist.rules import (COARSE_AXIS, PARTITION_AXIS, REFINE_AXIS,
                              partition_mesh, partition_mesh2d)
from repro.kernels.ops import backend_supports_moments, resolve_assign_backend

from .problem import PartitionProblem, PartitionResult

BOOTSTRAPS = ("host", "device")

#: largest per-shard slot index the traced int32 index/label math can
#: address (core.balanced_kmeans iotas, the assign kernels' index math)
INT32_INDEX_CAP = np.iinfo(np.int32).max


def _device_shape(devices) -> tuple[int, ...]:
    """Normalize ``devices`` (int or (P1, P2) tuple) to a mesh shape."""
    if isinstance(devices, (tuple, list)):
        shape = tuple(int(d) for d in devices)
        if len(shape) != 2:
            raise ValueError(
                f"devices tuple must be (P1, P2), got {devices!r}")
        if min(shape) < 1:
            raise ValueError(f"devices must be >= 1, got {devices!r}")
        return shape
    P = int(devices)
    if P < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    return (P,)


def _devices_stat(devices):
    """JSON-friendly devices value for stats dicts (tuple -> list)."""
    return list(devices) if isinstance(devices, (tuple, list)) \
        else int(devices)


def check_index_capacity(n: int, devices) -> int:
    """Validate that the per-shard slot count fits the traced index dtype.

    The round-robin layout gives every shard ``cap = ceil(n / P)`` slots.
    Host-side global-position arithmetic is explicit int64 throughout
    (``gather`` / ``scatter_labels`` address all n points), but the traced
    per-shard index math — the warm-up iota in ``core.balanced_kmeans``
    and the assign kernels' slot indexing — is int32 by kernel contract,
    so ``cap`` must stay <= 2**31 - 1. Spreading the points over more
    devices shrinks ``cap``, so the capacity grows with P (up to
    ~2.1 billion points *per shard*).

    Args:
        n: global point count.
        devices: shard count P, or a (P1, P2) mesh tuple.

    Returns:
        cap — the per-shard slot count ``ceil(n / P)``.

    Raises:
        ValueError: ``cap`` exceeds the int32 index capacity (names n,
            P, cap, and the limit).
    """
    P = int(np.prod(_device_shape(devices)))
    cap = -(-int(n) // P)                  # ceil(n / P)
    if cap > INT32_INDEX_CAP:
        raise ValueError(
            f"per-shard slot count cap=ceil(n/P)={cap} overflows the "
            f"int32 traced index capacity ({INT32_INDEX_CAP}) at "
            f"n={n}, devices={P}; shard over more devices so that "
            f"ceil(n/P) <= {INT32_INDEX_CAP}")
    return cap


def _mesh_for_shape(shape: tuple[int, ...]):
    """The device mesh matching a ``_device_shape`` result."""
    if len(shape) == 1:
        return partition_mesh(shape[0])
    return partition_mesh2d(*shape)


def _mesh_spec(mesh):
    """PartitionSpec sharding dim 0 over every axis of ``mesh``."""
    from jax.sharding import PartitionSpec as P
    names = mesh.axis_names
    return P(names[0] if len(names) == 1 else names)


@dataclass(frozen=True)
class ShardedPartitionProblem:
    """Static-shape sharded view of a ``PartitionProblem``.

    Layout: the points are first permuted with the problem's seed (the
    same permutation the single-device path uses for warm-up sampling),
    then dealt *round-robin* — permuted position g lives at shard g % P,
    slot g // P. A shard's slot prefix therefore tracks the global
    permutation prefix to within P-1 points, which keeps the warm-up
    sample semantics of ``core.balanced_kmeans`` (per-shard prefix masks)
    aligned with the single-device run.

    Slots past n (when P does not divide n) wrap around to real points at
    weight zero: they influence neither weighted sums nor the (psum'd)
    bounding box, and their labels are discarded on scatter-back.

    Attributes:
        problem: the source ``PartitionProblem``.
        devices: flat shard count P (the product, for a 2-D mesh — the
            layout depends only on P, never on the mesh factorization).
        points: [P, cap, d] — shard-major dealt coordinates in the
            *source* floating dtype (integer sources promote to float64;
            there is no silent float64 up-cast of float32 problems). A
            committed view (``commit=True``) holds a mesh-sharded
            ``jax.Array`` here instead of host numpy.
        weights: [P, cap] — dealt weights in the source floating dtype;
            exactly 0 marks a padded slot (the weight also carries the
            validity signal into the jitted core, which treats ``w > 0``
            as "real"). Committed views hold a ``jax.Array``.
        gather: [P, cap] int64 — original point id of every slot
            (``labels[gather[valid]]`` scatters shard labels home).
        valid: [P, cap] bool — False for padded slots.
    """
    problem: PartitionProblem
    devices: int
    points: np.ndarray
    weights: np.ndarray
    gather: np.ndarray
    valid: np.ndarray

    @property
    def cap(self) -> int:
        """Per-shard slot count, ``ceil(n / P)``."""
        return self.points.shape[1]

    @classmethod
    def from_problem(cls, problem: PartitionProblem, devices, *,
                     chunk: int | None = None, commit: bool = False,
                     dtype=None, mesh=None) -> "ShardedPartitionProblem":
        """Deal ``problem`` onto ``devices`` shards.

        The deal streams in bounded slot slices: each slice gathers
        ``P * min(chunk, cap)`` permuted points, so transient host
        staging is O(P * chunk) on top of the output arrays
        (``chunk=None`` = one-shot, a single full-cap slice — bit-
        identical to any chunked setting). With ``commit=True`` the
        dealt coordinates/weights go straight to their devices shard by
        shard and the host never holds the full [P, cap, d] copy: peak
        host staging drops to O(n/P + chunk) beyond the int64 ``gather``
        index (which stays on the host for ``scatter_labels``).

        Args:
            problem: the instance to shard; its seed fixes the
                permutation so re-sharding is deterministic.
            devices: shard count P with ``1 <= P <= problem.n``, or a
                (P1, P2) 2-D mesh shape (the layout only depends on the
                product).
            chunk: per-shard slots gathered per host slice (None = all).
            commit: placement-commit each shard's points/weights to its
                device (requires P <= visible jax devices); ``points`` /
                ``weights`` become mesh-sharded ``jax.Array``s.
            dtype: target dtype for committed arrays (None = the source
                dtype; commit respects jax's x64 setting).
            mesh: device mesh for ``commit`` (None = the 1-D or 2-D
                partition mesh implied by ``devices``).

        Returns:
            The static-shape sharded view.

        Raises:
            ValueError: P < 1, P > n, or an int32 index-capacity
                overflow (``check_index_capacity``).
        """
        shape = _device_shape(devices)
        P = int(np.prod(shape))
        n = problem.n
        if P > n:
            raise ValueError(f"devices={P} exceeds n={n} points")
        cap = check_index_capacity(n, P)
        rng = np.random.default_rng(problem.seed)
        perm = rng.permutation(n)
        src = np.asarray(problem.points)
        pdtype = (src.dtype if np.issubdtype(src.dtype, np.floating)
                  else np.dtype(np.float64))
        if problem.weights is None:
            w = np.ones(n, pdtype)
        else:
            w = np.asarray(problem.weights)
            if not np.issubdtype(w.dtype, np.floating):
                w = np.asarray(w, np.float64)
        dim = src.shape[1]
        step = cap if chunk is None else max(1, min(int(chunk), cap))
        gather = np.empty((P, cap), np.int64)
        valid = np.empty((P, cap), bool)

        if not commit:
            pts = np.empty((P, cap, dim), pdtype)
            wts = np.empty((P, cap), w.dtype)
            for s0 in range(0, cap, step):
                s1 = min(s0 + step, cap)
                # global positions of slot columns [s0, s1): g[p, j] =
                # (s0+j)*P + p — explicit int64 so the position space
                # P*cap never overflows a platform-default int32 arange
                g = np.arange(s0 * P, s1 * P,
                              dtype=np.int64).reshape(s1 - s0, P).T
                v = g < n
                gth = perm[g % n]
                gather[:, s0:s1] = gth
                valid[:, s0:s1] = v
                pts[:, s0:s1] = src[gth]
                wts[:, s0:s1] = np.where(v, w[gth], 0)
            return cls(problem=problem, devices=P, points=pts,
                       weights=wts, gather=gather, valid=valid)

        # placement-commit path: build one shard at a time (O(cap) host
        # staging), convert to the target dtype slice by slice, and push
        # it to its device before touching the next shard
        from jax.sharding import NamedSharding
        mesh = mesh if mesh is not None else _mesh_for_shape(shape)
        odtype = np.dtype(dtype) if dtype is not None else pdtype
        sharding = NamedSharding(mesh, _mesh_spec(mesh))
        devs = mesh.devices.reshape(-1)
        ppieces, wpieces = [], []
        for p in range(P):
            pbuf = np.empty((1, cap, dim), odtype)
            wbuf = np.empty((1, cap), odtype)
            for s0 in range(0, cap, step):
                s1 = min(s0 + step, cap)
                g = np.arange(s0, s1, dtype=np.int64) * P + p
                v = g < n
                gth = perm[g % n]
                gather[p, s0:s1] = gth
                valid[p, s0:s1] = v
                pbuf[0, s0:s1] = src[gth]
                wbuf[0, s0:s1] = np.where(v, w[gth], 0)
            ppieces.append(jax.device_put(pbuf, devs[p]))
            wpieces.append(jax.device_put(wbuf, devs[p]))
        pts = jax.make_array_from_single_device_arrays(
            (P, cap, dim), sharding, ppieces)
        wts = jax.make_array_from_single_device_arrays(
            (P, cap), sharding, wpieces)
        return cls(problem=problem, devices=P, points=pts, weights=wts,
                   gather=gather, valid=valid)

    def deal(self, values: np.ndarray,
             chunk: int | None = None) -> np.ndarray:
        """Deal a per-point host array onto the shard layout.

        The inverse direction of ``scatter_labels``: original-point-order
        values land at their round-robin slot (padded slots replicate the
        aliased real point's value, consistent with the coordinate
        padding).

        Args:
            values: [n, ...] array in original point order.
            chunk: per-shard slots gathered per slice (None = one shot);
                bit-identical to the one-shot gather for every setting.

        Returns:
            [P, cap, ...] dealt array (source dtype preserved).
        """
        values = np.asarray(values)
        if chunk is None:
            return values[self.gather]
        out = np.empty(self.gather.shape + values.shape[1:], values.dtype)
        step = max(1, min(int(chunk), self.cap))
        for s0 in range(0, self.cap, step):
            s1 = min(s0 + step, self.cap)
            out[:, s0:s1] = values[self.gather[:, s0:s1]]
        return out

    def scatter_labels(self, A: np.ndarray,
                       chunk: int | None = None) -> np.ndarray:
        """Scatter shard labels back home.

        Args:
            A: [P, cap] per-shard labels.
            chunk: per-shard slots scattered per slice (None = one shot).
                Every valid slot addresses a distinct original id, so the
                chunked scatter is bit-identical to the one-shot write.

        Returns:
            [n] int64 labels in original point order (padded slots
            dropped).
        """
        A = np.asarray(A)
        labels = np.empty(self.problem.n, np.int64)
        step = self.cap if chunk is None else max(1, min(int(chunk),
                                                         self.cap))
        for s0 in range(0, self.cap, step):
            s1 = min(s0 + step, self.cap)
            v = self.valid[:, s0:s1]
            labels[self.gather[:, s0:s1][v]] = A[:, s0:s1][v]
        return labels


@functools.lru_cache(maxsize=64)
def _build_runner(devices, cap: int, dim: int, cfg: BKMConfig,
                  bootstrap: str, n_global: int):
    """Compile-cached shard_map driver for one (mesh, shapes, cfg) combo.

    ``devices`` is an int (1-D ``PARTITION_AXIS`` mesh) or a (P1, P2)
    tuple (2-D ``(COARSE_AXIS, REFINE_AXIS)`` mesh): the points shard
    over the axis *product* and every reduction inside the core psums
    over the axis tuple, so the 2-D run is bit-identical to the flat
    P1*P2 run (same flattened device order, same partial-sum placement).

    ``bootstrap`` selects center seeding: "host" (centers0 computed on the
    host, passed in replicated), "device" (in-graph distributed SFC
    bootstrap; centers0 input ignored), or "warm" (centers0 AND influence0
    are the replicated previous-partition state and the k-means core runs
    with ``warm_start=True`` — the sampled warm-up and the SFC bootstrap
    are both skipped).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if isinstance(devices, tuple):
        mesh = partition_mesh2d(*devices)
        axis = (COARSE_AXIS, REFINE_AXIS)
        spec = P(axis)
    else:
        mesh = partition_mesh(devices)
        axis = PARTITION_AXIS
        spec = P(axis)

    def local_fn(points, weights, centers0, influence0, prev_labels):
        points = points.reshape(cap, dim)
        weights = weights.reshape(cap)
        if bootstrap == "device":
            centers0 = sfc_initial_centers_sharded(
                points.astype(jnp.float32), weights.astype(jnp.float32),
                cfg.k, axis)
        A, centers, infl, stats = balanced_kmeans(
            points, cfg, weights, centers0.astype(cfg.dtype),
            axis_name=axis, n_global=n_global,
            influence0=influence0, warm_start=(bootstrap == "warm"),
            prev_assignment=(prev_labels.reshape(cap)
                             if bootstrap == "warm" else None))
        return A[None], centers, infl, stats

    inner = shard_map(
        local_fn, mesh=mesh,
        in_specs=(spec, spec, P(), P(), spec),
        out_specs=(spec, P(), P(), P()),
        check_rep=False)
    return jax.jit(inner)


def _runner_key(devices):
    """Hashable ``devices`` for the runner cache (tuple-or-int)."""
    shape = _device_shape(devices)
    return shape if len(shape) > 1 else shape[0]


def _prep_sharded_cfg(problem: PartitionProblem, devices,
                      cfg: BKMConfig, chunk: int | None = None):
    """Shard the problem (placement-committed in the solve dtype, so the
    drivers stage no further host copies) and pin cfg's "auto" backend AND
    its fused assign+reduce choice to concrete values *before* tracing the
    shard_map body (both depend on process-global state, not trace-local
    state). Returns (sharded, cfg). The fused sweep keeps the paper's
    psum-only communication contract: per balance iteration one [k] size
    sum, per movement iteration one [k, d] + one [k] moment sum."""
    shape = _device_shape(devices)
    sp = ShardedPartitionProblem.from_problem(
        problem, devices, chunk=chunk, commit=True, dtype=cfg.dtype,
        mesh=_mesh_for_shape(shape))
    backend = resolve_assign_backend(cfg.assign_backend, sharded=True,
                                     n_local=sp.cap)
    fused = (backend_supports_moments(backend) if cfg.fused is None
             else cfg.fused)
    cfg = dataclasses.replace(cfg, use_kernel=False, backend=backend,
                              fused=fused)
    return sp, cfg


def geographer_partition_sharded(problem: PartitionProblem, devices,
                                 cfg: BKMConfig | None = None,
                                 bootstrap: str = "host",
                                 chunk: int | None = None):
    """Raw sharded (cold-start) run.

    Args:
        problem: the partitioning instance; its seed fixes the round-robin
            deal permutation.
        devices: number of shards P (1 <= P <= problem.n), or a (P1, P2)
            2-D mesh shape — bit-identical to the flat P1*P2 run.
        cfg: BKMConfig; None uses the problem's (k, epsilon) defaults.
        bootstrap: "host" (host-side SFC centers, identical to the
            single-device path) or "device" (in-graph distributed SFC
            bootstrap — also the out-of-core choice: no O(n) float64
            host copy of the points).
        chunk: per-shard slots per deal slice (streaming deal; None =
            one shot — results are bit-identical either way).

    Returns:
        (labels [n] int64 in original point order, centers [k, d],
        influence [k], stats dict) — prefer the front door
        ``partition(problem, devices=...)``.
    """
    if bootstrap not in BOOTSTRAPS:
        raise ValueError(f"bootstrap must be one of {BOOTSTRAPS}, "
                         f"got {bootstrap!r}")
    cfg = cfg or BKMConfig(k=problem.k, epsilon=problem.epsilon)
    sp, cfg = _prep_sharded_cfg(problem, devices, cfg, chunk=chunk)
    if bootstrap == "host":
        centers0 = sfc_initial_centers(
            np.asarray(problem.points, np.float64), cfg.k, problem.weights)
    else:
        centers0 = np.zeros((cfg.k, problem.dim))      # ignored in-graph
    run = _build_runner(_runner_key(devices), sp.cap, problem.dim, cfg,
                        bootstrap, problem.n)
    A, centers, infl, stats = run(sp.points, sp.weights,
                                  jnp.asarray(centers0, cfg.dtype),
                                  jnp.ones(cfg.k, cfg.dtype),
                                  jnp.zeros(sp.devices * sp.cap, jnp.int32))
    labels = sp.scatter_labels(np.asarray(jax.device_get(A)), chunk=chunk)
    return labels, centers, infl, jax.tree.map(np.asarray, stats)


def geographer_repartition_sharded(problem: PartitionProblem, devices,
                                   centers0: np.ndarray,
                                   influence0: np.ndarray | None = None,
                                   cfg: BKMConfig | None = None,
                                   prev_labels: np.ndarray | None = None,
                                   chunk: int | None = None):
    """Raw sharded warm-start run: balanced k-means resumed from a previous
    partition's (centers0, influence0) state, no SFC bootstrap.

    The previous centers and influence are replicated across shards
    (exactly like every cold run's centers) and the communication pattern
    stays psum-only — warm starting adds zero new collectives. The shard
    layout comes from the problem's seed, so ``devices=1`` is bit-for-bit
    identical to ``core.partitioner.geographer_repartition`` with the same
    seed.

    Args:
        problem: the (possibly re-weighted / moved) partitioning instance.
        devices: number of shards P, or a (P1, P2) 2-D mesh shape.
        centers0: [k, d] previous centers.
        influence0: [k] previous influence (None = ones).
        cfg: BKMConfig; ``warmup`` is forced off.
        prev_labels: [n] previous block ids in original point order; when
            given, an unchanged-and-still-balanced partition is re-emitted
            verbatim (no-op detection). Padded slots replicate real
            points, so the comparison is consistent across the deal.
            ``repartition()`` always passes the previous labels; when a
            direct caller omits them, a -1 sentinel is dealt instead —
            it can never equal a real assignment (labels are >= 0), so
            no-op detection and migration-style comparisons can never
            fire on synthetic labels (locked by
            tests/test_out_of_core.py).
        chunk: per-shard slots per deal slice (None = one shot).

    Returns:
        (labels [n] int64, centers [k, d], influence [k], stats dict);
        ``stats["iters"]`` is 0 when the previous state is still a fixed
        point. Prefer ``repartition(problem, previous, devices=...)``.
    """
    cfg = cfg or BKMConfig(k=problem.k, epsilon=problem.epsilon,
                           warmup=False)
    if cfg.warmup:
        cfg = dataclasses.replace(cfg, warmup=False)
    if centers0.shape[0] != cfg.k:
        raise ValueError(f"centers0 has {centers0.shape[0]} rows, "
                         f"k={cfg.k}")
    sp, cfg = _prep_sharded_cfg(problem, devices, cfg, chunk=chunk)
    run = _build_runner(_runner_key(devices), sp.cap, problem.dim, cfg,
                        "warm", problem.n)
    infl0 = (jnp.ones(cfg.k, cfg.dtype) if influence0 is None
             else jnp.asarray(influence0, cfg.dtype))
    if prev_labels is None:
        # synthetic sentinel: -1 never matches a real assignment (block
        # ids are >= 0), so the no-op shortcut in the core cannot fire on
        # a partition that never existed — the solver always re-assigns
        # from (centers0, influence0)
        prev = np.full((sp.devices, sp.cap), -1, np.int32)
    else:
        prev = sp.deal(np.asarray(prev_labels, np.int32), chunk=chunk)
    A, centers, infl, stats = run(sp.points, sp.weights,
                                  jnp.asarray(centers0, cfg.dtype),
                                  infl0,
                                  jnp.asarray(prev.reshape(-1), jnp.int32))
    labels = sp.scatter_labels(np.asarray(jax.device_get(A)), chunk=chunk)
    return labels, centers, infl, jax.tree.map(np.asarray, stats)


def partition_sharded(problem: PartitionProblem, devices, *,
                      bootstrap: str = "host", chunk: int | None = None,
                      **opts) -> PartitionResult:
    """Multi-device geographer partition of ``problem`` over ``devices``
    shards (the ``devices=`` path of the ``partition()`` front door).

    Args:
        problem: the partitioning instance (its seed fixes the shard
            layout permutation).
        devices: number of shards P, or a (P1, P2) 2-D hierarchical mesh
            shape (bit-identical to the flat P1*P2 run — the points shard
            over the axis product); must satisfy 1 <= P <= problem.n and
            P <= len(jax.devices()).
        bootstrap: SFC center seeding — "host" (identical to the
            single-device path, the agreement default) or "device" (fully
            in-graph distributed bootstrap, O(1)-sized communication, no
            O(n) float64 host copy).
        chunk: per-shard slots per deal slice — bounds transient host
            staging during the deal without changing any result bit.
        **opts: BKMConfig field overrides, exactly as in the single-device
            adapter (e.g. ``max_iter=50``, ``warmup=False``); unknown
            fields raise TypeError.

    Returns:
        PartitionResult with labels in original point order, the final
        (centers, influence) state — reusable as a ``repartition()`` warm
        start — and ``stats`` carrying the k-means iteration history plus
        ``devices`` / ``bootstrap``.
    """
    from .algorithms import make_bkm_config
    cfg = make_bkm_config(problem, **opts)
    labels, centers, infl, stats = geographer_partition_sharded(
        problem, devices, cfg=cfg, bootstrap=bootstrap, chunk=chunk)
    return PartitionResult(
        labels=labels, k=problem.k, method="geographer", problem=problem,
        centers=np.asarray(centers), influence=np.asarray(infl),
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"]),
               "devices": _devices_stat(devices), "bootstrap": bootstrap})


def repartition_sharded(problem: PartitionProblem, devices,
                        centers0: np.ndarray,
                        influence0: np.ndarray | None = None,
                        prev_labels: np.ndarray | None = None,
                        chunk: int | None = None,
                        **opts) -> PartitionResult:
    """Multi-device warm-started repartition (the ``devices=`` path of the
    ``repartition()`` front door).

    Args:
        problem: the perturbed partitioning instance.
        devices: number of shards P, or a (P1, P2) 2-D mesh shape.
        centers0: [k, d] previous partition's centers.
        influence0: [k] previous partition's influence (None = ones).
        prev_labels: [n] previous block ids (enables no-op detection;
            ``repartition()`` always passes them — omitting them deals a
            -1 sentinel that can never masquerade as a real assignment).
        chunk: per-shard slots per deal slice (None = one shot).
        **opts: BKMConfig field overrides (``warmup`` is forced off).

    Returns:
        PartitionResult (labels, final centers/influence, stats with
        ``stats["warm_start"] = True`` and the movement iteration count at
        ``stats["iters"]``).
    """
    from .algorithms import make_bkm_config
    cfg = make_bkm_config(problem, **dict(opts, warmup=False))
    labels, centers, infl, stats = geographer_repartition_sharded(
        problem, devices, centers0, influence0, cfg=cfg,
        prev_labels=prev_labels, chunk=chunk)
    return PartitionResult(
        labels=labels, k=problem.k, method="geographer", problem=problem,
        centers=np.asarray(centers), influence=np.asarray(infl),
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"]),
               "iters": int(stats["iters"]),
               "devices": _devices_stat(devices), "warm_start": True})
