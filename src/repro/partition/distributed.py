"""Sharded multi-device balanced k-means — `partition(..., devices=P)`.

The paper's scalability story (§4.1) is that every step of Algorithms 1+2
communicates only *global vector sums* over per-process partials: cluster
sizes [k], weighted coordinate sums [k, d], weighted counts [k], and the
bounding box [d]. This module is the actual SPMD driver for that claim:

* ``ShardedPartitionProblem`` — a static-shape sharded view of a
  ``PartitionProblem``: points/weights split round-robin over P devices
  and padded to a common per-device ``cap`` (padding replicates real
  points at weight zero, so it perturbs no weighted sum and no bbox).
* ``partition_sharded`` — lays the shards on a 1-D device mesh
  (``dist.rules.partition_mesh``), replicates centers/influence, and runs
  ``core.balanced_kmeans`` under ``shard_map`` with ``axis_name`` plumbed
  end-to-end, so every ``_reduce`` in the core becomes a ``psum`` /
  ``pmin`` / ``pmax`` — the paper's communication structure, nothing else.

SFC bootstrap (paper Alg. 2 lines 4-7) comes in two flavours:

* ``bootstrap="host"`` (default) — ``core.sfc.sfc_initial_centers`` on the
  gathered points, byte-identical to the single-device path. This is what
  makes the agreement guarantee below possible.
* ``bootstrap="device"`` — fully in-graph distributed bootstrap
  (``core.sfc.sfc_initial_centers_sharded``): per-shard Hilbert keys
  against the psum'd global bbox + global weighted-prefix-sum splitting
  over a psum'd key histogram. O(1)-sized communication, but 30-bit keys
  (vs 62-bit host keys), so centers may differ from the host bootstrap.

Agreement with the single-device path (tested in
tests/test_sharded_partition.py, documented in DESIGN.md §3b):

* ``devices=1`` is *bit-for-bit identical* to
  ``partition(problem, method="geographer")``: the round-robin layout with
  P=1 is the identity on the permuted order and every psum over a 1-device
  axis is the identity.
* ``devices=P>1`` with ``warmup=False`` differs only by float reduction
  order (per-shard partial sums + psum vs one global ``segment_sum``):
  >= 97% identical labels (100% in most measured configs), asserted by
  the tests.
* ``devices=P>1`` with warm-up (the default) additionally samples a
  per-shard prefix that differs from the global prefix by up to P-1
  points per round; on small problems that can steer k-means to a
  *different but equally balanced* local optimum, so only the imbalance
  bound and block coverage are guaranteed, not label agreement.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans
from repro.core.sfc import sfc_initial_centers, sfc_initial_centers_sharded
from repro.dist.rules import PARTITION_AXIS, partition_mesh
from repro.kernels.ops import resolve_assign_backend

from .problem import PartitionProblem, PartitionResult

BOOTSTRAPS = ("host", "device")


@dataclass(frozen=True)
class ShardedPartitionProblem:
    """Static-shape sharded view of a ``PartitionProblem``.

    Layout: the points are first permuted with the problem's seed (the
    same permutation the single-device path uses for warm-up sampling),
    then dealt *round-robin* — permuted position g lives at shard g % P,
    slot g // P. A shard's slot prefix therefore tracks the global
    permutation prefix to within P-1 points, which keeps the warm-up
    sample semantics of ``core.balanced_kmeans`` (per-shard prefix masks)
    aligned with the single-device run.

    Slots past n (when P does not divide n) wrap around to real points at
    weight zero: they influence neither weighted sums nor the (psum'd)
    bounding box, and their labels are discarded on scatter-back.
    """
    problem: PartitionProblem
    devices: int
    points: np.ndarray      # [P, cap, d] float64
    weights: np.ndarray     # [P, cap] float64, 0.0 marks padded slots
    gather: np.ndarray      # [P, cap] int64 original point ids
    valid: np.ndarray       # [P, cap] bool, False for padded slots

    @property
    def cap(self) -> int:
        return self.points.shape[1]

    @classmethod
    def from_problem(cls, problem: PartitionProblem,
                     devices: int) -> "ShardedPartitionProblem":
        P = int(devices)
        if P < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        n = problem.n
        if P > n:
            raise ValueError(f"devices={P} exceeds n={n} points")
        rng = np.random.default_rng(problem.seed)
        perm = rng.permutation(n)
        cap = -(-n // P)                       # ceil(n / P)
        g = np.arange(P * cap).reshape(cap, P).T     # [P, cap] global pos
        valid = g < n
        gather = perm[g % n]
        pts = np.asarray(problem.points, np.float64)[gather]
        w = (np.ones(n, np.float64) if problem.weights is None
             else np.asarray(problem.weights, np.float64))
        weights = np.where(valid, w[gather], 0.0)
        return cls(problem=problem, devices=P, points=pts, weights=weights,
                   gather=gather, valid=valid)

    def scatter_labels(self, A: np.ndarray) -> np.ndarray:
        """[P, cap] per-shard labels -> [n] labels in original point order
        (padded slots dropped)."""
        labels = np.empty(self.problem.n, np.int64)
        labels[self.gather[self.valid]] = np.asarray(A)[self.valid]
        return labels


@functools.lru_cache(maxsize=64)
def _build_runner(devices: int, cap: int, dim: int, cfg: BKMConfig,
                  bootstrap: str, n_global: int):
    """Compile-cached shard_map driver for one (mesh, shapes, cfg) combo."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = partition_mesh(devices)
    axis = PARTITION_AXIS

    def local_fn(points, weights, centers0):
        points = points.reshape(cap, dim)
        weights = weights.reshape(cap)
        if bootstrap == "device":
            centers0 = sfc_initial_centers_sharded(
                points.astype(jnp.float32), weights.astype(jnp.float32),
                cfg.k, axis)
        A, centers, infl, stats = balanced_kmeans(
            points, cfg, weights, centers0.astype(cfg.dtype),
            axis_name=axis, n_global=n_global)
        return A[None], centers, infl, stats

    inner = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(axis), P(), P(), P()),
        check_rep=False)
    return jax.jit(inner)


def geographer_partition_sharded(problem: PartitionProblem, devices: int,
                                 cfg: BKMConfig | None = None,
                                 bootstrap: str = "host"):
    """Raw sharded run. Returns (labels [n] int64, centers, influence,
    stats) — prefer ``partition(problem, devices=...)``."""
    if bootstrap not in BOOTSTRAPS:
        raise ValueError(f"bootstrap must be one of {BOOTSTRAPS}, "
                         f"got {bootstrap!r}")
    cfg = cfg or BKMConfig(k=problem.k, epsilon=problem.epsilon)
    # pin "auto" to a concrete backend *before* tracing the shard_map body
    sp = ShardedPartitionProblem.from_problem(problem, devices)
    cfg = dataclasses.replace(
        cfg, use_kernel=False,
        backend=resolve_assign_backend(cfg.assign_backend, sharded=True,
                                       n_local=sp.cap))
    if bootstrap == "host":
        centers0 = sfc_initial_centers(
            np.asarray(problem.points, np.float64), cfg.k, problem.weights)
    else:
        centers0 = np.zeros((cfg.k, problem.dim))      # ignored in-graph
    run = _build_runner(sp.devices, sp.cap, problem.dim, cfg, bootstrap,
                        problem.n)
    pts = jnp.asarray(sp.points, cfg.dtype)
    w = jnp.asarray(sp.weights, cfg.dtype)
    A, centers, infl, stats = run(pts, w, jnp.asarray(centers0, cfg.dtype))
    labels = sp.scatter_labels(np.asarray(jax.device_get(A)))
    return labels, centers, infl, jax.tree.map(np.asarray, stats)


def partition_sharded(problem: PartitionProblem, devices: int, *,
                      bootstrap: str = "host", **opts) -> PartitionResult:
    """Multi-device geographer partition of ``problem`` over ``devices``
    shards (the ``devices=`` path of the ``partition()`` front door).

    ``opts`` are BKMConfig fields, exactly as in the single-device
    adapter. ``bootstrap`` selects the SFC center seeding: "host"
    (identical to single-device, the agreement default) or "device"
    (fully in-graph distributed bootstrap).
    """
    from .algorithms import make_bkm_config
    cfg = make_bkm_config(problem, **opts)
    labels, centers, infl, stats = geographer_partition_sharded(
        problem, devices, cfg=cfg, bootstrap=bootstrap)
    return PartitionResult(
        labels=labels, k=problem.k, method="geographer", problem=problem,
        centers=np.asarray(centers), influence=np.asarray(infl),
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"]),
               "devices": int(devices), "bootstrap": bootstrap})
