"""Sharded multi-device balanced k-means — `partition(..., devices=P)`.

The paper's scalability story (§4.1) is that every step of Algorithms 1+2
communicates only *global vector sums* over per-process partials: cluster
sizes [k], weighted coordinate sums [k, d], weighted counts [k], and the
bounding box [d]. This module is the actual SPMD driver for that claim:

* ``ShardedPartitionProblem`` — a static-shape sharded view of a
  ``PartitionProblem``: points/weights split round-robin over P devices
  and padded to a common per-device ``cap`` (padding replicates real
  points at weight zero, so it perturbs no weighted sum and no bbox).
* ``partition_sharded`` — lays the shards on a 1-D device mesh
  (``dist.rules.partition_mesh``), replicates centers/influence, and runs
  ``core.balanced_kmeans`` under ``shard_map`` with ``axis_name`` plumbed
  end-to-end, so every ``_reduce`` in the core becomes a ``psum`` /
  ``pmin`` / ``pmax`` — the paper's communication structure, nothing else.

SFC bootstrap (paper Alg. 2 lines 4-7) comes in two flavours:

* ``bootstrap="host"`` (default) — ``core.sfc.sfc_initial_centers`` on the
  gathered points, byte-identical to the single-device path. This is what
  makes the agreement guarantee below possible.
* ``bootstrap="device"`` — fully in-graph distributed bootstrap
  (``core.sfc.sfc_initial_centers_sharded``): per-shard Hilbert keys
  against the psum'd global bbox + global weighted-prefix-sum splitting
  over a psum'd key histogram. O(1)-sized communication, but 30-bit keys
  (vs 62-bit host keys), so centers may differ from the host bootstrap.

Agreement with the single-device path (tested in
tests/test_sharded_partition.py, documented in DESIGN.md §3b):

* ``devices=1`` is *bit-for-bit identical* to
  ``partition(problem, method="geographer")``: the round-robin layout with
  P=1 is the identity on the permuted order and every psum over a 1-device
  axis is the identity.
* ``devices=P>1`` with ``warmup=False`` differs only by float reduction
  order (per-shard partial sums + psum vs one global ``segment_sum``):
  >= 97% identical labels (100% in most measured configs), asserted by
  the tests.
* ``devices=P>1`` with warm-up (the default) additionally samples a
  per-shard prefix that differs from the global prefix by up to P-1
  points per round; on small problems that can steer k-means to a
  *different but equally balanced* local optimum, so only the imbalance
  bound and block coverage are guaranteed, not label agreement.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans
from repro.core.sfc import sfc_initial_centers, sfc_initial_centers_sharded
from repro.dist.rules import PARTITION_AXIS, partition_mesh
from repro.kernels.ops import backend_supports_moments, resolve_assign_backend

from .problem import PartitionProblem, PartitionResult

BOOTSTRAPS = ("host", "device")


@dataclass(frozen=True)
class ShardedPartitionProblem:
    """Static-shape sharded view of a ``PartitionProblem``.

    Layout: the points are first permuted with the problem's seed (the
    same permutation the single-device path uses for warm-up sampling),
    then dealt *round-robin* — permuted position g lives at shard g % P,
    slot g // P. A shard's slot prefix therefore tracks the global
    permutation prefix to within P-1 points, which keeps the warm-up
    sample semantics of ``core.balanced_kmeans`` (per-shard prefix masks)
    aligned with the single-device run.

    Slots past n (when P does not divide n) wrap around to real points at
    weight zero: they influence neither weighted sums nor the (psum'd)
    bounding box, and their labels are discarded on scatter-back.

    Attributes:
        problem: the source ``PartitionProblem``.
        devices: shard count P.
        points: [P, cap, d] float64 — shard-major dealt coordinates.
        weights: [P, cap] float64 — dealt weights; exactly 0.0 marks a
            padded slot (the weight also carries the validity signal into
            the jitted core, which treats ``w > 0`` as "real").
        gather: [P, cap] int64 — original point id of every slot
            (``labels[gather[valid]]`` scatters shard labels home).
        valid: [P, cap] bool — False for padded slots.
    """
    problem: PartitionProblem
    devices: int
    points: np.ndarray
    weights: np.ndarray
    gather: np.ndarray
    valid: np.ndarray

    @property
    def cap(self) -> int:
        """Per-shard slot count, ``ceil(n / P)``."""
        return self.points.shape[1]

    @classmethod
    def from_problem(cls, problem: PartitionProblem,
                     devices: int) -> "ShardedPartitionProblem":
        """Deal ``problem`` onto ``devices`` shards.

        Args:
            problem: the instance to shard; its seed fixes the
                permutation so re-sharding is deterministic.
            devices: shard count P with ``1 <= P <= problem.n``.

        Returns:
            The static-shape sharded view.

        Raises:
            ValueError: P < 1 or P > n.
        """
        P = int(devices)
        if P < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        n = problem.n
        if P > n:
            raise ValueError(f"devices={P} exceeds n={n} points")
        rng = np.random.default_rng(problem.seed)
        perm = rng.permutation(n)
        cap = -(-n // P)                       # ceil(n / P)
        g = np.arange(P * cap).reshape(cap, P).T     # [P, cap] global pos
        valid = g < n
        gather = perm[g % n]
        pts = np.asarray(problem.points, np.float64)[gather]
        w = (np.ones(n, np.float64) if problem.weights is None
             else np.asarray(problem.weights, np.float64))
        weights = np.where(valid, w[gather], 0.0)
        return cls(problem=problem, devices=P, points=pts, weights=weights,
                   gather=gather, valid=valid)

    def deal(self, values: np.ndarray) -> np.ndarray:
        """Deal a per-point host array onto the shard layout.

        The inverse direction of ``scatter_labels``: original-point-order
        values land at their round-robin slot (padded slots replicate the
        aliased real point's value, consistent with the coordinate
        padding).

        Args:
            values: [n, ...] array in original point order.

        Returns:
            [P, cap, ...] dealt array.
        """
        return np.asarray(values)[self.gather]

    def scatter_labels(self, A: np.ndarray) -> np.ndarray:
        """Scatter shard labels back home.

        Args:
            A: [P, cap] per-shard labels.

        Returns:
            [n] int64 labels in original point order (padded slots
            dropped).
        """
        labels = np.empty(self.problem.n, np.int64)
        labels[self.gather[self.valid]] = np.asarray(A)[self.valid]
        return labels


@functools.lru_cache(maxsize=64)
def _build_runner(devices: int, cap: int, dim: int, cfg: BKMConfig,
                  bootstrap: str, n_global: int):
    """Compile-cached shard_map driver for one (mesh, shapes, cfg) combo.

    ``bootstrap`` selects center seeding: "host" (centers0 computed on the
    host, passed in replicated), "device" (in-graph distributed SFC
    bootstrap; centers0 input ignored), or "warm" (centers0 AND influence0
    are the replicated previous-partition state and the k-means core runs
    with ``warm_start=True`` — the sampled warm-up and the SFC bootstrap
    are both skipped).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = partition_mesh(devices)
    axis = PARTITION_AXIS

    def local_fn(points, weights, centers0, influence0, prev_labels):
        points = points.reshape(cap, dim)
        weights = weights.reshape(cap)
        if bootstrap == "device":
            centers0 = sfc_initial_centers_sharded(
                points.astype(jnp.float32), weights.astype(jnp.float32),
                cfg.k, axis)
        A, centers, infl, stats = balanced_kmeans(
            points, cfg, weights, centers0.astype(cfg.dtype),
            axis_name=axis, n_global=n_global,
            influence0=influence0, warm_start=(bootstrap == "warm"),
            prev_assignment=(prev_labels.reshape(cap)
                             if bootstrap == "warm" else None))
        return A[None], centers, infl, stats

    inner = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(), P(), P(axis)),
        out_specs=(P(axis), P(), P(), P()),
        check_rep=False)
    return jax.jit(inner)


def _prep_sharded_cfg(problem: PartitionProblem, devices: int,
                      cfg: BKMConfig):
    """Shard the problem and pin cfg's "auto" backend AND its fused
    assign+reduce choice to concrete values *before* tracing the shard_map
    body (both depend on process-global state, not trace-local state).
    Returns (sharded, cfg). The fused sweep keeps the paper's psum-only
    communication contract: per balance iteration one [k] size sum, per
    movement iteration one [k, d] + one [k] moment sum."""
    sp = ShardedPartitionProblem.from_problem(problem, devices)
    backend = resolve_assign_backend(cfg.assign_backend, sharded=True,
                                     n_local=sp.cap)
    fused = (backend_supports_moments(backend) if cfg.fused is None
             else cfg.fused)
    cfg = dataclasses.replace(cfg, use_kernel=False, backend=backend,
                              fused=fused)
    return sp, cfg


def geographer_partition_sharded(problem: PartitionProblem, devices: int,
                                 cfg: BKMConfig | None = None,
                                 bootstrap: str = "host"):
    """Raw sharded (cold-start) run.

    Args:
        problem: the partitioning instance; its seed fixes the round-robin
            deal permutation.
        devices: number of shards P (1 <= P <= problem.n).
        cfg: BKMConfig; None uses the problem's (k, epsilon) defaults.
        bootstrap: "host" (host-side SFC centers, identical to the
            single-device path) or "device" (in-graph distributed SFC
            bootstrap).

    Returns:
        (labels [n] int64 in original point order, centers [k, d],
        influence [k], stats dict) — prefer the front door
        ``partition(problem, devices=...)``.
    """
    if bootstrap not in BOOTSTRAPS:
        raise ValueError(f"bootstrap must be one of {BOOTSTRAPS}, "
                         f"got {bootstrap!r}")
    cfg = cfg or BKMConfig(k=problem.k, epsilon=problem.epsilon)
    sp, cfg = _prep_sharded_cfg(problem, devices, cfg)
    if bootstrap == "host":
        centers0 = sfc_initial_centers(
            np.asarray(problem.points, np.float64), cfg.k, problem.weights)
    else:
        centers0 = np.zeros((cfg.k, problem.dim))      # ignored in-graph
    run = _build_runner(sp.devices, sp.cap, problem.dim, cfg, bootstrap,
                        problem.n)
    pts = jnp.asarray(sp.points, cfg.dtype)
    w = jnp.asarray(sp.weights, cfg.dtype)
    A, centers, infl, stats = run(pts, w, jnp.asarray(centers0, cfg.dtype),
                                  jnp.ones(cfg.k, cfg.dtype),
                                  jnp.zeros(sp.devices * sp.cap, jnp.int32))
    labels = sp.scatter_labels(np.asarray(jax.device_get(A)))
    return labels, centers, infl, jax.tree.map(np.asarray, stats)


def geographer_repartition_sharded(problem: PartitionProblem, devices: int,
                                   centers0: np.ndarray,
                                   influence0: np.ndarray | None = None,
                                   cfg: BKMConfig | None = None,
                                   prev_labels: np.ndarray | None = None):
    """Raw sharded warm-start run: balanced k-means resumed from a previous
    partition's (centers0, influence0) state, no SFC bootstrap.

    The previous centers and influence are replicated across shards
    (exactly like every cold run's centers) and the communication pattern
    stays psum-only — warm starting adds zero new collectives. The shard
    layout comes from the problem's seed, so ``devices=1`` is bit-for-bit
    identical to ``core.partitioner.geographer_repartition`` with the same
    seed.

    Args:
        problem: the (possibly re-weighted / moved) partitioning instance.
        devices: number of shards P.
        centers0: [k, d] previous centers.
        influence0: [k] previous influence (None = ones).
        cfg: BKMConfig; ``warmup`` is forced off.
        prev_labels: [n] previous block ids in original point order; when
            given, an unchanged-and-still-balanced partition is re-emitted
            verbatim (no-op detection). Padded slots replicate real
            points, so the comparison is consistent across the deal.

    Returns:
        (labels [n] int64, centers [k, d], influence [k], stats dict);
        ``stats["iters"]`` is 0 when the previous state is still a fixed
        point. Prefer ``repartition(problem, previous, devices=...)``.
    """
    cfg = cfg or BKMConfig(k=problem.k, epsilon=problem.epsilon,
                           warmup=False)
    if cfg.warmup:
        cfg = dataclasses.replace(cfg, warmup=False)
    if centers0.shape[0] != cfg.k:
        raise ValueError(f"centers0 has {centers0.shape[0]} rows, "
                         f"k={cfg.k}")
    sp, cfg = _prep_sharded_cfg(problem, devices, cfg)
    run = _build_runner(sp.devices, sp.cap, problem.dim, cfg, "warm",
                        problem.n)
    pts = jnp.asarray(sp.points, cfg.dtype)
    w = jnp.asarray(sp.weights, cfg.dtype)
    infl0 = (jnp.ones(cfg.k, cfg.dtype) if influence0 is None
             else jnp.asarray(influence0, cfg.dtype))
    prev = (np.zeros((sp.devices, sp.cap), np.int32) if prev_labels is None
            else sp.deal(np.asarray(prev_labels, np.int32)))
    if prev_labels is None:
        # no previous labels -> disable no-op detection by making the
        # dummy never match a real assignment
        prev -= 1
    A, centers, infl, stats = run(pts, w, jnp.asarray(centers0, cfg.dtype),
                                  infl0,
                                  jnp.asarray(prev.reshape(-1), jnp.int32))
    labels = sp.scatter_labels(np.asarray(jax.device_get(A)))
    return labels, centers, infl, jax.tree.map(np.asarray, stats)


def partition_sharded(problem: PartitionProblem, devices: int, *,
                      bootstrap: str = "host", **opts) -> PartitionResult:
    """Multi-device geographer partition of ``problem`` over ``devices``
    shards (the ``devices=`` path of the ``partition()`` front door).

    Args:
        problem: the partitioning instance (its seed fixes the shard
            layout permutation).
        devices: number of shards P; must satisfy 1 <= P <= problem.n and
            P <= len(jax.devices()).
        bootstrap: SFC center seeding — "host" (identical to the
            single-device path, the agreement default) or "device" (fully
            in-graph distributed bootstrap, O(1)-sized communication).
        **opts: BKMConfig field overrides, exactly as in the single-device
            adapter (e.g. ``max_iter=50``, ``warmup=False``); unknown
            fields raise TypeError.

    Returns:
        PartitionResult with labels in original point order, the final
        (centers, influence) state — reusable as a ``repartition()`` warm
        start — and ``stats`` carrying the k-means iteration history plus
        ``devices`` / ``bootstrap``.
    """
    from .algorithms import make_bkm_config
    cfg = make_bkm_config(problem, **opts)
    labels, centers, infl, stats = geographer_partition_sharded(
        problem, devices, cfg=cfg, bootstrap=bootstrap)
    return PartitionResult(
        labels=labels, k=problem.k, method="geographer", problem=problem,
        centers=np.asarray(centers), influence=np.asarray(infl),
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"]),
               "devices": int(devices), "bootstrap": bootstrap})


def repartition_sharded(problem: PartitionProblem, devices: int,
                        centers0: np.ndarray,
                        influence0: np.ndarray | None = None,
                        prev_labels: np.ndarray | None = None,
                        **opts) -> PartitionResult:
    """Multi-device warm-started repartition (the ``devices=`` path of the
    ``repartition()`` front door).

    Args:
        problem: the perturbed partitioning instance.
        devices: number of shards P.
        centers0: [k, d] previous partition's centers.
        influence0: [k] previous partition's influence (None = ones).
        prev_labels: [n] previous block ids (enables no-op detection).
        **opts: BKMConfig field overrides (``warmup`` is forced off).

    Returns:
        PartitionResult (labels, final centers/influence, stats with
        ``stats["warm_start"] = True`` and the movement iteration count at
        ``stats["iters"]``).
    """
    from .algorithms import make_bkm_config
    cfg = make_bkm_config(problem, **dict(opts, warmup=False))
    labels, centers, infl, stats = geographer_repartition_sharded(
        problem, devices, centers0, influence0, cfg=cfg,
        prev_labels=prev_labels)
    return PartitionResult(
        labels=labels, k=problem.k, method="geographer", problem=problem,
        centers=np.asarray(centers), influence=np.asarray(infl),
        stats={"levels": [dict(stats)],
               "final_imbalance": float(stats["final_imbalance"]),
               "iters": int(stats["iters"]),
               "devices": int(devices), "warm_start": True})
