"""Problem/result dataclasses for the unified partitioning engine.

``PartitionProblem`` is the single input type every algorithm in the
registry consumes: a point cloud with optional node weights and an optional
CSR graph (for quality metrics), plus the balance constraint (k, epsilon).
``PartitionResult`` is the single output type: labels, optional centers /
influence (center-based methods), per-level stats, and lazily computed
quality metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np


@dataclass(frozen=True)
class PartitionProblem:
    """One partitioning instance.

    Attributes:
        points: [n, d] float coordinates; d in {2, 3} for the SFC-based
            methods.
        k: number of blocks, ``1 <= k <= n``.
        weights: [n] nonneg float node weights, or None (= unit weights).
        epsilon: balance slack — every block must end with weight
            ``<= (1 + epsilon) * W/k``.
        indptr, indices: optional CSR adjacency (metrics only — the
            geometric partitioners never read the graph, exactly like the
            paper). Must be given together.
        seed: permutation seed (warm-up sampling order + sharded layout).
        name: label used in benchmark tables.
    """
    points: np.ndarray
    k: int
    weights: np.ndarray | None = None
    epsilon: float = 0.03
    indptr: np.ndarray | None = None
    indices: np.ndarray | None = None
    seed: int = 0
    name: str = "problem"

    def __post_init__(self):
        pts = np.asarray(self.points)
        if pts.ndim != 2:
            raise ValueError(f"points must be [n, d], got {pts.shape}")
        if not (1 <= self.k <= pts.shape[0]):
            raise ValueError(f"k={self.k} out of range for n={pts.shape[0]}")
        if self.weights is not None and len(self.weights) != pts.shape[0]:
            raise ValueError("weights length mismatch")
        if (self.indptr is None) != (self.indices is None):
            raise ValueError("indptr and indices must be given together")
        # store the normalized arrays (frozen dataclass -> object.__setattr__)
        object.__setattr__(self, "points", pts)
        for name in ("weights", "indptr", "indices"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, np.asarray(v))

    @property
    def n(self) -> int:
        return self.points.shape[0]

    @property
    def dim(self) -> int:
        return self.points.shape[1]

    @property
    def has_graph(self) -> bool:
        return self.indptr is not None

    @property
    def total_weight(self) -> float:
        if self.weights is None:
            return float(self.n)
        return float(np.sum(self.weights))

    @property
    def target_weight(self) -> float:
        """Ideal per-block weight W/k (the denominator of the imbalance)."""
        return self.total_weight / self.k

    @classmethod
    def from_mesh(cls, mesh, k: int, epsilon: float = 0.03,
                  seed: int = 0) -> "PartitionProblem":
        """Build a problem from a ``core.meshes.Mesh``.

        Args:
            mesh: a Mesh (points + CSR graph + optional 2.5D weights).
            k: number of blocks.
            epsilon: balance slack (default 0.03, the paper's setting).
            seed: permutation seed.

        Returns:
            A ``PartitionProblem`` carrying the mesh's graph for metrics.
        """
        return cls(points=mesh.points, k=k, weights=mesh.weights,
                   epsilon=epsilon, indptr=mesh.indptr, indices=mesh.indices,
                   seed=seed, name=mesh.name)

    def replace(self, **kw) -> "PartitionProblem":
        """A copy with ``kw`` fields replaced (validation re-runs) — the
        idiom for perturbing a problem between ``repartition`` steps,
        e.g. ``problem.replace(weights=w_t)``."""
        import dataclasses
        return dataclasses.replace(self, **kw)

    def to_sharded(self, devices: int, chunk: int | None = None):
        """Static-shape sharded view for the multi-device engine: points
        and weights dealt round-robin over ``devices`` shards (source
        dtype preserved) and padded to a common per-device cap; ``chunk``
        streams the deal in bounded host slices with bit-identical
        results (see partition/distributed.py)."""
        from .distributed import ShardedPartitionProblem
        return ShardedPartitionProblem.from_problem(self, devices,
                                                    chunk=chunk)

    def to_sharded_graph(self, devices: int):
        """Sharded CSR companion view for the distributed evaluation
        subsystem: the graph's rows dealt onto the same seed-permuted
        round-robin layout as ``to_sharded`` (see repro.eval.sharded).
        Requires the problem to carry a CSR adjacency."""
        from repro.eval.sharded import ShardedGraph
        return ShardedGraph.from_problem(self, devices)


@dataclass
class PartitionResult:
    """Output of ``partition()`` / ``repartition()``.

    Attributes:
        labels: [n] int64 block ids in [0, k), original point order.
        k: number of blocks.
        method: registry name that produced the result.
        problem: the source problem (weights/graph for lazy metrics).
        centers: [k, d] final k-means centers (center-based methods only)
            — together with ``influence`` this is the warm-start state
            ``repartition()`` resumes from.
        influence: [k] final influence (paper Eq. 1 state).
        stats: solver statistics; per-level entries under ``"levels"``.
            ``repartition()`` adds ``warm_start``, ``iters``,
            ``balance_retries`` and ``migration``.
        quality: lazily computed paper metric set (see ``evaluate``).
    """
    labels: np.ndarray
    k: int
    method: str
    problem: PartitionProblem | None = None
    centers: np.ndarray | None = None
    influence: np.ndarray | None = None
    stats: dict = field(default_factory=dict)
    quality: dict | None = None

    def imbalance(self) -> float:
        """Measured global imbalance max_b W_b / (W/k) - 1."""
        from repro.core import metrics
        w = None if self.problem is None else self.problem.weights
        return metrics.imbalance(np.asarray(self.labels), self.k, w)

    def block_sizes(self) -> np.ndarray:
        from repro.core import metrics
        w = None if self.problem is None else self.problem.weights
        return metrics.block_sizes(np.asarray(self.labels), self.k, w)

    def evaluate(self, with_diameter: bool = False,
                 devices: int | None = None) -> dict:
        """Compute (and cache at ``self.quality``) the paper's quality
        metric set.

        Args:
            with_diameter: also compute per-block diameter bounds (BFS —
                noticeably slower on large meshes; host path only).
            devices: compute the graph metrics in-graph over P shards
                (``repro.eval.evaluate_sharded`` — bit-for-bit equal to
                the host metrics, scales with the solver layer). None
                keeps the host numpy path.

        Returns:
            dict with ``imbalance`` / ``n_blocks_used`` always, plus
            ``cut`` / ``maxCommVol`` / ``totalCommVol`` /
            ``boundaryNodes`` (and diameter stats) when the problem
            carries a CSR graph.

        Raises:
            ValueError: the result has no problem attached, or
                ``devices`` is combined with ``with_diameter``.
        """
        from repro.core import metrics
        if self.problem is None:
            raise ValueError("result has no problem attached")
        if devices is not None:
            if with_diameter:
                raise ValueError("with_diameter has no sharded path; "
                                 "call evaluate(with_diameter=True) "
                                 "without devices=")
            from repro.eval import evaluate_sharded
            self.quality = evaluate_sharded(
                self.problem, np.asarray(self.labels), devices)
            return self.quality
        self.quality = metrics.evaluate_problem(
            self.problem, np.asarray(self.labels),
            with_diameter=with_diameter)
        return self.quality

    def refine(self, method="label_prop", *, devices: int | None = None,
               eps: float | None = None, evaluate: bool = False,
               **opts) -> "PartitionResult":
        """Quality-recovery post-pass over this result's labels (the
        ``repro.partition.refine`` front door bound to ``self``).

        Args:
            method: refiner registry name (default size-constrained label
                propagation).
            devices: None = host reference; P >= 1 = the sharded
                shard_map path (bit-for-bit equal at every device count).
            eps: balance slack for the refinement budgets (None = the
                problem's epsilon).
            evaluate: fill ``quality`` on the refined result.
            **opts: forwarded to the refiner (e.g. ``max_rounds``).

        Returns:
            A new ``PartitionResult`` with refined labels, ``method``
            suffixed (e.g. ``"geographer+lp"``) and
            ``stats["refine"]`` recording rounds/moves/cut delta.

        Raises:
            ValueError: the result has no problem attached, or the
                problem carries no CSR graph.
        """
        if self.problem is None:
            raise ValueError("result has no problem attached")
        from .refine import refine as _refine
        return _refine(self.problem, self, method, devices=devices,
                       eps=eps, evaluate=evaluate, **opts)

    def summary(self) -> dict[str, Any]:
        out = {"method": self.method, "k": self.k,
               "imbalance": self.imbalance(),
               "n_blocks_used": int(len(np.unique(self.labels)))}
        if self.quality:
            out.update(self.quality)
        return out
