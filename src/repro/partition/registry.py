"""String-keyed algorithm registry — the pluggable half of the engine.

An algorithm is any callable ``fn(problem: PartitionProblem, **opts) ->
PartitionResult``. Register with::

    @register_algorithm("mymethod", aliases=("mm",))
    def _my_method(problem, **opts):
        ...

``get_algorithm`` resolves aliases and raises ``UnknownMethodError`` (a
``KeyError``) with the available names for anything unregistered, so typos
fail loudly at the front door instead of deep inside a jit trace.
"""
from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}
_ALIASES: dict[str, str] = {}
_SUPPORTS_DEVICES: set[str] = set()


class UnknownMethodError(KeyError):
    pass


def register_algorithm(name: str, aliases: tuple[str, ...] = (),
                       supports_devices: bool = False):
    """Decorator: register ``fn`` under ``name`` (+ aliases).

    ``supports_devices=True`` declares that the algorithm understands the
    ``devices=`` option (a multi-device shard_map path); the front door
    rejects ``devices=`` for anything else before the algorithm runs.
    """
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = fn
        if supports_devices:
            _SUPPORTS_DEVICES.add(name)
        for a in aliases:
            _ALIASES[a] = name
        return fn
    return deco


def resolve_method(name: str) -> str:
    """Canonical name for ``name`` (resolving aliases)."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise UnknownMethodError(
            f"unknown partition method {name!r}; available: "
            f"{available_methods()} (aliases: {sorted(_ALIASES)})")
    return name


def get_algorithm(name: str) -> Callable:
    return _REGISTRY[resolve_method(name)]


def supports_devices(name: str) -> bool:
    """True when ``name`` (or its alias) has a multi-device path."""
    return resolve_method(name) in _SUPPORTS_DEVICES


def distributed_methods() -> list[str]:
    return sorted(_SUPPORTS_DEVICES)


def available_methods() -> list[str]:
    return sorted(_REGISTRY)
