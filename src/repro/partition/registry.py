"""String-keyed algorithm registry — the pluggable half of the engine.

An algorithm is any callable ``fn(problem: PartitionProblem, **opts) ->
PartitionResult``. Register with::

    @register_algorithm("mymethod", aliases=("mm",))
    def _my_method(problem, **opts):
        ...

``get_algorithm`` resolves aliases and raises ``UnknownMethodError`` (a
``KeyError``) with the available names for anything unregistered, so typos
fail loudly at the front door instead of deep inside a jit trace.

Two capability flags ride on each registration:

* ``supports_devices`` — the algorithm understands ``devices=P`` (a
  multi-device shard_map path); the ``partition()`` front door rejects
  ``devices=`` for anything else before the algorithm runs.
* ``supports_warm_start`` — the algorithm can resume from a previous
  ``PartitionResult``'s (centers, influence) state; ``repartition()``
  takes the warm path for these and falls back to cold start +
  relabel-matching for everything else (so migration is still measured
  fairly for SFC/RCB-style methods).
"""
from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}
_ALIASES: dict[str, str] = {}
_SUPPORTS_DEVICES: set[str] = set()
_SUPPORTS_WARM_START: set[str] = set()


class UnknownMethodError(KeyError):
    pass


def register_algorithm(name: str, aliases: tuple[str, ...] = (),
                       supports_devices: bool = False,
                       supports_warm_start: bool = False):
    """Decorator: register ``fn`` under ``name`` (+ aliases).

    Args:
        name: canonical registry key.
        aliases: extra names resolving to ``name``.
        supports_devices: declares a multi-device ``devices=`` path.
        supports_warm_start: declares that ``repartition()`` may warm-start
            this algorithm from a previous result's (centers, influence).

    Returns:
        The decorator; the wrapped function is returned unchanged.
    """
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = fn
        if supports_devices:
            _SUPPORTS_DEVICES.add(name)
        if supports_warm_start:
            _SUPPORTS_WARM_START.add(name)
        for a in aliases:
            _ALIASES[a] = name
        return fn
    return deco


def resolve_method(name: str) -> str:
    """Canonical name for ``name`` (resolving aliases)."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise UnknownMethodError(
            f"unknown partition method {name!r}; available: "
            f"{available_methods()} (aliases: {sorted(_ALIASES)})")
    return name


def get_algorithm(name: str) -> Callable:
    """The registered callable for ``name`` (aliases resolved)."""
    return _REGISTRY[resolve_method(name)]


def supports_devices(name: str) -> bool:
    """True when ``name`` (or its alias) has a multi-device path."""
    return resolve_method(name) in _SUPPORTS_DEVICES


def supports_warm_start(name: str) -> bool:
    """True when ``name`` (or its alias) can be warm-started by
    ``repartition()`` from a previous result's (centers, influence)."""
    return resolve_method(name) in _SUPPORTS_WARM_START


def distributed_methods() -> list[str]:
    """Sorted names of all methods with a multi-device path."""
    return sorted(_SUPPORTS_DEVICES)


def warm_start_methods() -> list[str]:
    """Sorted names of all methods supporting warm-started repartition."""
    return sorted(_SUPPORTS_WARM_START)


def available_methods() -> list[str]:
    """Sorted canonical names of every registered algorithm."""
    return sorted(_REGISTRY)
