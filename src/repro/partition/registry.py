"""String-keyed algorithm registry — the pluggable half of the engine.

An algorithm is any callable ``fn(problem: PartitionProblem, **opts) ->
PartitionResult``. Register with::

    @register_algorithm("mymethod", aliases=("mm",))
    def _my_method(problem, **opts):
        ...

``get_algorithm`` resolves aliases and raises ``UnknownMethodError`` (a
``KeyError``) with the available names for anything unregistered, so typos
fail loudly at the front door instead of deep inside a jit trace.
"""
from __future__ import annotations

from typing import Callable

_REGISTRY: dict[str, Callable] = {}
_ALIASES: dict[str, str] = {}


class UnknownMethodError(KeyError):
    pass


def register_algorithm(name: str, aliases: tuple[str, ...] = ()):
    """Decorator: register ``fn`` under ``name`` (+ aliases)."""
    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} already registered")
        _REGISTRY[name] = fn
        for a in aliases:
            _ALIASES[a] = name
        return fn
    return deco


def resolve_method(name: str) -> str:
    """Canonical name for ``name`` (resolving aliases)."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise UnknownMethodError(
            f"unknown partition method {name!r}; available: "
            f"{available_methods()} (aliases: {sorted(_ALIASES)})")
    return name


def get_algorithm(name: str) -> Callable:
    return _REGISTRY[resolve_method(name)]


def available_methods() -> list[str]:
    return sorted(_REGISTRY)
