"""Flash-attention and MoE-router Pallas kernels vs pure-jnp oracles
(interpret mode): shape/dtype sweeps + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import flash_attention, router_topk
from repro.kernels.ref import flash_attention_ref, router_topk_ref


def _qkv(B, S, H, KV, dh, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, dh)), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,KV,dh,bq,bk,softcap,dtype", [
    (2, 256, 4, 4, 32, 128, 128, 0.0, jnp.float32),     # MHA
    (1, 512, 8, 2, 64, 256, 128, 0.0, jnp.float32),     # GQA 4:1
    (2, 384, 4, 1, 32, 128, 128, 0.0, jnp.float32),     # MQA + padding
    (1, 256, 4, 4, 128, 128, 128, 50.0, jnp.float32),   # softcap (gemma)
    (1, 256, 2, 2, 64, 128, 128, 0.0, jnp.bfloat16),    # bf16 io
    (1, 300, 3, 1, 16, 128, 128, 0.0, jnp.float32),     # odd S, odd heads
])
def test_flash_matches_ref(B, S, H, KV, dh, bq, bk, softcap, dtype):
    q, k, v = _qkv(B, S, H, KV, dh, dtype)
    out = flash_attention(q, k, v, bq=bq, bk=bk, softcap=softcap)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, S, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, S, dh)
    ref = flash_attention_ref(qh, kh, vh, softcap=softcap)
    ref = ref.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_matches_model_dense_path():
    """Kernel agrees with the model's dense attention math (the path the
    smoke tests run): same GQA grouping, same causal mask."""
    from repro.models import layers as L
    from repro import configs
    cfg = configs.get_config("phi4_mini_3p8b", smoke=True)
    B, S, H, KV, dh = 2, 128, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _qkv(B, S, H, KV, dh)
    out = flash_attention(q, k, v, bq=128, bk=128)
    scores = L._gqa_scores(q, k, cfg)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bkgst,btkd->bskgd", p, v).reshape(B, S, H, dh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    S=st.integers(130, 400),
    H=st.integers(1, 6),
    g=st.sampled_from([1, 2]),
    dh=st.sampled_from([16, 32]),
)
def test_flash_property(S, H, g, dh):
    KV = max(H // g, 1)
    H = KV * g
    q, k, v = _qkv(1, S, H, KV, dh, seed=S)
    out = flash_attention(q, k, v, bq=128, bk=128)
    qh = q.transpose(0, 2, 1, 3).reshape(H, S, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(KV, S, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(KV, S, dh)
    ref = flash_attention_ref(qh, kh, vh)
    ref = ref.reshape(1, H, S, dh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# MoE router kernel
# ---------------------------------------------------------------------------

def _router_inputs(T, E, D, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((T, D)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((E, D)), jnp.float32)
    infl = jnp.asarray(rng.uniform(0.5, 2.0, (E,)), jnp.float32)
    return x, c, infl


@pytest.mark.parametrize("T,E,D,K,bt", [
    (512, 8, 64, 1, 256),         # llama4-style top-1
    (512, 16, 64, 2, 128),        # jamba top-2
    (512, 40, 32, 8, 256),        # granite top-8, E padded 40->128
    (300, 128, 128, 2, 128),      # T padding
    # E > 128: expert axis tiled, running top-k merged across tiles
    (512, 200, 64, 4, 128),       # 2 tiles, second tile padded 200->256
    (300, 256, 32, 8, 128),       # 2 exact tiles + T padding, deep top-k
    (256, 384, 16, 2, 256),       # 3 tiles
])
def test_router_matches_ref(T, E, D, K, bt):
    x, c, infl = _router_inputs(T, E, D)
    idx, eff = router_topk(x, c, infl, top_k=K, bt=bt)
    ridx, reff = router_topk_ref(x, c, 1.0 / (infl * infl), K)
    np.testing.assert_allclose(np.asarray(eff), np.asarray(reff),
                               rtol=1e-4, atol=1e-4)
    # indices may differ only where effective distances tie
    mismatch = np.asarray(idx) != np.asarray(ridx)
    if mismatch.any():
        np.testing.assert_allclose(np.asarray(eff)[mismatch],
                                   np.asarray(reff)[mismatch],
                                   rtol=1e-4, atol=1e-4)


def test_router_uniform_influence_is_nearest_expert():
    x, c, _ = _router_inputs(256, 16, 32, seed=3)
    infl = jnp.ones(16)
    idx, _ = router_topk(x, c, infl, top_k=1)
    d = jnp.sum((x[:, None] - c[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]),
                                  np.asarray(jnp.argmin(d, 1)))


@settings(max_examples=10, deadline=None)
@given(T=st.integers(64, 300), E=st.integers(2, 160),
       K=st.integers(1, 4), D=st.sampled_from([8, 32]))
def test_router_property(T, E, K, D):
    K = min(K, E)
    x, c, infl = _router_inputs(T, E, D, seed=T + E)
    idx, eff = router_topk(x, c, infl, top_k=K, bt=64)
    # effs ascend along k and are >= 0
    e = np.asarray(eff)
    assert (e >= -1e-6).all()
    assert (np.diff(e, axis=1) >= -1e-5).all()
    # idx are valid expert ids, distinct per token
    i = np.asarray(idx)
    assert ((i >= 0) & (i < E)).all()
    for row in i:
        assert len(set(row.tolist())) == K
