"""Distributed evaluation subsystem (repro.eval): ShardedGraph layout
invariants, exact sharded-vs-host metric agreement, and the engine
plumbing (``PartitionResult.evaluate(devices=P)``).

The property-based randomized sweep lives in
tests/test_metrics_properties.py (tier2); this module is the fast tier-1
coverage of the same contracts on fixed instances.
"""
import jax
import numpy as np
import pytest

from repro.core import meshes, metrics
from repro.eval import (ShardedGraph, boundary_nodes_sharded,
                        comm_volume_sharded, edge_cut_sharded,
                        evaluate_sharded)
from repro.partition import PartitionProblem, partition

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


@pytest.fixture(scope="module")
def problem():
    mesh = meshes.REGISTRY["delaunay2d"](1603, seed=0)   # P does not divide n
    return PartitionProblem.from_mesh(mesh, k=7, epsilon=0.03)


@pytest.fixture(scope="module")
def labels(problem):
    return partition(problem, method="rcb").labels


def test_sharded_graph_layout(problem):
    """Every directed CSR edge appears exactly once, on its source's
    shard, with the source's local slot index."""
    sg = ShardedGraph.from_problem(problem, 4)
    deg = np.diff(problem.indptr)
    assert sg.ecap >= 1
    assert int(sg.edge_valid.sum()) == len(problem.indices)
    sp = sg.sharded
    for p in range(4):
        ev = sg.edge_valid[p]
        # each shard's edge count == sum of its valid slots' degrees
        slots = np.nonzero(sp.valid[p])[0]
        assert int(ev.sum()) == int(deg[sp.gather[p][slots]].sum())
        # sources are valid local slots; targets are the CSR neighbors
        src_global = sp.gather[p][sg.src[p][ev]]
        for g, d in zip(*np.unique(src_global, return_counts=True)):
            assert d == deg[g]
    # reconstructed directed edge multiset == the CSR edge multiset
    all_src, all_dst = [], []
    for p in range(4):
        ev = sg.edge_valid[p]
        all_src.append(sp.gather[p][sg.src[p][ev]])
        all_dst.append(sg.dst[p][ev])
    got = sorted(zip(np.concatenate(all_src).tolist(),
                     np.concatenate(all_dst).tolist()))
    n = problem.n
    want = sorted(zip(np.repeat(np.arange(n), deg).tolist(),
                      problem.indices.tolist()))
    assert got == want


@needs8
@pytest.mark.parametrize("devices", [1, 2, 4, 8])
def test_sharded_metrics_exact(problem, labels, devices):
    """Integer counts psum in any order exactly: the sharded metrics are
    bit-for-bit equal to the numpy metrics at EVERY device count."""
    sg = ShardedGraph.from_problem(problem, devices)
    assert edge_cut_sharded(sg, labels) == metrics.edge_cut(
        labels, problem.indptr, problem.indices)
    hmax, htot, hpb = metrics.comm_volume(labels, problem.indptr,
                                          problem.indices, problem.k)
    smax, stot, spb = comm_volume_sharded(sg, labels)
    assert (smax, stot) == (hmax, htot)
    np.testing.assert_array_equal(spb, hpb)
    htotal, hper = metrics.boundary_nodes(labels, problem.indptr,
                                          problem.indices, problem.k)
    stotal, sper = boundary_nodes_sharded(sg, labels)
    assert stotal == htotal
    np.testing.assert_array_equal(sper, hper)


@needs8
def test_evaluate_sharded_matches_host_dict(problem, labels):
    host = metrics.evaluate_problem(problem, labels)
    assert evaluate_sharded(problem, labels, devices=4) == host


@needs8
def test_result_evaluate_devices_path(problem):
    res = partition(problem, method="geographer")
    host = dict(res.evaluate())
    assert res.evaluate(devices=2) == host
    assert res.quality == host                       # cache refreshed
    with pytest.raises(ValueError, match="diameter"):
        res.evaluate(with_diameter=True, devices=2)


@needs8
def test_weighted_mesh_sharded_eval():
    mesh = meshes.REGISTRY["rggpow"](901, seed=3)
    prob = PartitionProblem.from_mesh(mesh, k=5, epsilon=0.05)
    res = partition(prob, method="sfc")
    assert evaluate_sharded(prob, res.labels, devices=8) == res.evaluate()


def test_graph_required():
    pts = np.random.default_rng(0).uniform(0, 1, (64, 2))
    prob = PartitionProblem(points=pts, k=4)
    with pytest.raises(ValueError, match="CSR"):
        ShardedGraph.from_problem(prob, 2)
    with pytest.raises(ValueError, match="CSR"):
        prob.to_sharded_graph(2)


def test_label_shape_checked(problem):
    sg = problem.to_sharded_graph(2)
    with pytest.raises(ValueError, match="labels"):
        edge_cut_sharded(sg, np.zeros(problem.n - 1, np.int64))


def test_graph_problem_mismatch_rejected(problem):
    sg = problem.to_sharded_graph(2)
    other = PartitionProblem.from_mesh(
        meshes.REGISTRY["tri"](400, seed=0), k=4)
    with pytest.raises(ValueError, match="different problem"):
        evaluate_sharded(other, np.zeros(other.n, np.int64), 2, graph=sg)
    with pytest.raises(ValueError, match="different problem"):
        evaluate_sharded(problem, np.zeros(problem.n, np.int64), 4,
                         graph=sg)                   # devices mismatch


@needs8
def test_memo_invalidates_on_new_labels(problem):
    """The per-graph (labels, result) memo must never serve stale results
    when a different labeling is evaluated on the same graph."""
    sg = problem.to_sharded_graph(2)
    a = np.zeros(problem.n, np.int64)
    b = (np.arange(problem.n) % problem.k).astype(np.int64)
    assert edge_cut_sharded(sg, a) == 0
    cut_b = edge_cut_sharded(sg, b)
    assert cut_b == metrics.edge_cut(b, problem.indptr, problem.indices)
    assert cut_b > 0
    assert edge_cut_sharded(sg, a) == 0              # back again
    # memoized repeat returns the identical result object
    assert comm_volume_sharded(sg, a) == comm_volume_sharded(sg, a)


def test_deal_scatter_roundtrip(problem):
    """deal() is the inverse direction of scatter_labels on valid slots."""
    sp = problem.to_sharded(4)
    vals = np.arange(problem.n, dtype=np.int64)
    dealt = sp.deal(vals)
    assert dealt.shape == (4, sp.cap)
    np.testing.assert_array_equal(sp.scatter_labels(dealt), vals)
    # padded slots replicate their aliased real point's value
    np.testing.assert_array_equal(dealt[~sp.valid],
                                  vals[sp.gather[~sp.valid]])


def test_edge_cap_minimal_and_too_small(problem, labels):
    """The remote-block dedup table must behave identically at the
    MINIMAL edge cap (exactly the largest per-shard edge count — zero
    padding rows), and an explicitly too-small cap must raise instead of
    silently truncating edges."""
    auto = ShardedGraph.from_problem(problem, 4)
    minimal = ShardedGraph.from_problem(problem, 4, edge_cap=auto.ecap)
    assert minimal.ecap == auto.ecap
    # at the minimal cap at least one shard has NO padded edge slots
    assert bool(np.all(minimal.edge_valid.sum(axis=1).max()
                       == minimal.ecap))
    for sg in (auto, minimal):
        assert edge_cut_sharded(sg, labels) == metrics.edge_cut(
            labels, problem.indptr, problem.indices)
        host = metrics.comm_volume(labels, problem.indptr,
                                   problem.indices, problem.k)
        assert comm_volume_sharded(sg, labels)[:2] == host[:2]
    # a roomier explicit cap is allowed and changes nothing
    padded = ShardedGraph.from_problem(problem, 4, edge_cap=auto.ecap + 5)
    assert padded.ecap == auto.ecap + 5
    assert edge_cut_sharded(padded, labels) == edge_cut_sharded(
        auto, labels)
    with pytest.raises(ValueError, match="truncate"):
        ShardedGraph.from_problem(problem, 4, edge_cap=auto.ecap - 1)
    with pytest.raises(ValueError, match="edge_cap"):
        ShardedGraph.from_problem(problem, 4, edge_cap=0)


def test_edge_cap_minimal_equals_max_degree_at_p_equals_n():
    """With one point per shard the minimal cap IS the max degree — the
    tightest layout the dedup table can see."""
    mesh = meshes.REGISTRY["tri"](25, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k=3, epsilon=0.03)
    P = min(8, len(jax.devices()))
    if P < 2:
        pytest.skip("needs >= 2 jax devices")
    # P shards, few points each: cap = max per-shard degree sum
    sg = ShardedGraph.from_sharded(prob.to_sharded(P))
    sp = sg.sharded
    deg = np.diff(prob.indptr)
    per_shard = [int(deg[sp.gather[p][sp.valid[p]]].sum())
                 for p in range(P)]
    assert sg.ecap == max(max(per_shard), 1)
    lab = (np.arange(prob.n) % 3).astype(np.int64)
    assert edge_cut_sharded(sg, lab) == metrics.edge_cut(
        lab, prob.indptr, prob.indices)
