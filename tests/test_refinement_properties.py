"""Property suite for the label-propagation refinement pass
(repro.partition.refine, DESIGN.md §11) — runs under real hypothesis when
installed, or the deterministic fixed-example stub (tests/_stubs)
otherwise.

Host invariants (any labeling of any mesh):
  * refinement NEVER increases the edge cut, and the cut drops by at
    least one edge per accepted move
  * refinement never worsens balance: output imbalance <= max(input
    imbalance, eps); balanced in => balanced out (<= eps), weighted
    meshes included (the quantization margin is part of the contract)
  * a converged refinement is a fixed point: refining again accepts
    zero moves and returns identical labels
  * exact equivariance under block relabelings and — via ``node_order``
    priority keys — under point permutations
  * natural convergence certifies local optimality: no admissible
    single positive-gain move remains (brute-force oracle on tiny
    meshes, admissibility from the exposed ``refinement_budgets``)

Sharded equality (tier2): the shard_map path returns labels bit-for-bit
equal to the host numpy reference at devices in {1, 2, 4, 8} — every
decision is made from psum-assembled replicated integer vectors, so this
is equality, not tolerance.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import meshes, metrics
from repro.partition import (PartitionProblem, PartitionResult,
                             UnknownRefinerError, available_refiners,
                             partition, refine, refinement_budgets,
                             refinement_quantization, repartition,
                             resolve_refiner)

FAMILIES = ["tri", "refined2d", "aniso", "rggpow", "climate25d"]

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")
needs2 = pytest.mark.skipif(len(jax.devices()) < 2,
                            reason="needs 2 (virtual) jax devices")


def _instance(family: str, n: int, k: int, seed: int):
    """Randomized (problem, labels): labels cover arbitrary subsets of
    [0, k) including empty blocks — refinement must cope with worse
    inputs than any solver produces."""
    mesh = meshes.REGISTRY[family](n, seed=seed)
    prob = PartitionProblem.from_mesh(mesh, k=k, seed=seed)
    rng = np.random.default_rng(seed + 1)
    return prob, rng.integers(0, k, prob.n).astype(np.int64)


# ---------------------------------------------------------------------------
# core invariants

@settings(max_examples=8, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 600),
       st.integers(2, 10), st.integers(0, 10 ** 6))
def test_never_increases_cut_and_gain_accounting(family, n, k, seed):
    prob, labels = _instance(family, n, k, seed)
    out = refine(prob, labels)
    st_ = out.stats["refine"]
    cut0 = metrics.edge_cut(labels, prob.indptr, prob.indices)
    cut1 = metrics.edge_cut(out.labels, prob.indptr, prob.indices)
    assert st_["cut_before"] == cut0 and st_["cut_after"] == cut1
    assert cut1 <= cut0
    # every accepted move has integer gain >= 1 against frozen neighbor
    # labels, and accepted moves form an independent set — so the cut
    # drops by at least one edge per move
    assert cut0 - cut1 >= st_["moves"]
    assert (st_["moves"] == 0) == (cut1 == cut0)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 600),
       st.integers(2, 10), st.integers(0, 10 ** 6))
def test_never_worsens_imbalance(family, n, k, seed):
    prob, labels = _instance(family, n, k, seed)
    out = refine(prob, labels)
    imb0 = metrics.imbalance(labels, prob.k, prob.weights)
    imb1 = metrics.imbalance(np.asarray(out.labels), prob.k, prob.weights)
    assert imb1 <= max(imb0, prob.epsilon) + 1e-9


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["tri", "refined2d", "aniso", "climate25d"]),
       st.integers(0, 10 ** 6))
def test_balanced_in_balanced_out(family, seed):
    """A balanced input stays <= eps after refinement — the budget
    protocol's whole point, including float-weighted meshes where the
    quantization margin has to absorb the rounding drift."""
    mesh = meshes.REGISTRY[family](400, seed=seed)
    prob = PartitionProblem.from_mesh(mesh, k=6, seed=seed)
    res = partition(prob, method="geographer")
    assert res.imbalance() <= prob.epsilon + 1e-6, "precondition"
    out = res.refine()
    assert metrics.imbalance(np.asarray(out.labels), prob.k,
                             prob.weights) <= prob.epsilon + 1e-6


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 500),
       st.integers(2, 8), st.integers(0, 10 ** 6))
def test_converged_refinement_is_fixed_point(family, n, k, seed):
    prob, labels = _instance(family, n, k, seed)
    out = refine(prob, labels)
    assert out.stats["refine"]["converged"]
    again = refine(prob, out.labels)
    assert again.stats["refine"]["moves"] == 0
    assert again.stats["refine"]["rounds"] == 1
    np.testing.assert_array_equal(np.asarray(again.labels),
                                  np.asarray(out.labels))


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 500),
       st.integers(2, 8), st.integers(0, 10 ** 6))
def test_block_relabel_equivariance(family, n, k, seed):
    """refine(sigma(labels)) == sigma(refine(labels)) EXACTLY for any
    block-id permutation sigma — the canonicalization contract."""
    prob, labels = _instance(family, n, k, seed)
    rng = np.random.default_rng(seed + 7)
    sigma = rng.permutation(k)
    a = np.asarray(refine(prob, labels).labels)
    b = np.asarray(refine(prob, sigma[labels]).labels)
    np.testing.assert_array_equal(sigma[a], b)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["tri", "aniso", "rggpow", "climate25d"]),
       st.integers(120, 400), st.integers(2, 8), st.integers(0, 10 ** 6))
def test_point_permutation_equivariance(family, n, k, seed):
    """Permuting the points (and passing permutation-consistent
    ``node_order`` keys) permutes the refined labels EXACTLY."""
    prob, labels = _instance(family, n, k, seed)
    rng = np.random.default_rng(seed + 13)
    p = rng.permutation(prob.n)              # new i holds old point p[i]
    inv = np.empty(prob.n, np.int64)
    inv[p] = np.arange(prob.n)
    # permute the CSR graph: row i of the new problem is old row p[i]
    # with every neighbor id mapped through inv
    indptr = np.asarray(prob.indptr)
    indices = np.asarray(prob.indices)
    deg = np.diff(indptr)[p]
    new_indptr = np.concatenate([[0], np.cumsum(deg)])
    new_indices = np.concatenate(
        [inv[indices[indptr[v]:indptr[v + 1]]] for v in p])
    pprob = PartitionProblem(
        points=prob.points[p], k=prob.k,
        weights=None if prob.weights is None else prob.weights[p],
        epsilon=prob.epsilon, indptr=new_indptr, indices=new_indices,
        seed=prob.seed)
    a = np.asarray(refine(prob, labels).labels)
    b = np.asarray(refine(pprob, labels[p], node_order=p).labels)
    np.testing.assert_array_equal(a[p], b)


# ---------------------------------------------------------------------------
# local-optimality oracle (tiny meshes, brute force)

@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["tri", "climate25d"]), st.integers(30, 70),
       st.integers(2, 5), st.integers(0, 10 ** 6))
def test_no_admissible_positive_gain_move_remains(family, n, k, seed):
    """After natural convergence, exhaustively trying every (node, block)
    move finds no admissible one that lowers the cut — the convergence
    certificate, with admissibility taken from the same exposed budget
    helper the rounds use."""
    prob, labels = _instance(family, n, k, seed)
    out = refine(prob, labels)
    assert out.stats["refine"]["converged"], \
        "oracle needs natural convergence, raise max_rounds"
    lab = np.asarray(out.labels)
    iw, budget = refinement_budgets(prob, lab)
    cut0 = metrics.edge_cut(lab, prob.indptr, prob.indices)
    for v in range(prob.n):
        for b in range(prob.k):
            if b == lab[v] or iw[v] > budget[b]:
                continue
            trial = lab.copy()
            trial[v] = b
            assert metrics.edge_cut(trial, prob.indptr,
                                    prob.indices) >= cut0, (v, b)


def test_budget_helpers_consistent():
    """refinement_budgets == limit - block weights, clamped at zero, in
    quantized units; unit weights quantize to ones with a zero margin."""
    mesh = meshes.REGISTRY["tri"](200, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k=4, seed=0)
    iw, limit = refinement_quantization(prob)
    assert prob.weights is None
    np.testing.assert_array_equal(iw, np.ones(prob.n, np.int64))
    assert limit == int((1 + prob.epsilon) * prob.n / prob.k)
    labels = np.zeros(prob.n, np.int64)
    iw2, budget = refinement_budgets(prob, labels)
    np.testing.assert_array_equal(iw, iw2)
    assert budget[0] == 0                     # block 0 over-full
    assert np.all(budget[1:] == limit)
    with pytest.raises(ValueError, match="eps"):
        refinement_quantization(prob, eps=-0.1)


# ---------------------------------------------------------------------------
# sharded == host, bit for bit

@needs2
def test_sharded_equals_host_fast():
    """Tier-1 smoke of the parity claim at P in {1, 2} (full randomized
    sweep at P up to 8 runs under tier2)."""
    prob, labels = _instance("tri", 300, 6, seed=3)
    host = np.asarray(refine(prob, labels).labels)
    for P in (1, 2):
        dev = np.asarray(refine(prob, labels, devices=P).labels)
        np.testing.assert_array_equal(host, dev)


@pytest.mark.tier2
@needs8
@settings(max_examples=6, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 600),
       st.integers(2, 10), st.integers(0, 10 ** 6),
       st.sampled_from([1, 2, 4, 8]))
def test_sharded_equals_host_randomized(family, n, k, seed, devices):
    """Acceptance: the shard_map refinement returns labels bit-for-bit
    equal to the host numpy reference at every device count, on
    randomized meshes and randomized labelings."""
    prob, labels = _instance(family, n, k, seed)
    host = refine(prob, labels)
    dev = refine(prob, labels, devices=devices)
    np.testing.assert_array_equal(np.asarray(host.labels),
                                  np.asarray(dev.labels))
    for fld in ("rounds", "moves", "converged"):
        assert host.stats["refine"][fld] == dev.stats["refine"][fld]


@pytest.mark.tier2
@needs8
def test_sharded_solver_to_refiner_pipeline():
    """partition(devices=P, refine=True): the solve and the refinement
    both run sharded, and the refined labels still match a host-refined
    copy of the same solve."""
    mesh = meshes.REGISTRY["tri"](600, seed=1)
    prob = PartitionProblem.from_mesh(mesh, k=8, seed=1)
    res = partition(prob, method="geographer", devices=4)
    a = partition(prob, method="geographer", devices=4, refine=True)
    b = refine(prob, res)                    # host reference
    np.testing.assert_array_equal(np.asarray(a.labels),
                                  np.asarray(b.labels))


# ---------------------------------------------------------------------------
# front doors and error paths

def test_refine_front_door_plumbing():
    prob, labels = _instance("tri", 200, 4, seed=0)
    res = partition(prob, method="sfc")
    out = res.refine()
    assert isinstance(out, PartitionResult)
    assert out.method == "sfc+lp"
    st_ = out.stats["refine"]
    assert set(st_) >= {"method", "rounds", "moves", "converged",
                        "cut_before", "cut_after", "devices", "eps"}
    assert st_["method"] == "label_prop" and st_["devices"] is None
    assert st_["eps"] == prob.epsilon
    # raw label arrays work too (no PartitionResult required)
    raw = refine(prob, labels)
    assert raw.method == "labels+lp"
    # evaluate=True fills quality
    ev = refine(prob, res, evaluate=True)
    assert ev.quality is not None and "totalCommVol" in ev.quality
    # aliases resolve; unknown names fail loudly
    assert resolve_refiner("lp") == "label_prop"
    assert resolve_refiner(True) == "label_prop"
    assert "label_prop" in available_refiners()
    with pytest.raises(UnknownRefinerError):
        refine(prob, res, "nope")
    with pytest.raises(UnknownRefinerError):
        partition(prob, method="sfc", refine="nope")


def test_refine_error_paths():
    mesh = meshes.REGISTRY["tri"](150, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k=4, seed=0)
    labels = np.zeros(prob.n, np.int64)
    nograph = PartitionProblem(points=prob.points, k=4, seed=0)
    with pytest.raises(ValueError, match="graph"):
        refine(nograph, labels)
    with pytest.raises(TypeError):
        refine("not a problem", labels)
    with pytest.raises(ValueError, match="labels"):
        refine(prob, labels[:-1])
    with pytest.raises(ValueError, match="max_rounds"):
        refine(prob, labels, max_rounds=0)
    with pytest.raises(ValueError, match="unique"):
        refine(prob, labels, node_order=np.zeros(prob.n, np.int64))
    with pytest.raises(ValueError, match="node_order"):
        refine(prob, labels, node_order=np.arange(prob.n - 1))
    with pytest.raises(ValueError, match="int32"):
        refine(prob, labels,
               node_order=np.arange(prob.n, dtype=np.int64) + 2 ** 40)
    res = PartitionResult(labels=labels, k=4, method="x")
    with pytest.raises(ValueError, match="problem"):
        res.refine()


@needs2
def test_refine_rejects_mismatched_graph():
    prob, labels = _instance("tri", 200, 4, seed=0)
    g1 = prob.to_sharded_graph(1)
    with pytest.raises(ValueError, match="different problem/devices"):
        refine(prob, labels, devices=2, graph=g1)


def test_partition_refine_composition():
    prob, _ = _instance("tri", 300, 6, seed=2)
    base = partition(prob, method="rcb")
    comp = partition(prob, method="rcb", refine=True)
    assert comp.method == "rcb+lp"
    ref = refine(prob, base)
    np.testing.assert_array_equal(np.asarray(comp.labels),
                                  np.asarray(ref.labels))
    # refine=False / None are no-ops
    off = partition(prob, method="rcb", refine=False)
    assert off.method == "rcb" and "refine" not in off.stats


def test_repartition_refines_before_migration_accounting():
    mesh = meshes.REGISTRY["tri"](300, seed=4)
    prob = PartitionProblem.from_mesh(mesh, k=6, seed=4)
    prev = partition(prob, method="geographer")
    rng = np.random.default_rng(5)
    prob2 = prob.replace(
        weights=rng.uniform(0.5, 1.5, prob.n))
    res = repartition(prob2, prev, refine=True)
    assert res.method.endswith("+lp")
    assert "refine" in res.stats and "migration" in res.stats
    # migration is measured on the REFINED labels
    expect = metrics.migration_fraction(prev.labels, res.labels,
                                        prob2.weights)
    assert res.stats["migration"]["fraction"] == pytest.approx(expect)
