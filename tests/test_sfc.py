"""Hilbert SFC properties: locality, bijectivity on grids, np/jnp agreement."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sfc import (hilbert_index_np, hilbert_index_jnp,
                            sfc_initial_centers)


@pytest.mark.parametrize("dim", [2, 3])
def test_locality(dim):
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (4000, dim))
    keys = hilbert_index_np(pts)
    order = np.argsort(keys)
    d_sorted = np.linalg.norm(np.diff(pts[order], axis=0), axis=1).mean()
    d_rand = np.linalg.norm(np.diff(pts, axis=0), axis=1).mean()
    assert d_sorted < 0.3 * d_rand


def test_bijective_on_grid_2d():
    """Every cell of a 2^b x 2^b grid gets a distinct key covering 0..4^b-1."""
    b = 4
    g = np.arange(2 ** b)
    xs, ys = np.meshgrid(g, g, indexing="ij")
    pts = np.stack([xs.ravel(), ys.ravel()], 1).astype(np.float64)
    pts = pts / (2 ** b - 1) * (1 - 2 ** -b) + 2 ** -(b + 1)  # cell centers
    keys = hilbert_index_np(pts, bits=b)
    assert len(np.unique(keys)) == 4 ** b
    assert keys.min() == 0 and keys.max() == 4 ** b - 1


def test_curve_is_continuous_2d():
    """Consecutive Hilbert indices map to grid-adjacent cells."""
    b = 4
    g = np.arange(2 ** b)
    xs, ys = np.meshgrid(g, g, indexing="ij")
    cells = np.stack([xs.ravel(), ys.ravel()], 1).astype(np.float64)
    pts = cells / (2 ** b - 1) * (1 - 2 ** -b) + 2 ** -(b + 1)
    keys = hilbert_index_np(pts, bits=b)
    order = np.argsort(keys)
    steps = np.abs(np.diff(cells[order], axis=0)).sum(axis=1)
    assert np.all(steps == 1), "Hilbert curve must step to an adjacent cell"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31), st.integers(2, 3))
def test_np_jnp_rank_agreement(seed, dim):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-5, 7, (256, dim))
    k_np = hilbert_index_np(pts)
    k_j = np.asarray(hilbert_index_jnp(jnp.asarray(pts, jnp.float32)))
    r_np = np.argsort(np.argsort(k_np, kind="stable"), kind="stable")
    r_j = np.argsort(np.argsort(k_j, kind="stable"), kind="stable")
    corr = np.corrcoef(r_np, r_j)[0, 1]
    assert corr > 0.99


def test_initial_centers_spread():
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, (10000, 2))
    c = sfc_initial_centers(pts, 16)
    assert c.shape == (16, 2)
    # centers should be well spread: min pairwise distance not tiny
    d = np.linalg.norm(c[:, None] - c[None, :], axis=-1)
    d[np.arange(16), np.arange(16)] = np.inf
    assert d.min() > 0.05


def test_initial_centers_weighted():
    rng = np.random.default_rng(2)
    pts = np.concatenate([rng.uniform(0, 0.1, (1000, 2)),
                          rng.uniform(0.9, 1.0, (1000, 2))])
    w = np.concatenate([np.full(1000, 100.0), np.full(1000, 1.0)])
    c = sfc_initial_centers(pts, 8, w)
    # nearly all centers should sit in the heavy cluster
    assert (c < 0.2).all(axis=1).sum() >= 6
