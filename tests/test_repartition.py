"""Dynamic repartitioning: warm-started balanced k-means, migration
metrics, no-op fixed points, cold relabel matching, sharded agreement,
and the acceptance claims on the drifting-hotspot workload."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import meshes, metrics
from repro.core.balanced_kmeans import BKMConfig
from repro.core.timeseries import (simulate_loadbalance,
                                   simulate_loadbalance_scan)
from repro.partition import (PartitionProblem, greedy_center_match,
                             partition, repartition, supports_warm_start,
                             warm_start_methods, weighted_centroids)
from repro.partition.repartition import WARM_DELTA_TOL

EPS = 0.03


def _hotspot_problem(n=3000, k=16, seed=0, t=0,
                     workload=None) -> PartitionProblem:
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, 2))
    wl = workload or meshes.WORKLOADS["drifting_hotspot"]()
    w = np.asarray(wl.weights_at(pts, t))
    return PartitionProblem(points=pts, k=k, weights=w, epsilon=EPS,
                            seed=seed)


# ---------------------------------------------------------------------------
# migration metrics — hand-computed 6-point cases
# ---------------------------------------------------------------------------

class TestMigrationMetrics:
    PREV = np.array([0, 0, 1, 1, 2, 2])
    NEW = np.array([0, 1, 1, 1, 2, 0])       # points 1 and 5 moved
    W = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])

    def test_weighted_volume(self):
        assert float(metrics.migration_volume(self.PREV, self.NEW,
                                              self.W)) == 8.0   # 2 + 6

    def test_unweighted_volume(self):
        assert float(metrics.migration_volume(self.PREV, self.NEW)) == 2.0

    def test_fraction(self):
        assert float(metrics.migration_fraction(
            self.PREV, self.NEW, self.W)) == pytest.approx(8.0 / 21.0)
        assert float(metrics.migration_fraction(
            self.PREV, self.NEW)) == pytest.approx(2.0 / 6.0)

    def test_retained(self):
        assert float(metrics.retained_fraction(
            self.PREV, self.NEW, self.W)) == pytest.approx(13.0 / 21.0)

    def test_identity_is_zero(self):
        assert float(metrics.migration_volume(self.PREV, self.PREV,
                                              self.W)) == 0.0
        assert float(metrics.retained_fraction(self.PREV,
                                               self.PREV)) == 1.0

    def test_in_graph(self):
        """The same functions trace under jit (sharded-path composition)."""
        import jax
        import jax.numpy as jnp
        frac = jax.jit(metrics.migration_fraction)(
            jnp.asarray(self.PREV), jnp.asarray(self.NEW),
            jnp.asarray(self.W))
        assert float(frac) == pytest.approx(8.0 / 21.0, rel=1e-6)


# ---------------------------------------------------------------------------
# greedy center matching
# ---------------------------------------------------------------------------

class TestGreedyMatch:
    def test_permutation_recovered(self):
        rng = np.random.default_rng(3)
        prev = rng.uniform(0, 1, (8, 2))
        perm = rng.permutation(8)
        mapping = greedy_center_match(prev[perm], prev)
        assert np.array_equal(mapping, perm)
        assert sorted(mapping) == list(range(8))

    def test_noise_tolerant(self):
        rng = np.random.default_rng(4)
        prev = rng.uniform(0, 1, (6, 2)) * 10       # well-separated
        perm = rng.permutation(6)
        new = prev[perm] + rng.normal(0, 0.01, (6, 2))
        assert np.array_equal(greedy_center_match(new, prev), perm)

    def test_shape_mismatch(self):
        with pytest.raises(ValueError):
            greedy_center_match(np.zeros((3, 2)), np.zeros((4, 2)))

    def test_weighted_centroids(self):
        pts = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 2.0], [0.0, 4.0]])
        lab = np.array([0, 0, 1, 1])
        w = np.array([1.0, 3.0, 1.0, 1.0])
        c = weighted_centroids(pts, lab, 2, w)
        assert c[0] == pytest.approx([0.75, 0.0])
        assert c[1] == pytest.approx([0.0, 3.0])


# ---------------------------------------------------------------------------
# warm start semantics
# ---------------------------------------------------------------------------

class TestWarmStart:
    def test_registry_flags(self):
        assert supports_warm_start("geographer")
        assert supports_warm_start("bkm")           # alias resolves
        assert not supports_warm_start("rcb")
        assert warm_start_methods() == ["geographer"]

    def test_warm_true_rejected_for_rcb(self):
        prob = _hotspot_problem(n=400, k=4)
        prev = partition(prob, method="rcb")
        with pytest.raises(ValueError, match="warm-start"):
            repartition(prob, prev, method="rcb", warm=True)

    def test_k_mismatch_rejected(self):
        prob = _hotspot_problem(n=400, k=4)
        prev = partition(prob, method="geographer")
        with pytest.raises(ValueError, match="k="):
            repartition(prob.replace(k=8), prev)

    def test_n_mismatch_rejected(self):
        prob = _hotspot_problem(n=400, k=4)
        prev = partition(prob, method="geographer")
        smaller = PartitionProblem(points=prob.points[:200], k=4,
                                   epsilon=EPS)
        with pytest.raises(ValueError, match="point set"):
            repartition(smaller, prev)

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6))
    def test_unchanged_problem_is_fixed_point(self, seed):
        """Property: repartition with an unchanged problem migrates zero
        weight and needs <= 1 movement iteration."""
        prob = _hotspot_problem(n=1500, k=8, seed=seed % 97)
        prev = partition(prob, method="geographer")
        res = repartition(prob, prev)
        assert res.stats["iters"] <= 1
        assert res.stats["migration"]["volume"] == 0.0
        assert np.array_equal(res.labels, prev.labels)
        assert res.stats["warm_start"] is True

    def test_cold_relabel_reduces_id_churn(self):
        """The greedy matching must keep block ids stable: a cold rcb
        restart of the SAME problem is (near-)identical after matching."""
        prob = _hotspot_problem(n=1000, k=8)
        prev = partition(prob, method="rcb")
        res = repartition(prob, prev, method="rcb")
        assert res.stats["warm_start"] is False
        assert res.stats["relabel_matched"] is True
        # deterministic method + unchanged problem -> same cut, and the
        # matching must recover the identical labeling
        assert np.array_equal(res.labels, prev.labels)
        assert res.stats["migration"]["volume"] == 0.0

    def test_warm_from_centerless_previous_raises(self):
        prob = _hotspot_problem(n=400, k=4)
        prev = partition(prob, method="rcb")        # no centers
        with pytest.raises(ValueError, match="no centers"):
            repartition(prob, prev, method="geographer", warm=True)

    def test_auto_mode_falls_back_cold(self):
        """warm=None + a centerless previous -> cold path, not an error."""
        prob = _hotspot_problem(n=400, k=4)
        prev = partition(prob, method="rcb")
        res = repartition(prob, prev, method="geographer")
        assert res.stats["warm_start"] is False
        assert "migration" in res.stats


# ---------------------------------------------------------------------------
# the acceptance claims: drifting hotspot, T >= 8 steps, k = 16
# ---------------------------------------------------------------------------

class TestAcceptance:
    @pytest.fixture(scope="class")
    def runs(self):
        prob = _hotspot_problem(n=3000, k=16, seed=0)
        wl = meshes.WORKLOADS["drifting_hotspot"]()
        warm = simulate_loadbalance(prob, wl, steps=8, mode="warm")
        cold = simulate_loadbalance(prob, wl, steps=8, mode="cold")
        return warm, cold

    def test_iteration_ratio(self, runs):
        warm, cold = runs
        ratio = (cold["summary"]["mean_iters"]
                 / max(warm["summary"]["mean_iters"], 1e-9))
        assert ratio >= 3.0, (
            f"warm start must use >=3x fewer iterations, got {ratio:.1f}x "
            f"(warm {warm['summary']['mean_iters']}, "
            f"cold {cold['summary']['mean_iters']})")

    def test_migration_ratio(self, runs):
        warm, cold = runs
        ratio = (warm["summary"]["mean_migration_fraction"]
                 / max(cold["summary"]["mean_migration_fraction"], 1e-9))
        assert ratio <= 0.30, (
            f"warm start must move <=30% of cold's weight, got "
            f"{ratio:.3f}")

    def test_balanced_every_step(self, runs):
        warm, cold = runs
        for run in (warm, cold):
            for rec in run["per_step"]:
                assert rec["imbalance"] <= EPS + 1e-6, rec

    def test_migration_accounting_consistent(self, runs):
        warm, _ = runs
        for rec in warm["per_step"]:
            assert rec["retained_fraction"] == pytest.approx(
                1.0 - rec["migration_fraction"])


# ---------------------------------------------------------------------------
# sharded path agreement
# ---------------------------------------------------------------------------

class TestSharded:
    @pytest.fixture(scope="class")
    def setup(self):
        import jax
        if len(jax.devices()) < 4:
            pytest.skip("needs >= 4 (virtual) devices")
        prob0 = _hotspot_problem(n=2000, k=8, seed=1, t=0)
        prob1 = prob0.replace(
            weights=np.asarray(meshes.WORKLOADS["drifting_hotspot"]()
                               .weights_at(prob0.points, 1)))
        prev = partition(prob0, method="geographer")
        return prob0, prob1, prev

    def test_devices1_bit_for_bit(self, setup):
        _, prob1, prev = setup
        single = repartition(prob1, prev)
        d1 = repartition(prob1, prev, devices=1)
        assert np.array_equal(single.labels, d1.labels)
        assert np.array_equal(single.centers, d1.centers)
        assert np.array_equal(single.influence, d1.influence)
        assert single.stats["iters"] == d1.stats["iters"]

    def test_devices4_balance_invariant(self, setup):
        _, prob1, prev = setup
        res = repartition(prob1, prev, devices=4)
        assert res.imbalance() <= EPS + 1e-6
        assert res.stats["warm_start"] is True
        assert len(np.unique(res.labels)) == prob1.k
        # warm advantage survives sharding: far fewer iterations than a
        # cold solve's ~max_iter
        assert res.stats["iters"] <= 10

    def test_devices4_fixed_point(self, setup):
        prob0, _, prev = setup
        res = repartition(prob0, prev, devices=4)
        assert res.stats["migration"]["volume"] == 0.0
        assert res.stats["iters"] == 0


# ---------------------------------------------------------------------------
# scan driver == host loop (permuted space)
# ---------------------------------------------------------------------------

class TestScanDriver:
    def test_scan_matches_host_loop(self):
        prob = _hotspot_problem(n=1500, k=8, seed=2)
        wl = meshes.WORKLOADS["drifting_hotspot"]()
        host = simulate_loadbalance(prob, wl, steps=4, mode="warm")
        prev = partition(
            prob.replace(weights=np.asarray(
                wl.weights_at(prob.points, 0))), method="geographer")
        perm = np.random.default_rng(prob.seed).permutation(prob.n)
        cfg = BKMConfig(k=prob.k, warmup=False, delta_tol=WARM_DELTA_TOL)
        _, recs = simulate_loadbalance_scan(
            prob.points[perm], prev.centers, prev.influence,
            np.asarray(prev.labels)[perm], wl, 4, cfg)
        host_iters = [r["iters"] for r in host["per_step"]]
        assert np.asarray(recs["iters"]).tolist() == host_iters
        np.testing.assert_allclose(
            np.asarray(recs["migration_fraction"]),
            [r["migration_fraction"] for r in host["per_step"]],
            rtol=1e-5, atol=1e-7)

    def test_other_workloads_run(self):
        """Rotating wave + AMR refinement drive the loop balanced too."""
        for name in ("rotating_wave", "amr_refine"):
            prob = _hotspot_problem(n=1200, k=8, seed=3)
            wl = meshes.WORKLOADS[name]()
            sim = simulate_loadbalance(prob, wl, steps=3, mode="warm")
            assert sim["summary"]["all_balanced"], (name, sim["summary"])
            assert sim["workload"] == type(wl).__name__
