"""Experiment harness coverage: the §5 method × mesh-zoo matrix runs at
toy sizes, the emitted ``BENCH_experiments.json`` obeys its schema, and
the ``compare_experiments`` gate accepts a self-compare / rejects a
planted regression."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), os.pardir))

ROW_INT_METRICS = ("cut", "maxCommVol", "totalCommVol", "boundaryNodes",
                   "n_blocks_used")
ROW_KEYS = set(ROW_INT_METRICS) | {
    "family", "graph", "tool", "n", "k", "imbalance", "balanced",
    "refined", "base_tool", "time_partition_s", "time_refine_s",
    "time_eval_s"}


def validate_schema(out: dict) -> None:
    """Assert the BENCH_experiments.json contract the CI gate relies on."""
    for key in ("schema", "quick", "n", "k", "epsilon", "seed",
                "eval_devices", "refiner", "families", "methods", "rows",
                "summary"):
        assert key in out, f"missing top-level key {key!r}"
    assert out["schema"] == 2
    families, methods = out["families"], out["methods"]
    per_cell = 2 if out["refiner"] else 1
    assert len(out["rows"]) == len(families) * len(methods) * per_cell
    seen = set()
    for r in out["rows"]:
        assert ROW_KEYS <= set(r), ROW_KEYS - set(r)
        assert r["family"] in families and r["base_tool"] in methods
        seen.add((r["family"], r["tool"]))
        for met in ROW_INT_METRICS:
            assert int(r[met]) >= 0
        assert r["totalCommVol"] >= r["maxCommVol"]
        assert r["imbalance"] >= 0.0
        if r["refined"]:
            assert r["tool"] != r["base_tool"]
            assert r["tool"].startswith(r["base_tool"] + "+")
            assert {"refine_rounds", "refine_moves",
                    "refine_converged"} <= set(r)
        else:
            assert r["tool"] == r["base_tool"]
    assert len(seen) == len(out["rows"]), "duplicate (family, tool) cell"
    trend = out["summary"]["geo_over_tool"]
    assert set(trend) == set(methods) - {"geographer"}
    for ratios in trend.values():
        assert {"cut", "maxCommVol", "totalCommVol"} <= set(ratios)
        assert all(v > 0 for v in ratios.values())
    if out["refiner"]:
        assert set(out["summary"]["geo_refined_over_tool"]) == \
            set(methods) - {"geographer"}
        assert set(out["summary"]["refined_over_unrefined"]) == \
            set(methods)
        assert isinstance(out["summary"]["refined_imbalance_ok"], bool)
    assert isinstance(out["summary"]["geographer_all_balanced"], bool)


@pytest.fixture(scope="module")
def toy_matrix():
    from repro.eval.experiments import run_matrix
    return run_matrix(n=400, k=4, eval_devices=2, seed=0)


def test_full_matrix_toy_sizes(toy_matrix):
    """Every registered method × every zoo family actually produces a
    cell (coverage is what the CI gate diffs against)."""
    from repro.eval.experiments import (EXPERIMENT_FAMILIES,
                                        experiment_methods)
    validate_schema(toy_matrix)
    assert set(toy_matrix["families"]) == set(EXPERIMENT_FAMILIES)
    assert set(toy_matrix["methods"]) == set(experiment_methods())
    assert {"geographer", "sfc", "rcb", "rib", "multijagged",
            "hierarchical"} <= set(toy_matrix["methods"])


def test_matrix_metrics_match_host_evaluation(toy_matrix):
    """Harness rows must equal an independent host-side re-evaluation —
    the sharded evaluator cannot drift from core.metrics unnoticed."""
    from repro.core import meshes, metrics
    from repro.eval.experiments import EXPERIMENT_FAMILIES
    from repro.partition import PartitionProblem

    row = next(r for r in toy_matrix["rows"]
               if r["tool"] == "rcb" and r["family"] == "tri")
    mesh = meshes.REGISTRY["tri"](
        int(400 * EXPERIMENT_FAMILIES["tri"]), seed=0)
    prob = PartitionProblem.from_mesh(mesh, 4, seed=0)
    from repro.partition import partition
    labels = partition(prob, method="rcb").labels
    host = metrics.evaluate_problem(prob, labels)
    for met in ("cut", "maxCommVol", "totalCommVol", "boundaryNodes"):
        assert row[met] == host[met]


@pytest.mark.tier2
def test_cli_quick_smoke_and_schema(tmp_path):
    """`python -m benchmarks.experiments --json` end to end (exit 0, file
    lands where REPRO_BENCH_JSON_DIR points, schema holds)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["REPRO_BENCH_JSON_DIR"] = str(tmp_path)
    env["REPRO_BENCH_DIR"] = str(tmp_path / "results")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.experiments",
         "--n", "400", "--k", "4", "--json"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"experiments CLI failed (exit {proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-2000:]}")
    path = tmp_path / "BENCH_experiments.json"
    assert path.exists()
    out = json.loads(path.read_text())
    validate_schema(out)
    assert out["n"] == 400 and out["k"] == 4 and out["quick"] is False


def _run_gate(baseline_dir, current_dir):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_compare.py"),
         "--baseline", str(baseline_dir), "--current", str(current_dir),
         "--files", "BENCH_experiments.json"],
        capture_output=True, text=True, timeout=120)


@pytest.fixture(scope="module")
def gate_dirs(toy_matrix, tmp_path_factory):
    base = tmp_path_factory.mktemp("baseline")
    cur = tmp_path_factory.mktemp("current")
    doc = json.loads(json.dumps(toy_matrix, default=float))
    # pin the trend summaries to CI-config-like values: the absolute
    # trend floors/ceilings are calibrated for the quick config
    # (n=4000), not for this n=400 toy matrix, and each has its own
    # rejection test below
    for tool in ("sfc", "rcb"):
        doc["summary"]["geo_over_tool"][tool]["totalCommVol"] = 0.85
        doc["summary"]["geo_refined_over_tool"][tool]["totalCommVol"] = 0.70
    doc["summary"]["refined_over_unrefined"]["geographer"][
        "totalCommVol"] = 0.90
    blob = json.dumps(doc)
    (base / "BENCH_experiments.json").write_text(blob)
    (cur / "BENCH_experiments.json").write_text(blob)
    return base, cur


def test_gate_accepts_self_compare(gate_dirs):
    base, cur = gate_dirs
    proc = _run_gate(base, cur)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_gate_rejects_commvol_regression(gate_dirs, tmp_path):
    """A 2x comm-volume blowup in one cell must fail the gate."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    row = next(r for r in bad["rows"] if r["tool"] == "geographer")
    row["totalCommVol"] = int(row["totalCommVol"] * 2 + 100)
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "totalCommVol" in proc.stdout


def test_gate_rejects_missing_cell(gate_dirs, tmp_path):
    """Dropping a (family, tool) cell is a coverage regression."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    bad["rows"] = bad["rows"][:-1]
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "coverage" in proc.stdout or "missing" in proc.stdout


def test_gate_rejects_refined_worse_than_sibling(gate_dirs, tmp_path):
    """A planted refined row whose cut EXCEEDS its unrefined sibling's
    is algorithmically impossible (refinement only accepts positive-gain
    moves) — the gate must reject it as a hard failure, at the benchmark
    level, whatever the baseline says."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    row = next(r for r in bad["rows"] if r["refined"])
    sib = next(r for r in bad["rows"]
               if not r["refined"] and r["family"] == row["family"]
               and r["tool"] == row["base_tool"])
    row["cut"] = sib["cut"] + 10
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "cut_monotonic" in proc.stdout


def test_gate_rejects_refined_imbalance_violation(gate_dirs, tmp_path):
    """Refinement claiming to have worsened balance past epsilon must
    fail the gate."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    bad["summary"]["refined_imbalance_ok"] = False
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "refined.imbalance" in proc.stdout


def test_gate_rejects_broken_refined_trend(gate_dirs, tmp_path):
    """The tightened refined-geographer ceiling (below the raw 0.79/0.86
    trend ratios) is the PR's headline claim — crossing it must fail."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    bad["summary"]["geo_refined_over_tool"]["sfc"]["totalCommVol"] = 0.78
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "refined_trend" in proc.stdout


def test_gate_rejects_vanished_refinement_gain(gate_dirs, tmp_path):
    """refined/unrefined geographer comm volume at 1.0 means the pass
    stopped paying for itself — gated strictly below 1.0."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    bad["summary"]["refined_over_unrefined"]["geographer"][
        "totalCommVol"] = 1.0
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "refined_gain" in proc.stdout


def test_gate_rejects_broken_trend(gate_dirs, tmp_path):
    """If geographer's comm volume stops beating sfc's (geomean ratio
    above 1.0) the paper-trend claim is gone and CI must say so."""
    base, _ = gate_dirs
    bad = json.loads((base / "BENCH_experiments.json").read_text())
    bad["summary"]["geo_over_tool"]["sfc"]["totalCommVol"] = 1.2
    (tmp_path / "BENCH_experiments.json").write_text(
        json.dumps(bad, default=float))
    proc = _run_gate(base, tmp_path)
    assert proc.returncode == 1
    assert "trend" in proc.stdout


def test_gate_files_selector_unknown_file(tmp_path):
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "bench_compare.py"),
         "--baseline", str(tmp_path), "--current", str(tmp_path),
         "--files", "BENCH_nonexistent.json"],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 2


def test_deterministic_rows_given_seed():
    """Same (n, k, seed) => identical metric cells (timings aside) —
    the property that makes the checked-in baseline meaningful."""
    from repro.eval.experiments import run_matrix
    a = run_matrix(n=300, k=3, families=["tri"], methods=["rcb", "sfc"],
                   eval_devices=2, seed=5)
    b = run_matrix(n=300, k=3, families=["tri"], methods=["rcb", "sfc"],
                   eval_devices=2, seed=5)
    for ra, rb in zip(a["rows"], b["rows"]):
        for met in ROW_INT_METRICS + ("imbalance",):
            assert ra[met] == rb[met]
    assert np.isclose(
        a["summary"]["geo_over_tool"]["sfc"].get("cut", 0.0),
        b["summary"]["geo_over_tool"]["sfc"].get("cut", 0.0))