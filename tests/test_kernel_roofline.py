"""Unit tests for the analytic assign-kernel roofline model
(repro.launch.kernel_roofline): platform table sanity, intensity math,
bottleneck selection, and the BENCH_scaling.json record schema."""
import math

import pytest

from repro.launch.kernel_roofline import (PLATFORMS, assign_intensity,
                                          detect_platform,
                                          kernel_roofline_record, predict,
                                          utilization)

# must stay in sync with tools/bench_compare.py::ROOFLINE_FIELDS
ROOFLINE_FIELDS = ("platform", "backend", "n", "d", "k", "ai", "compute_s",
                   "memory_s", "bound_s", "bottleneck", "measured_s",
                   "utilization")


def test_platform_table_sane():
    for name, p in PLATFORMS.items():
        assert p["hbm_bw"] > 0, name
        for prec in ("f32", "bf16"):
            assert p["peak_flops"][prec] > 0, (name, prec)
        # bf16 never slower than f32 on any modeled platform
        assert p["peak_flops"]["bf16"] >= p["peak_flops"]["f32"], name


def test_detect_platform_is_known():
    assert detect_platform() in PLATFORMS


def test_intensity_positive_and_scales_with_d():
    lo = assign_intensity(1 << 16, 2, 64)
    hi = assign_intensity(1 << 16, 128, 64)
    for block in ("distance", "moments", "total"):
        assert lo[block]["flops"] > 0
        assert lo[block]["hbm_bytes"] > 0
        assert lo[block]["ai"] > 0
    # the 2*BP*BC*d matmul dominates: more dims, more FLOPs — and AI
    # rises because bytes grow ~d while epilogue FLOPs stay fixed
    assert hi["distance"]["flops"] > lo["distance"]["flops"]
    assert hi["total"]["ai"] > lo["total"]["ai"]


def test_intensity_prune_frac_cuts_distance_flops():
    base = assign_intensity(1 << 18, 2, 256)
    pruned = assign_intensity(1 << 18, 2, 256, prune_frac=0.5)
    assert pruned["distance"]["flops"] == pytest.approx(
        0.5 * base["distance"]["flops"])
    # moments are per point tile, untouched by center-tile pruning
    assert pruned["moments"]["flops"] == base["moments"]["flops"]


def test_intensity_unfused_drops_moment_block():
    unfused = assign_intensity(1 << 16, 2, 64, fused=False)
    assert unfused["moments"]["flops"] == 0.0
    assert unfused["moments"]["hbm_bytes"] == 0.0


def test_jnp_memory_model_has_scratch_traffic():
    """The dense [chunk, k] scratch is what makes the jnp path
    bandwidth-bound — its byte count must dominate the tiled model's."""
    jnp_b = assign_intensity(1 << 18, 2, 256, backend="jnp")
    pal_b = assign_intensity(1 << 18, 2, 256, backend="pallas")
    assert jnp_b["total"]["hbm_bytes"] > pal_b["total"]["hbm_bytes"]
    assert jnp_b["total"]["ai"] < pal_b["total"]["ai"]


def test_predict_bottleneck_selection():
    # low-d on a bandwidth-starved host: memory bound
    cpu = predict(1 << 18, 2, 64, platform="cpu_host", backend="jnp")
    assert cpu["bottleneck"] == "memory"
    assert cpu["bound_s"] == pytest.approx(
        max(cpu["compute_s"], cpu["memory_s"]))
    # predictions are finite and positive everywhere
    for plat in PLATFORMS:
        p = predict(1 << 20, 2, 64, platform=plat)
        assert math.isfinite(p["bound_s"]) and p["bound_s"] > 0


def test_predict_bf16_speeds_distance_only():
    f32 = predict(1 << 20, 128, 256, platform="tpu_v5e", precision="f32")
    b16 = predict(1 << 20, 128, 256, platform="tpu_v5e", precision="bf16")
    assert b16["compute_s"] < f32["compute_s"]
    # HBM traffic is modeled unchanged (operands cast in-VMEM)
    assert b16["memory_s"] == f32["memory_s"]


def test_utilization_edge_cases():
    assert utilization(1.0, 2.0) == pytest.approx(0.5)
    assert utilization(1.0, 0.0) == 0.0
    assert utilization(1.0, float("nan")) == 0.0
    assert utilization(1.0, float("inf")) == 0.0


def test_record_schema_complete():
    rec = kernel_roofline_record(1 << 20, 2, 64, measured_s=1.0,
                                 platform="cpu_host", backend="jnp")
    for field in ROOFLINE_FIELDS:
        assert field in rec and rec[field] is not None, field
    assert 0.0 < rec["utilization"]
    # without a measurement the record still carries the prediction
    rec2 = kernel_roofline_record(1 << 20, 2, 64, platform="cpu_host")
    assert rec2["measured_s"] is None and rec2["utilization"] is None
    assert rec2["bound_s"] > 0
