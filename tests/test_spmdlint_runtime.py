"""Retrace sentinel (tools/spmdlint/runtime.py): the planted
recompilation MUST trip it, the real steady-state serving path MUST
pass it — the acceptance pair for the CI sanitizer leg."""
from dataclasses import dataclass

import numpy as np
import pytest

from tools.spmdlint.runtime import (HOT_ENTRY_POINTS, RetraceError,
                                    RetraceSentinel, _compile_count)


def _pts(n, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, 2))


def test_hot_entry_points_resolve_and_count():
    s = RetraceSentinel()
    snap = s.snapshot()
    # every declared entry point must exist and expose a counter — a
    # rename in the engine should fail HERE, not silently un-watch it
    assert set(snap) == {label for label, _, _ in HOT_ENTRY_POINTS}
    assert all(isinstance(v, int) for v in snap.values())


def test_planted_recompilation_trips_the_sentinel():
    """An unhashed (identity-hashed) config passed fresh per call is the
    canonical steady-state retrace bug: every call is a new static key."""
    import jax
    import jax.numpy as jnp

    @dataclass(eq=False)            # eq=False -> hash by object identity
    class UnhashedCfg:
        scale: float = 2.0

    from functools import partial

    @partial(jax.jit, static_argnames=("cfg",))
    def hot(x, cfg):
        return x * cfg.scale

    x = jnp.ones(8)
    hot(x, UnhashedCfg())           # warm-up compile
    s = RetraceSentinel()
    s.track("planted", hot)
    with s:
        hot(x, UnhashedCfg())       # fresh object -> new static key
        hot(x, UnhashedCfg())
    assert s.deltas().get("planted", 0) >= 2
    with pytest.raises(RetraceError, match="planted"):
        s.assert_steady()


def test_well_behaved_static_config_stays_steady():
    """The same shape with a value-hashed config must NOT trip it."""
    import jax
    import jax.numpy as jnp
    from functools import partial

    @dataclass(frozen=True)         # value hash: fresh instances reuse
    class GoodCfg:
        scale: float = 2.0

    @partial(jax.jit, static_argnames=("cfg",))
    def hot(x, cfg):
        return x * cfg.scale

    x = jnp.ones(8)
    hot(x, GoodCfg())
    s = RetraceSentinel()
    s.track("good", hot)
    with s:
        for _ in range(3):
            hot(x, GoodCfg())
    s.assert_steady()


def test_steady_state_serving_path_does_not_retrace():
    """The real thing: a warmed PartitionServer keeps serving the same
    shape family without a single new compile on ANY hot entry point."""
    from repro.serve import PartitionRequest, PartitionServer

    server = PartitionServer(tiers=(256,), slots=2, cache_slots=8)

    def req(seed):
        return PartitionRequest(tenant="t", points=_pts(256, seed), k=4,
                                seed=7)

    # warm-up: cold solve compiles, then the warm-start solve compiles
    server.serve([req(0)])
    server.serve([req(1)])
    sentinel = RetraceSentinel()
    with sentinel:
        for seed in range(2, 6):
            [resp] = server.serve([req(seed)])
            assert resp.labels.shape == (256,)
    sentinel.assert_steady()


def test_steady_state_repartition_does_not_retrace():
    from repro.partition import PartitionProblem, partition, repartition

    prob = PartitionProblem(points=_pts(192, 3), k=4, seed=0)
    res = partition(prob, method="geographer")
    # warm-up the repartition trace once
    prob2 = PartitionProblem(points=_pts(192, 4), k=4, seed=0)
    res2 = repartition(prob2, res)
    sentinel = RetraceSentinel()
    with sentinel:
        prob3 = PartitionProblem(points=_pts(192, 5), k=4, seed=0)
        repartition(prob3, res2)
    sentinel.assert_steady()


def test_track_rejects_uncountable_callables():
    s = RetraceSentinel()
    with pytest.raises(TypeError, match="nothing to watch"):
        s.track("plain", lambda x: x)


def test_compile_count_reads_lru_builders():
    from repro.eval import sharded

    before = _compile_count(sharded._build_metrics_fn)
    assert isinstance(before, int)
