"""Minimal deterministic fallback for the ``hypothesis`` API surface used
by this test suite (``given`` / ``settings`` / ``strategies.integers`` /
``strategies.sampled_from``).

Only importable when the real hypothesis is missing — tests/conftest.py
appends this directory to ``sys.path`` as a last resort so the suite still
*runs* (with a handful of seeded examples per property) instead of dying
at collection. Install requirements-dev.txt for real property testing.
"""
from __future__ import annotations

import inspect

from . import strategies

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_EXAMPLES = 5


class HealthCheck:
    all = staticmethod(lambda: [])


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    """Bind strategies to the test's parameters (positional strategies map
    right-to-left onto the non-keyword parameters, matching real
    hypothesis, so leading pytest fixtures stay injectable) and run a few
    deterministic examples per call."""
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters)
        pos_names = [p for p in params if p not in kw_strategies]
        pos_names = pos_names[len(pos_names) - len(arg_strategies):]
        bound = dict(zip(pos_names, arg_strategies))
        bound.update(kw_strategies)
        free = [sig.parameters[p] for p in params if p not in bound]

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            for i in range(min(n, 10)):
                drawn = {name: s.example(i) for name, s in bound.items()}
                fn(*args, **kwargs, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # expose only unbound params so pytest doesn't look for fixtures
        # named after strategy-drawn arguments
        wrapper.__signature__ = sig.replace(parameters=free)
        return wrapper
    return deco
