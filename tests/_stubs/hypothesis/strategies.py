"""Deterministic stand-ins for the strategies the suite uses."""
from __future__ import annotations

import numpy as np


class _Strategy:
    def example(self, i: int):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def example(self, i: int) -> int:
        # edges first, then seeded interior draws
        edges = [self.lo, self.hi, (self.lo + self.hi) // 2]
        if i < len(edges):
            return edges[i]
        rng = np.random.default_rng(0xC0FFEE + i)
        return int(rng.integers(self.lo, self.hi + 1))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, i: int):
        return self.options[i % len(self.options)]


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Integers(min_value, max_value)


def sampled_from(options) -> _Strategy:
    return _SampledFrom(options)
