"""Shared slot/mask test utilities for the fixed-slot serving layers.

Both serving test suites exercise the same static-shape discipline —
``ServeEngine`` right-pads variable-length prompts into fixed decode
slots, ``PartitionServer`` cycle-pads variable-size point clouds into
fixed bucket slots — and used to re-implement the padding/mask helpers
inline. They live here (tests/_stubs is appended to ``sys.path`` by
tests/conftest.py) so every serving test builds its expected padded
batches through one implementation.
"""
from __future__ import annotations

import numpy as np


def pad_rows(rows, pad_value=0, dtype=np.int32):
    """Right-pad variable-length 1-D rows into a dense [B, Lmax] batch.

    The ``ServeEngine`` prompt-slot discipline: every row starts at
    position 0, shorter rows are filled with ``pad_value`` and masked.

    Returns:
        (arr [B, Lmax], valid [B, Lmax] bool) — ``valid[i, j]`` is True
        where ``arr[i, j]`` is real data.
    """
    rows = [np.asarray(r) for r in rows]
    if not rows:
        raise ValueError("need at least one row")
    lmax = max(len(r) for r in rows)
    arr = np.full((len(rows), lmax), pad_value, dtype)
    valid = np.zeros((len(rows), lmax), bool)
    for i, r in enumerate(rows):
        arr[i, :len(r)] = r
        valid[i, :len(r)] = True
    return arr, valid


def cycle_pad(points, cap, weights=None, perm=None):
    """Pad one point cloud to ``cap`` slots by cycling its (optionally
    permuted) real points at weight zero — the engine-wide padding
    discipline (``partition.batched`` / ``PartitionServer``): bounding
    boxes stay tight, weighted sums are exact.

    Args:
        points:  [n, d] coordinates, n <= cap.
        cap:     target padded length.
        weights: [n] weights or None (= ones).
        perm:    optional [n] permutation applied before cycling (the
            request-seed permutation the server uses).

    Returns:
        (pts [cap, d], w [cap], valid [cap] bool) — ``w`` is 0 and
        ``valid`` False on the padded tail.
    """
    points = np.asarray(points)
    n = points.shape[0]
    if n > cap:
        raise ValueError(f"n={n} exceeds cap={cap}")
    if perm is None:
        perm = np.arange(n)
    idx = np.asarray(perm)[np.arange(cap) % n]
    valid = np.arange(cap) < n
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    return points[idx], np.where(valid, w[idx], 0.0), valid


def fill_slots(items, slots, filler=None):
    """Top a short group up to a fixed slot count — the bucket-admission
    discipline shared by both serving engines.

    Args:
        items:  the real group (1 <= len <= slots).
        slots:  fixed lane count.
        filler: value for the padded lanes (default: ``items[0]``, the
            PartitionServer convention).

    Returns:
        (padded list of length ``slots``, valid [slots] bool).
    """
    if not (1 <= len(items) <= slots):
        raise ValueError(f"group size {len(items)} not in [1, {slots}]")
    filler = items[0] if filler is None else filler
    padded = list(items) + [filler] * (slots - len(items))
    return padded, np.arange(slots) < len(items)
