"""PartitionServer: slot-bucket admission, warm-state cache semantics,
per-request metrics, determinism, and the serving gate."""
import numpy as np
import pytest
from slot_utils import cycle_pad, fill_slots

from repro.core import metrics
from repro.core.balanced_kmeans import BKMConfig
from repro.partition import (PartitionProblem, WarmState,
                             bucket_balanced_kmeans, partition, repartition)
from repro.serve import (PartitionRequest, PartitionServer, request_stream)

# one shared shape family (cap 256, k 4, d 2) so the bucket trace compiles
# once and is reused across the module
TIERS = (256,)
K = 4


def _pts(n, seed=0):
    return np.random.default_rng(seed).uniform(0, 1, (n, 2))


def _server(**kw):
    kw.setdefault("tiers", TIERS)
    kw.setdefault("slots", 2)
    kw.setdefault("cache_slots", 8)
    return PartitionServer(**kw)


def _req(tenant, n=256, k=K, seed=None, weights=None):
    seed = (abs(hash(tenant)) % 1000) if seed is None else seed
    return PartitionRequest(tenant=tenant, points=_pts(n, seed), k=k,
                            weights=weights, seed=seed)


# -- admission / validation ------------------------------------------------

def test_empty_queue_step_is_noop():
    server = _server()
    assert server.step() == []
    assert server.pending() == 0
    assert server.stats["dispatches"] == 0


def test_oversized_request_clear_error():
    server = _server()
    with pytest.raises(ValueError, match="exceeds the largest tier"):
        server.submit(_req("big", n=300))
    assert server.pending() == 0      # rejected at the front door


def test_request_validation():
    with pytest.raises(ValueError, match="points must be"):
        PartitionRequest(tenant="a", points=np.zeros(5), k=2)
    with pytest.raises(ValueError, match="out of range"):
        PartitionRequest(tenant="a", points=_pts(8), k=9)
    with pytest.raises(ValueError, match="weights must be"):
        PartitionRequest(tenant="a", points=_pts(8), k=2,
                         weights=np.ones(7))
    with pytest.raises(TypeError, match="unknown BKMConfig"):
        PartitionServer(tiers=TIERS, nonsense=1)
    with pytest.raises(TypeError, match="per-request state"):
        PartitionServer(tiers=TIERS, epsilon=0.1)
    with pytest.raises(ValueError, match="powers of two"):
        PartitionServer(tiers=(100,))


def test_tier_router_picks_smallest_fit():
    server = PartitionServer(tiers=(256, 512, 1024))
    assert server.tier_for(200) == 256
    assert server.tier_for(256) == 256
    assert server.tier_for(257) == 512
    assert server.tier_for(1024) == 1024


# -- solve correctness -----------------------------------------------------

def test_cold_solve_matches_partition_at_cap():
    """A full-tier request is bit-for-bit the engine's geographer path
    (same seed permutation, same SFC bootstrap, vmap == single solve)."""
    pts = _pts(256, seed=3)
    [resp] = _server().serve(
        [PartitionRequest(tenant="t", points=pts, k=K, seed=3)])
    ref = partition(PartitionProblem(points=pts, k=K, seed=3),
                    method="geographer")
    assert np.array_equal(resp.labels, np.asarray(ref.labels))
    assert not resp.warm and resp.balanced
    assert resp.imbalance == pytest.approx(ref.imbalance(), abs=1e-5)


def test_warm_hit_matches_repartition():
    pts = _pts(256, seed=3)
    w = 1.0 + 6 * np.exp(-np.sum((pts - 0.3) ** 2, axis=1) / 0.03)
    server = _server()
    [r0] = server.serve(
        [PartitionRequest(tenant="t", points=pts, k=K, seed=3)])
    [r1] = server.serve(
        [PartitionRequest(tenant="t", points=pts, k=K, weights=w, seed=3)])
    assert r1.warm and server.stats["warm_hits"] == 1

    prob0 = PartitionProblem(points=pts, k=K, seed=3)
    prev = partition(prob0, method="geographer")
    ref = repartition(prob0.replace(weights=w), prev)
    assert np.array_equal(r1.labels, np.asarray(ref.labels))
    assert r1.iters == ref.stats["iters"]
    assert r1.migration_fraction == pytest.approx(
        ref.stats["migration"]["fraction"], abs=1e-6)


def test_padded_slot_is_balanced_and_valid():
    [resp] = _server().serve([_req("small", n=180)])
    assert resp.labels.shape == (180,)
    assert set(np.unique(resp.labels)) <= set(range(K))
    assert resp.balanced
    assert resp.tier == 256


def test_heterogeneous_batch_one_step():
    """Mixed n under one (cap, k): grouped into one bucket, one filler
    lane; every response correct for its own request."""
    server = _server(slots=4)
    reqs = [_req("a", n=256, seed=1), _req("b", n=200, seed=2),
            _req("c", n=180, seed=3)]
    out = server.serve(reqs)
    assert [r.tenant for r in out] == ["a", "b", "c"]
    assert server.stats["dispatches"] == 1
    assert server.stats["filler_slots"] == 1
    for r, req in zip(out, reqs):
        assert r.labels.shape == (req.n,)
        assert r.balanced


# -- warm cache semantics --------------------------------------------------

def test_warm_state_invalidated_on_n_change():
    server = _server()
    server.serve([_req("t", n=200, seed=1)])
    [resp] = server.serve([_req("t", n=210, seed=1)])
    assert not resp.warm
    assert server.stats["invalidations"] == 1
    # the new shape's solve re-populates the cache
    [resp2] = server.serve([_req("t", n=210, seed=1)])
    assert resp2.warm


def test_warm_state_invalidated_on_k_change():
    server = _server()
    server.serve([_req("t", n=64, k=4, seed=1)])
    [resp] = server.serve([_req("t", n=64, k=8, seed=1)])
    assert not resp.warm
    assert server.stats["invalidations"] == 1


def test_lru_eviction_and_refill_ordering():
    server = _server(cache_slots=2)
    server.serve([_req("a"), _req("b")])
    assert server.cached_tenants() == ["a", "b"]
    server.serve([_req("a")])                 # touch a -> LRU order [b, a]
    assert server.cached_tenants() == ["b", "a"]
    server.serve([_req("c")])                 # evicts b (least recent)
    assert server.cached_tenants() == ["a", "c"]
    assert server.stats["evictions"] == 1
    [rb] = server.serve([_req("b")])          # b refills cold, evicts a
    assert not rb.warm
    assert server.cached_tenants() == ["c", "b"]


def test_cache_disabled_serves_all_cold():
    server = _server(cache_slots=0)
    server.serve([_req("t")])
    [resp] = server.serve([_req("t")])
    assert not resp.warm
    assert server.stats["warm_hits"] == 0
    assert server.cached_tenants() == []


# -- determinism -----------------------------------------------------------

def test_stream_determinism_under_interleaving():
    """Same request stream => identical labels, independent of admission
    order and bucket packing (each slot is an independent vmap lane)."""
    def stream(order):
        server = _server(slots=2)
        reqs0 = [_req(t, n=200 + 10 * i, seed=i)
                 for i, t in enumerate("abcd")]
        out = {}
        for r in server.serve([reqs0[i] for i in order]):
            out[(0, r.tenant)] = r.labels
        reqs1 = [_req(t, n=200 + 10 * i, seed=i,
                      weights=1.0 + np.linspace(0, 5, 200 + 10 * i))
                 for i, t in enumerate("abcd")]
        for r in server.serve([reqs1[i] for i in reversed(order)]):
            out[(1, r.tenant)] = r.labels
        return out

    a = stream([0, 1, 2, 3])
    b = stream([2, 0, 3, 1])
    assert a.keys() == b.keys()
    for key in a:
        assert np.array_equal(a[key], b[key]), key


# -- bucket entry + padded-batch metrics -----------------------------------

def test_bucket_entry_error_paths():
    pts = np.stack([_pts(64, 1), _pts(64, 2)])
    w = np.ones((2, 64))
    c0 = np.stack([pts[0][:4], pts[1][:4]])
    cfg = BKMConfig(k=4)
    with pytest.raises(ValueError, match="prev_assignment"):
        bucket_balanced_kmeans(pts, w, c0, cfg, warm=True)
    with pytest.raises(ValueError, match="warm=True"):
        bucket_balanced_kmeans(pts, w, c0, cfg,
                               prev_assignment=np.zeros((2, 64), np.int32))
    with pytest.raises(ValueError, match="counts"):
        bucket_balanced_kmeans(pts, w, c0, cfg, counts=[64, 65])
    with pytest.raises(ValueError, match="valid"):
        bucket_balanced_kmeans(pts, w, c0, cfg, valid=[True])


def test_bucket_stats_match_host_metrics():
    """The in-graph padded-batch metrics equal the host metrics computed
    per unpadded slot."""
    rng = np.random.default_rng(0)
    caps, n0, n1 = 64, 64, 50
    p0, w0, _ = cycle_pad(_pts(n0, 1), caps, weights=1 + rng.random(n0))
    p1, w1, _ = cycle_pad(_pts(n1, 2), caps, weights=1 + rng.random(n1))
    pts, w = np.stack([p0, p1]), np.stack([w0, w1])
    c0 = np.stack([p0[:4], p1[:4]])
    cfg = BKMConfig(k=4)
    A, C, infl, stats = bucket_balanced_kmeans(
        pts, w, c0, cfg, counts=[n0, n1], valid=[True, True])
    assert np.array_equal(stats["counts"], [n0, n1])
    for s, n in ((0, n0), (1, n1)):
        host = metrics.imbalance(np.asarray(A[s][:n]), 4, w[s][:n])
        assert float(stats["imbalance"][s]) == pytest.approx(host, abs=1e-5)
    # warm re-solve from the converged state: migration vs prev in-graph
    A2, _, _, st2 = bucket_balanced_kmeans(
        pts, w, np.asarray(C), cfg, warm=True,
        influence0=np.asarray(infl), prev_assignment=np.asarray(A))
    for s in (0, 1):
        host = metrics.migration_fraction(np.asarray(A[s]),
                                          np.asarray(A2[s]), w[s])
        assert float(st2["migration_fraction"][s]) == pytest.approx(
            float(host), abs=1e-6)


def test_batch_metrics_host_equals_jnp():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    lab = rng.integers(0, 4, (3, 32))
    prev = rng.integers(0, 4, (3, 32))
    w = np.where(np.arange(32) < 28, 1 + rng.random((3, 32)), 0.0)
    np.testing.assert_allclose(
        metrics.batch_imbalance(lab, 4, w),
        np.asarray(metrics.batch_imbalance(jnp.asarray(lab), 4,
                                           jnp.asarray(w))), rtol=1e-5)
    np.testing.assert_allclose(
        metrics.batch_migration_fraction(prev, lab, w),
        np.asarray(metrics.batch_migration_fraction(
            jnp.asarray(prev), jnp.asarray(lab), jnp.asarray(w))),
        rtol=1e-6)


def test_cycle_pad_matches_server_prep():
    """The shared test helper reproduces the server's slot prep exactly."""
    pts = _pts(50, seed=7)
    perm = np.random.default_rng(9).permutation(50)
    padded, w, valid = cycle_pad(pts, 64, perm=perm)
    req = PartitionRequest(tenant="x", points=pts, k=4, seed=9)
    server = _server(tiers=(64,))
    _, spts, sw, _, _, _ = server._prep_slot(req, 64, None)
    np.testing.assert_array_equal(padded, spts)
    np.testing.assert_array_equal(w, sw)
    assert valid.sum() == 50


def test_warm_state_capture_and_compat():
    pts = _pts(128, seed=4)
    res = partition(PartitionProblem(points=pts, k=4, seed=4),
                    method="geographer")
    state = WarmState.capture(res)
    assert state.n == 128 and state.k == 4 and state.dim == 2
    assert state.compatible_with(128, 4)
    assert not state.compatible_with(128, 8)
    assert not state.compatible_with(127, 4)
    sfc = partition(PartitionProblem(points=pts, k=4), method="sfc")
    with pytest.raises(ValueError, match="no centers"):
        WarmState.capture(sfc)


def test_fill_slots_helper():
    padded, valid = fill_slots(["a"], 3)
    assert padded == ["a", "a", "a"]
    assert list(valid) == [True, False, False]
    with pytest.raises(ValueError):
        fill_slots([], 3)


def test_request_stream_generator():
    from repro.core.meshes import WORKLOADS
    probs = [PartitionProblem(points=_pts(40, i), k=2, seed=i)
             for i in range(2)]
    steps = list(request_stream(probs, WORKLOADS["drifting_hotspot"](), 3))
    assert len(steps) == 3 and all(len(b) == 2 for b in steps)
    # weights drift, identity stays fixed
    assert not np.array_equal(steps[0][0].weights, steps[2][0].weights)
    assert steps[0][1].tenant == steps[2][1].tenant == 1
    assert np.array_equal(steps[0][0].points, steps[2][0].points)


# -- the serving regression gate -------------------------------------------

def _gate(cur, base=None, gate_time=False):
    import os
    import sys
    tools = os.path.join(os.path.dirname(__file__), os.pardir, "tools")
    if tools not in sys.path:
        sys.path.insert(0, tools)
    from bench_compare import Report, compare_serving
    rep = Report()
    compare_serving(base or cur, cur, rep, gate_time, 1.0)
    return rep


def _fake_serving(**over):
    summary = {
        "iters_ratio": 9.0, "warm_mean_iters": 2.5, "cold_mean_iters": 22.0,
        "warm_hit_rate": 0.875, "warm_all_balanced": True,
        "cold_all_balanced": True, "problems_per_s": 100.0, "p50_ms": 10.0,
        "p99_ms": 40.0, "measured_steps": 6, "requests_measured": 24,
        "requests_total": 32,
    }
    summary.update(over.pop("summary", {}))
    out = {"quick": True, "steps": 8, "slots": 2, "tiers": [1024],
           "workload": "drifting_hotspot",
           "tenants": [{"tenant": 0, "n": 1800, "k": 8}],
           "summary": summary}
    out.update(over)
    return out


def test_gate_accepts_self_compare():
    assert _gate(_fake_serving()).failures == []


def test_gate_rejects_planted_regressions():
    assert _gate(_fake_serving(summary={"iters_ratio": 2.0})).failures
    assert _gate(_fake_serving(summary={"warm_hit_rate": 0.5})).failures
    assert _gate(_fake_serving(summary={"cold_all_balanced": False})).failures
    assert _gate(_fake_serving(steps=6), base=_fake_serving()).failures
    missing = _fake_serving()
    del missing["summary"]["p99_ms"]
    assert _gate(missing).failures


def test_gate_wall_clock_soft_unless_gate_time():
    slow = _fake_serving(summary={"p99_ms": 400.0, "problems_per_s": 5.0})
    rep = _gate(slow, base=_fake_serving())
    assert rep.failures == [] and len(rep.rows) == 2   # warnings only
    rep = _gate(slow, base=_fake_serving(), gate_time=True)
    assert len(rep.failures) == 2


def test_gate_accepts_checked_in_baseline():
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "baselines", "BENCH_serving.json")
    with open(path) as f:
        base = json.load(f)
    assert _gate(base).failures == []
