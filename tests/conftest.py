import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))
# repo root too: the spmdlint tests import the linter as tools.spmdlint,
# exactly the way CI invokes it (python -m tools.spmdlint ...)
sys.path.insert(1, os.path.join(os.path.dirname(__file__), os.pardir))

# Give CPU-only runners 8 virtual jax devices so the multi-device
# (shard_map) tests run in-process. Must happen before the first jax
# import — conftest.py loads before any test module, and nothing above
# this line imports jax (repro.envflags is jax-free by design).
from repro.envflags import force_virtual_devices  # noqa: E402

force_virtual_devices(8)

# tests/_stubs also hosts the shared slot/mask test utilities
# (slot_utils) used by the serving test suites, and the deterministic
# hypothesis fallback package. Appending (not prepending) keeps a real
# installed hypothesis winning over the stub.
sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
