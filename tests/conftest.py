import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    # minimal container: fall back to the deterministic fixed-example stub
    # (see requirements-dev.txt for the real thing)
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
