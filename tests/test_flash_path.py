"""Pure-JAX flash/banded attention paths vs the dense oracle, and the
HLO liveness-peak estimator used by the dry-run fit-check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import layers as L

MESH = make_host_mesh()


def _dense_ref(q, k, v, cfg, kind="full"):
    S = q.shape[1]
    scores = L._gqa_scores(q, k, cfg)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if kind == "swa":
        mask &= (qpos - kpos) < cfg.window
    if cfg.logit_softcap:
        scores = jnp.tanh(scores / cfg.logit_softcap) * cfg.logit_softcap
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    B, _, H, dh = q.shape
    return out.reshape(B, S, H, dh)


def _qkv(B, S, H, KV, dh, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    return mk(B, S, H, dh), mk(B, S, KV, dh), mk(B, S, KV, dh)


@pytest.mark.parametrize("seq_sharded", [False, True])
@pytest.mark.parametrize("unroll", [False, True])
def test_flash_full_matches_dense(monkeypatch, seq_sharded, unroll):
    monkeypatch.setattr(L, "_QC", 32)
    monkeypatch.setattr(L, "_KVC", 32)
    cfg = configs.get_config("phi4_mini_3p8b", smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    table = dict(rules.table, act_seq="model" if seq_sharded else None)
    import dataclasses
    rules = dataclasses.replace(rules, table=table)
    B, S, H, KV, dh = 2, 128, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _qkv(B, S, H, KV, dh)
    out = L._flash_full(q, k, v, cfg, rules, unroll_chunks=unroll)
    ref = _dense_ref(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_full_grad_matches_dense(monkeypatch):
    monkeypatch.setattr(L, "_QC", 32)
    monkeypatch.setattr(L, "_KVC", 32)
    cfg = configs.get_config("phi3_mini_3p8b", smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    B, S, H, KV, dh = 1, 64, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = _qkv(B, S, H, KV, dh, seed=1)
    g1 = jax.grad(lambda q: jnp.sum(
        L._flash_full(q, k, v, cfg, rules) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(_dense_ref(q, k, v, cfg) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)


def test_local_band_matches_dense_swa():
    cfg = configs.get_config("gemma3_1b", smoke=True)   # window=8
    B, S = 2, 64
    import dataclasses
    cfg = dataclasses.replace(cfg, window=8)
    q, k, v = _qkv(B, S, cfg.n_heads, cfg.n_kv_heads, cfg.hd, seed=2)
    # bc floor is max(window, 1024); patch via tiny local version
    import repro.models.layers as LL
    orig = LL._local_band
    out = None

    def banded(q, k, v, cfg, bc=16):
        B, S, H, dh = q.shape
        KV = k.shape[2]
        G = H // KV
        f32 = jnp.float32
        nb = S // bc
        qb = q.reshape(B, nb, bc, KV, G, dh)
        kb = k.reshape(B, nb, bc, KV, dh)
        vb = v.reshape(B, nb, bc, KV, dh).astype(f32)
        zk = jnp.zeros_like(kb[:, :1])
        zv = jnp.zeros_like(vb[:, :1])
        kcat = jnp.concatenate([jnp.concatenate([zk, kb[:, :-1]], 1), kb], 2)
        vcat = jnp.concatenate([jnp.concatenate([zv, vb[:, :-1]], 1), vb], 2)
        s = jnp.einsum("bnqkgd,bntkd->bnkgqt", qb, kcat,
                       preferred_element_type=f32) * (dh ** -0.5)
        rel = (bc + jnp.arange(bc))[:, None] - jnp.arange(2 * bc)[None, :]
        mask0 = (rel >= 0) & (rel < cfg.window)
        first = jnp.arange(2 * bc)[None, :] >= bc
        mask = jnp.where(jnp.arange(nb)[:, None, None] == 0,
                         mask0[None] & first[None], mask0[None])
        s = jnp.where(mask[None, :, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bnkgqt,bntkd->bnqkgd", p, vcat,
                       preferred_element_type=f32)
        return o.reshape(B, S, H, dh)

    out = banded(q, k, v, cfg, bc=16)
    ref = _dense_ref(q, k, v, cfg, kind="swa")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# liveness-peak estimator
# ---------------------------------------------------------------------------

def test_hlo_peak_sequential_scan_bounded():
    """A scan whose body allocates a 16MB temp must show ~1-2 temps of
    peak, not trip_count x 16MB."""
    from repro.launch.hlo_mem import peak_temp_bytes

    def f(x, w):
        def body(acc, xi):
            return acc + xi @ w, None
        acc, _ = jax.lax.scan(body, jnp.zeros((2048, 2048), jnp.float32), x)
        return acc

    x = jax.ShapeDtypeStruct((8, 2048, 2048), jnp.float32)
    w = jax.ShapeDtypeStruct((2048, 2048), jnp.float32)
    co = jax.jit(f).lower(x, w).compile()
    pk = peak_temp_bytes(co.as_text())
    assert pk < 4 * 2048 * 2048 * 4, f"peak {pk/2**20:.0f}MB too high"


def test_hlo_peak_parallel_counts_all():
    from repro.launch.hlo_mem import peak_temp_bytes

    def f(x, w):
        prods = [x[i] @ w for i in range(8)]
        out = prods[0]
        for p in prods[1:]:
            out = out + p
        return out

    x = jax.ShapeDtypeStruct((8, 1024, 1024), jnp.float32)
    w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    co = jax.jit(f).lower(x, w).compile()
    pk = peak_temp_bytes(co.as_text())
    assert pk >= 2 * 1024 * 1024 * 4      # at least a couple live products
