"""Pallas assignment kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import assign_argmin, assign_argmin_jnp, segment_moments
from repro.kernels.ref import assign_argmin_ref


def _rand(n, k, d, seed=0, spread=1.0):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.uniform(0, spread, (n, d)), jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, spread, (k, d)), jnp.float32)
    infl = jnp.asarray(rng.uniform(0.5, 2.0, (k,)), jnp.float32)
    return pts, ctr, infl


@pytest.mark.parametrize("n,k,d,bp,bc", [
    (1024, 64, 2, 256, 32),
    (2048, 128, 3, 512, 128),
    (777, 33, 2, 256, 32),      # padding on both axes
    (512, 16, 16, 128, 16),     # MoE-routing-like dims
    (256, 8, 128, 128, 8),      # high-dim (token-embedding routing)
    (4096, 512, 2, 1024, 128),  # production tile shape
])
def test_kernel_matches_ref(n, k, d, bp, bc):
    pts, ctr, infl = _rand(n, k, d)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)


def test_kernel_uniform_influence_is_plain_kmeans():
    """influence == 1 must reduce to vanilla nearest-center assignment."""
    pts, ctr, _ = _rand(512, 32, 2, seed=3)
    infl = jnp.ones(32, jnp.float32)
    i1, b1, _ = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    d = jnp.sum((pts[:, None] - ctr[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(jnp.argmin(d, 1)))


def test_kernel_influence_monotonicity():
    """Raising one cluster's influence can only gain it points (weighted
    Voronoi property the balancing loop relies on)."""
    pts, ctr, infl = _rand(2048, 16, 2, seed=4)
    i_before, _, _ = assign_argmin(pts, ctr, infl, block_p=512, block_c=16)
    infl2 = infl.at[3].mul(1.5)
    i_after, _, _ = assign_argmin(pts, ctr, infl2, block_p=512, block_c=16)
    before = set(np.where(np.asarray(i_before) == 3)[0].tolist())
    after = set(np.where(np.asarray(i_after) == 3)[0].tolist())
    assert before.issubset(after)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.sampled_from([(130, 17, 2), (257, 9, 3), (96, 5, 4)]))
def test_kernel_property_random(seed, shape):
    n, k, d = shape
    pts, ctr, infl = _rand(n, k, d, seed=seed)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=64, block_c=8)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    # argmin ties can differ; compare effective distances instead
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.mean((i1 == i0).astype(jnp.float32))) > 0.99


def test_second_best_greater_equal_best():
    pts, ctr, infl = _rand(1024, 64, 2, seed=7)
    _, b, s = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    assert bool(jnp.all(s >= b - 1e-7))


# ---------------------------------------------------------------------------
# padded (_FAR) center masking
# ---------------------------------------------------------------------------

def test_k1_second_is_exact_inf():
    """k == 1: every point's second-best would be a _FAR padding center.
    The kernel must mask those to exactly +inf (not a huge finite value,
    not NaN) so the Hamerly guard in assign_effective fires."""
    pts, _, _ = _rand(256, 1, 2, seed=11)
    ctr = jnp.asarray([[0.4, 0.6]], jnp.float32)
    infl = jnp.ones(1, jnp.float32)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=256, block_c=8)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), 0)
    assert bool(jnp.all(jnp.isinf(s1))) and bool(jnp.all(jnp.isinf(s0)))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,k,bc", [(256, 3, 8), (512, 9, 8), (300, 1, 128)])
def test_padded_centers_large_coordinates(n, k, bc):
    """Regression: with coordinates large enough that 2*p@c overflows
    against the _FAR padding rows, ``|p|^2 + |c|^2 - 2 p@c`` became
    ``inf - inf = NaN`` and corrupted argmin AND second-best (observed:
    ~51% wrong labels). The k_real mask must keep padded centers out of
    the distance math entirely."""
    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.uniform(0, 1, (n, 2)) * 1e9, jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, 1, (k, 2)) * 1e9, jnp.float32)
    infl = jnp.ones(k, jnp.float32)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=256, block_c=bc)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    assert not bool(jnp.isnan(b1).any()) and not bool(jnp.isnan(s1).any())
    # |p|^2+|c|^2-2p.c cancels catastrophically at 1e9-scale coordinates,
    # so the two matmul orders only agree loosely; the test's subject is
    # the NaN/label corruption, not the conditioning
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0), rtol=1e-2)


# ---------------------------------------------------------------------------
# fused assign+reduce (return_moments=True)
# ---------------------------------------------------------------------------

def _moments_ref(pts, w, idx, best_sq, k):
    csum = np.zeros((k, pts.shape[1]))
    cw = np.zeros(k)
    rad2 = np.zeros(k)
    np.add.at(csum, idx, np.asarray(w)[:, None] * np.asarray(pts))
    np.add.at(cw, idx, np.asarray(w))
    np.add.at(rad2, idx, np.asarray(w) * np.asarray(best_sq))
    return csum, cw, rad2


@pytest.mark.parametrize("n,chunk", [(500, 65536), (5000, 1024)])
def test_jnp_fused_bitexact_vs_unfused(n, chunk):
    """The jnp backend's fused moments must equal the unfused
    assignment + segment_moments fallback BIT-FOR-BIT (they share the
    per-chunk one-hot reduction), single- and multi-chunk."""
    pts, ctr, infl = _rand(n, 7, 2, seed=13)
    w = jnp.asarray(np.random.default_rng(13).uniform(0.5, 2.0, n),
                    jnp.float32)
    iF, bF, sF, csum, cw, rad2 = assign_argmin_jnp(
        pts, ctr, infl, chunk=chunk, weights=w, return_moments=True)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl, chunk=chunk)
    m0 = segment_moments(pts, w, i0, b0, 7, chunk=chunk)
    for a, b in zip((iF, bF, sF, csum, cw, rad2), (i0, b0, s0) + m0):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the moments are the right quantities (float64 oracle)
    cs, cn_, r2 = _moments_ref(pts, w, np.asarray(i0), b0, 7)
    np.testing.assert_allclose(np.asarray(csum), cs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cw), cn_, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rad2), r2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k,bp,bc", [
    (2000, 9, 256, 8),       # multi point-tile, padded center tile
    (1024, 64, 256, 32),     # multi center-tile
    (300, 1, 128, 128),      # k == 1
])
def test_pallas_fused_moments_match_jnp(n, k, bp, bc):
    """The Pallas kernel's VMEM-accumulated moments agree with the jnp
    reference (f32 tile order differs, so tolerance not bitwise); the
    assignment itself must be identical."""
    pts, ctr, infl = _rand(n, k, 2, seed=17)
    w = jnp.asarray(np.random.default_rng(17).uniform(0.5, 2.0, n),
                    jnp.float32)
    pf = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc,
                       weights=w, return_moments=True)
    jf = assign_argmin_jnp(pts, ctr, infl, weights=w, return_moments=True)
    np.testing.assert_array_equal(np.asarray(pf[0]), np.asarray(jf[0]))
    for a, b in zip(pf[3:], jf[3:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    # fused and plain pallas agree on the assignment triple
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc)
    np.testing.assert_array_equal(np.asarray(pf[0]), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(pf[1]), np.asarray(b1))
    np.testing.assert_array_equal(np.asarray(pf[2]), np.asarray(s1))


def test_fused_moments_ignore_zero_weight_padding():
    """Zero-weight (padded) points must contribute nothing to any moment
    — the sharded driver relies on this for its weight-0 slot padding."""
    pts, ctr, infl = _rand(400, 5, 2, seed=19)
    w = jnp.asarray(np.r_[np.ones(300), np.zeros(100)], jnp.float32)
    _, _, _, csum, cw, rad2 = assign_argmin_jnp(
        pts, ctr, infl, weights=w, return_moments=True)
    _, _, _, csum2, cw2, rad22 = assign_argmin_jnp(
        pts[:300], ctr, infl, weights=w[:300], return_moments=True)
    np.testing.assert_allclose(np.asarray(csum), np.asarray(csum2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cw), np.asarray(cw2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rad2), np.asarray(rad22),
                               rtol=1e-6, atol=1e-6)
