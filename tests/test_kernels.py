"""Pallas assignment kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import (assign_argmin, assign_argmin_jnp,
                               assign_backend, segment_moments,
                               tile_prune_fraction)
from repro.kernels.ref import assign_argmin_ref

# moments-capable non-jnp backends, checked against the jnp oracle
KERNEL_BACKENDS = ("pallas", "triton")


def _rand(n, k, d, seed=0, spread=1.0):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.uniform(0, spread, (n, d)), jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, spread, (k, d)), jnp.float32)
    infl = jnp.asarray(rng.uniform(0.5, 2.0, (k,)), jnp.float32)
    return pts, ctr, infl


@pytest.mark.parametrize("n,k,d,bp,bc", [
    (1024, 64, 2, 256, 32),
    (2048, 128, 3, 512, 128),
    (777, 33, 2, 256, 32),      # padding on both axes
    (512, 16, 16, 128, 16),     # MoE-routing-like dims
    (256, 8, 128, 128, 8),      # high-dim (token-embedding routing)
    (4096, 512, 2, 1024, 128),  # production tile shape
    # non-default tile sizes x d sweep: lock the VMEM-block revisiting
    # logic for shapes the default-config paths never touch
    (1024, 200, 2, 256, 128),
    (1024, 200, 3, 256, 128),
    (512, 200, 128, 256, 128),
    (2048, 300, 2, 1024, 256),
    (2048, 300, 3, 1024, 256),
    (1024, 300, 128, 1024, 256),
])
def test_kernel_matches_ref(n, k, d, bp, bc):
    pts, ctr, infl = _rand(n, k, d)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)


def test_kernel_uniform_influence_is_plain_kmeans():
    """influence == 1 must reduce to vanilla nearest-center assignment."""
    pts, ctr, _ = _rand(512, 32, 2, seed=3)
    infl = jnp.ones(32, jnp.float32)
    i1, b1, _ = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    d = jnp.sum((pts[:, None] - ctr[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(jnp.argmin(d, 1)))


def test_kernel_influence_monotonicity():
    """Raising one cluster's influence can only gain it points (weighted
    Voronoi property the balancing loop relies on)."""
    pts, ctr, infl = _rand(2048, 16, 2, seed=4)
    i_before, _, _ = assign_argmin(pts, ctr, infl, block_p=512, block_c=16)
    infl2 = infl.at[3].mul(1.5)
    i_after, _, _ = assign_argmin(pts, ctr, infl2, block_p=512, block_c=16)
    before = set(np.where(np.asarray(i_before) == 3)[0].tolist())
    after = set(np.where(np.asarray(i_after) == 3)[0].tolist())
    assert before.issubset(after)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.sampled_from([(130, 17, 2), (257, 9, 3), (96, 5, 4)]))
def test_kernel_property_random(seed, shape):
    n, k, d = shape
    pts, ctr, infl = _rand(n, k, d, seed=seed)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=64, block_c=8)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    # argmin ties can differ; compare effective distances instead
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.mean((i1 == i0).astype(jnp.float32))) > 0.99


def test_second_best_greater_equal_best():
    pts, ctr, infl = _rand(1024, 64, 2, seed=7)
    _, b, s = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    assert bool(jnp.all(s >= b - 1e-7))


# ---------------------------------------------------------------------------
# padded (_FAR) center masking
# ---------------------------------------------------------------------------

def test_k1_second_is_exact_inf():
    """k == 1: every point's second-best would be a _FAR padding center.
    The kernel must mask those to exactly +inf (not a huge finite value,
    not NaN) so the Hamerly guard in assign_effective fires."""
    pts, _, _ = _rand(256, 1, 2, seed=11)
    ctr = jnp.asarray([[0.4, 0.6]], jnp.float32)
    infl = jnp.ones(1, jnp.float32)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=256, block_c=8)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), 0)
    assert bool(jnp.all(jnp.isinf(s1))) and bool(jnp.all(jnp.isinf(s0)))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,k,bc", [(256, 3, 8), (512, 9, 8), (300, 1, 128)])
def test_padded_centers_large_coordinates(n, k, bc):
    """Regression: with coordinates large enough that 2*p@c overflows
    against the _FAR padding rows, ``|p|^2 + |c|^2 - 2 p@c`` became
    ``inf - inf = NaN`` and corrupted argmin AND second-best (observed:
    ~51% wrong labels). The k_real mask must keep padded centers out of
    the distance math entirely."""
    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.uniform(0, 1, (n, 2)) * 1e9, jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, 1, (k, 2)) * 1e9, jnp.float32)
    infl = jnp.ones(k, jnp.float32)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=256, block_c=bc)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    assert not bool(jnp.isnan(b1).any()) and not bool(jnp.isnan(s1).any())
    # |p|^2+|c|^2-2p.c cancels catastrophically at 1e9-scale coordinates,
    # so the two matmul orders only agree loosely; the test's subject is
    # the NaN/label corruption, not the conditioning
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0), rtol=1e-2)


# ---------------------------------------------------------------------------
# fused assign+reduce (return_moments=True)
# ---------------------------------------------------------------------------

def _moments_ref(pts, w, idx, best_sq, k):
    csum = np.zeros((k, pts.shape[1]))
    cw = np.zeros(k)
    rad2 = np.zeros(k)
    np.add.at(csum, idx, np.asarray(w)[:, None] * np.asarray(pts))
    np.add.at(cw, idx, np.asarray(w))
    np.add.at(rad2, idx, np.asarray(w) * np.asarray(best_sq))
    return csum, cw, rad2


@pytest.mark.parametrize("n,chunk", [(500, 65536), (5000, 1024)])
def test_jnp_fused_bitexact_vs_unfused(n, chunk):
    """The jnp backend's fused moments must equal the unfused
    assignment + segment_moments fallback BIT-FOR-BIT (they share the
    per-chunk one-hot reduction), single- and multi-chunk."""
    pts, ctr, infl = _rand(n, 7, 2, seed=13)
    w = jnp.asarray(np.random.default_rng(13).uniform(0.5, 2.0, n),
                    jnp.float32)
    iF, bF, sF, csum, cw, rad2 = assign_argmin_jnp(
        pts, ctr, infl, chunk=chunk, weights=w, return_moments=True)
    i0, b0, s0 = assign_argmin_jnp(pts, ctr, infl, chunk=chunk)
    m0 = segment_moments(pts, w, i0, b0, 7, chunk=chunk)
    for a, b in zip((iF, bF, sF, csum, cw, rad2), (i0, b0, s0) + m0):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the moments are the right quantities (float64 oracle)
    cs, cn_, r2 = _moments_ref(pts, w, np.asarray(i0), b0, 7)
    np.testing.assert_allclose(np.asarray(csum), cs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cw), cn_, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rad2), r2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", KERNEL_BACKENDS)
@pytest.mark.parametrize("n,k,d,bp,bc", [
    (2000, 9, 2, 256, 8),       # multi point-tile, padded center tile
    (1024, 64, 2, 256, 32),     # multi center-tile
    (300, 1, 2, 128, 128),      # k == 1
    # non-default tile sizes x d sweep (VMEM revisiting / in-kernel loop)
    (1024, 200, 3, 256, 128),
    (512, 200, 128, 256, 128),
    (2048, 300, 2, 1024, 256),
])
def test_kernel_fused_moments_match_jnp(backend, n, k, d, bp, bc):
    """Fused==unfused parity per kernel backend: the VMEM-accumulated
    (pallas) / split-k (triton) moments agree with the jnp reference
    (f32 accumulation order differs, so tolerance not bitwise); the
    assignment triple must be bit-identical between the backend's fused
    and plain modes."""
    if backend == "triton" and bc == 8:
        bc = 128                  # triton tiles centers at lane multiples
    pts, ctr, infl = _rand(n, k, d, seed=17)
    w = jnp.asarray(np.random.default_rng(17).uniform(0.5, 2.0, n),
                    jnp.float32)
    fn = assign_backend(backend)
    pf = fn(pts, ctr, infl, block_p=bp, block_c=bc,
            weights=w, return_moments=True)
    jf = assign_argmin_jnp(pts, ctr, infl, weights=w, return_moments=True)
    np.testing.assert_array_equal(np.asarray(pf[0]), np.asarray(jf[0]))
    for a, b in zip(pf[3:], jf[3:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    # fused and plain agree on the assignment triple
    i1, b1, s1 = fn(pts, ctr, infl, block_p=bp, block_c=bc)
    np.testing.assert_array_equal(np.asarray(pf[0]), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(pf[1]), np.asarray(b1))
    np.testing.assert_array_equal(np.asarray(pf[2]), np.asarray(s1))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from(KERNEL_BACKENDS),
       st.sampled_from([(130, 17, 2), (257, 9, 3)]))
def test_backend_fused_property(seed, backend, shape):
    """Property parity over backends: plain triple == fused triple, and
    fused moments match the jnp oracle."""
    n, k, d = shape
    pts, ctr, infl = _rand(n, k, d, seed=seed)
    w = jnp.asarray(np.random.default_rng(seed).uniform(0.5, 2.0, n),
                    jnp.float32)
    fn = assign_backend(backend)
    plain = fn(pts, ctr, infl, block_p=64, block_c=128)
    fused = fn(pts, ctr, infl, block_p=64, block_c=128,
               weights=w, return_moments=True)
    for a, b in zip(plain, fused[:3]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    jf = assign_argmin_jnp(pts, ctr, infl, weights=w, return_moments=True)
    for a, b in zip(fused[3:], jf[3:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# kernel-entry padding contract (wrapper-side ValueError, not bare assert)
# ---------------------------------------------------------------------------

def test_nonmultiple_n_at_kernel_entry_raises():
    """Regression: a non-tile-multiple n reaching the kernel entry points
    directly must raise a ValueError naming the offending shape, not trip
    a bare assert (or worse, silently mis-tile)."""
    from repro.kernels.assign_kernel import (assign_argmin_pallas,
                                             assign_reduce_pallas)
    from repro.kernels.triton_assign import triton_assign_pallas
    pts, ctr, infl = _rand(1000, 8, 2, seed=23)   # 1000 % 256 != 0
    inv2 = 1.0 / (infl * infl)
    bounds = jnp.zeros((4, 1), jnp.float32)
    with pytest.raises(ValueError, match=r"n=1000.*block_p=256"):
        assign_argmin_pallas(pts, ctr, inv2, bounds, k_real=8,
                             block_p=256, block_c=8)
    with pytest.raises(ValueError, match=r"n=1000.*block_p=256"):
        assign_reduce_pallas(pts, ctr, inv2, bounds, jnp.ones(1000),
                             k_real=8, block_p=256, block_c=8)
    with pytest.raises(ValueError, match=r"n=1000.*block_p=256"):
        triton_assign_pallas(pts, ctr, inv2, k_real=8,
                             block_p=256, block_c=8)
    with pytest.raises(ValueError, match=r"k=8.*block_c=128"):
        assign_argmin_pallas(pts[:768], ctr, inv2, bounds, k_real=8,
                             block_p=256, block_c=128)


# ---------------------------------------------------------------------------
# precision split (bf16 distance matmul, f32 accumulation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ("jnp",) + KERNEL_BACKENDS)
def test_bf16_precision_within_tolerance(backend):
    """DESIGN.md §4c tolerance contract: bf16 effective distances within
    rtol ~2^-7 of f32, labels flip only where the f32 best/second gap is
    inside that band, and fused moments stay f32-accumulated (close to
    the f32 moments wherever labels agree)."""
    pts, ctr, infl = _rand(2048, 32, 3, seed=29)
    fn = assign_backend(backend)
    i32, b32, s32 = fn(pts, ctr, infl, block_p=256, block_c=32)
    i16, b16, s16 = fn(pts, ctr, infl, block_p=256, block_c=32,
                       precision="bf16")
    flipped = np.asarray(i16) != np.asarray(i32)
    # bf16 mantissa error (~2^-8 per operand) on the cross term is
    # *absolute* in the operand-norm scale (|p|^2 + |c|^2 ~ O(1) here);
    # small distances see it amplified by cancellation, hence atol
    np.testing.assert_allclose(np.asarray(b16)[~flipped],
                               np.asarray(b32)[~flipped],
                               rtol=1e-2, atol=2e-2)
    if flipped.any():
        # flips only on near-ties: the f32 second/best gap sits inside the
        # bf16 error band, which is absolute at the operand-norm scale
        # (~2^-8 per operand on |p|^2+|c|^2 ~ O(1), times inv2 <= 4)
        gap = np.asarray(s32)[flipped] - np.asarray(b32)[flipped]
        assert float(gap.max()) <= 2.0 ** -6
    assert float(np.mean(flipped)) < 0.05


# ---------------------------------------------------------------------------
# double-buffered point-tile DMA (explicit opt-in under interpret)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d,bp,bc", [
    (2048, 64, 2, 256, 32),
    (1024, 200, 3, 256, 128),
    (2048, 300, 2, 1024, 256),
])
def test_double_buffer_matches_pipelined(n, k, d, bp, bc):
    """The manual two-slot DMA variant must be bit-identical to the
    automatically pipelined kernel — same tiles, same arithmetic, only
    the fetch schedule differs."""
    pts, ctr, infl = _rand(n, k, d, seed=31)
    w = jnp.asarray(np.random.default_rng(31).uniform(0.5, 2.0, n),
                    jnp.float32)
    a = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc,
                      double_buffer=False)
    b = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc,
                      double_buffer=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    af = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc, weights=w,
                       return_moments=True, double_buffer=False)
    bf = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc, weights=w,
                       return_moments=True, double_buffer=True)
    for x, y in zip(af, bf):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# adaptive default chunk + tile-prune statistic + env override
# ---------------------------------------------------------------------------

def test_adaptive_chunk_is_label_bitexact():
    """chunk only tiles the point axis -> per-point results are identical
    for ANY chunk; the adaptive default must be label/best/second
    bit-exact vs the former fixed 65536."""
    pts, ctr, infl = _rand(5000, 37, 2, seed=37)
    a = assign_argmin_jnp(pts, ctr, infl)                  # adaptive
    b = assign_argmin_jnp(pts, ctr, infl, chunk=65536)     # PR 4 default
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    from repro.kernels.ops import default_chunk
    assert default_chunk(64) == (1 << 19) // 64
    assert default_chunk(1) == 65536                       # clamp high
    assert default_chunk(10 ** 6) == 2048                  # clamp low


def test_tile_prune_fraction_statistic():
    """Clustered data with converged (tight) second-best distances must
    show prunable tiles; the statistic is in [0, 1], never counts the
    j == 0 tile, and is 0 when second-best is infinite (k == 1)."""
    rng = np.random.default_rng(41)
    # four tight blobs: two near pairs far apart, point-sorted so tiles
    # are spatially coherent.  Each point's second-best is its pair
    # partner (~1 away); the far pair's center tiles (bound ~100) are
    # prunable.  k=2 alone can never prune (the second IS the other
    # center), hence 4 centers here.
    xs = [0.0, 1.0, 10.0, 11.0]
    pts = jnp.asarray(np.concatenate(
        [rng.normal([x, 0.0], 0.05, (512, 2)) for x in xs]), jnp.float32)
    ctr = jnp.asarray([[x, 0.0] for x in xs], jnp.float32)
    infl = jnp.ones(4, jnp.float32)
    _, _, s = assign_argmin_jnp(pts, ctr, infl)
    frac = tile_prune_fraction(pts, ctr, infl, s, block_p=256, block_c=1)
    assert 0.0 < float(frac) <= 1.0
    frac1 = tile_prune_fraction(pts, ctr[:1], infl[:1],
                                jnp.full(2048, jnp.inf), 256, 128)
    assert float(frac1) == 0.0


def test_stats_expose_tiles_pruned_frac():
    from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans_jit
    rng = np.random.default_rng(43)
    pts = jnp.asarray(rng.uniform(0, 1, (3000, 2)), jnp.float32)
    _, _, _, st = balanced_kmeans_jit(pts, BKMConfig(k=4, block_p=256))
    frac = float(st["tiles_pruned_frac"])
    assert 0.0 <= frac <= 1.0


def test_env_override_resolves_auto(monkeypatch):
    from repro.kernels.ops import (backend_supports_moments,
                                   resolve_assign_backend)
    monkeypatch.setenv("REPRO_ASSIGN_BACKEND", "triton")
    assert resolve_assign_backend("auto") == "triton"
    assert backend_supports_moments("auto")
    # explicit names are NOT overridden
    assert resolve_assign_backend("jnp") == "jnp"
    monkeypatch.setenv("REPRO_ASSIGN_BACKEND", "nope")
    with pytest.raises(KeyError, match="REPRO_ASSIGN_BACKEND"):
        resolve_assign_backend("auto")


def test_auto_resolves_to_moments_capable_backend():
    """Acceptance: whatever auto resolves to (under any env combination
    CI runs) must be a registered, moments-capable backend."""
    from repro.kernels.ops import (_ASSIGN_BACKENDS,
                                   backend_supports_moments,
                                   resolve_assign_backend)
    name = resolve_assign_backend("auto")
    assert name in _ASSIGN_BACKENDS
    assert backend_supports_moments(name)
    assert backend_supports_moments("auto")


def test_fused_moments_ignore_zero_weight_padding():
    """Zero-weight (padded) points must contribute nothing to any moment
    — the sharded driver relies on this for its weight-0 slot padding."""
    pts, ctr, infl = _rand(400, 5, 2, seed=19)
    w = jnp.asarray(np.r_[np.ones(300), np.zeros(100)], jnp.float32)
    _, _, _, csum, cw, rad2 = assign_argmin_jnp(
        pts, ctr, infl, weights=w, return_moments=True)
    _, _, _, csum2, cw2, rad22 = assign_argmin_jnp(
        pts[:300], ctr, infl, weights=w[:300], return_moments=True)
    np.testing.assert_allclose(np.asarray(csum), np.asarray(csum2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cw), np.asarray(cw2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rad2), np.asarray(rad22),
                               rtol=1e-6, atol=1e-6)
