"""Pallas assignment kernel vs pure-jnp oracle: shape/dtype sweeps +
hypothesis property tests (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import assign_argmin
from repro.kernels.ref import assign_argmin_ref


def _rand(n, k, d, seed=0, spread=1.0):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.uniform(0, spread, (n, d)), jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, spread, (k, d)), jnp.float32)
    infl = jnp.asarray(rng.uniform(0.5, 2.0, (k,)), jnp.float32)
    return pts, ctr, infl


@pytest.mark.parametrize("n,k,d,bp,bc", [
    (1024, 64, 2, 256, 32),
    (2048, 128, 3, 512, 128),
    (777, 33, 2, 256, 32),      # padding on both axes
    (512, 16, 16, 128, 16),     # MoE-routing-like dims
    (256, 8, 128, 128, 8),      # high-dim (token-embedding routing)
    (4096, 512, 2, 1024, 128),  # production tile shape
])
def test_kernel_matches_ref(n, k, d, bp, bc):
    pts, ctr, infl = _rand(n, k, d)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=bp, block_c=bc)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)


def test_kernel_uniform_influence_is_plain_kmeans():
    """influence == 1 must reduce to vanilla nearest-center assignment."""
    pts, ctr, _ = _rand(512, 32, 2, seed=3)
    infl = jnp.ones(32, jnp.float32)
    i1, b1, _ = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    d = jnp.sum((pts[:, None] - ctr[None]) ** 2, -1)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(jnp.argmin(d, 1)))


def test_kernel_influence_monotonicity():
    """Raising one cluster's influence can only gain it points (weighted
    Voronoi property the balancing loop relies on)."""
    pts, ctr, infl = _rand(2048, 16, 2, seed=4)
    i_before, _, _ = assign_argmin(pts, ctr, infl, block_p=512, block_c=16)
    infl2 = infl.at[3].mul(1.5)
    i_after, _, _ = assign_argmin(pts, ctr, infl2, block_p=512, block_c=16)
    before = set(np.where(np.asarray(i_before) == 3)[0].tolist())
    after = set(np.where(np.asarray(i_after) == 3)[0].tolist())
    assert before.issubset(after)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6),
       st.sampled_from([(130, 17, 2), (257, 9, 3), (96, 5, 4)]))
def test_kernel_property_random(seed, shape):
    n, k, d = shape
    pts, ctr, infl = _rand(n, k, d, seed=seed)
    i1, b1, s1 = assign_argmin(pts, ctr, infl, block_p=64, block_c=8)
    i0, b0, s0 = assign_argmin_ref(pts, ctr, infl)
    # argmin ties can differ; compare effective distances instead
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b0),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s0),
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.mean((i1 == i0).astype(jnp.float32))) > 0.99


def test_second_best_greater_equal_best():
    pts, ctr, infl = _rand(1024, 64, 2, seed=7)
    _, b, s = assign_argmin(pts, ctr, infl, block_p=256, block_c=32)
    assert bool(jnp.all(s >= b - 1e-7))
