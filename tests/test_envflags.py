"""envflags.force_virtual_devices — the pre-jax-import entry point.

Every harness (tests/conftest.py, benchmarks/run.py, the examples) calls
this before the first jax import; its contract is pure environment-string
surgery, so it is testable without touching jax at all."""
import os

import pytest

from repro.envflags import _COUNT_FLAG, force_virtual_devices


@pytest.fixture
def xla_flags(monkeypatch):
    """Sandbox XLA_FLAGS; returns a reader for its current value."""
    def read():
        return os.environ.get("XLA_FLAGS", "")
    return read


def test_sets_flag_when_unset(monkeypatch, xla_flags):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    force_virtual_devices(8)
    assert xla_flags() == f"{_COUNT_FLAG}=8"


def test_appends_to_existing_operator_flags(monkeypatch, xla_flags):
    monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_enable_fast_math=false")
    force_virtual_devices(4)
    assert xla_flags() == (
        f"--xla_cpu_enable_fast_math=false {_COUNT_FLAG}=4")


def test_existing_count_flag_wins_without_override(monkeypatch, xla_flags):
    operator = f"{_COUNT_FLAG}=2 --xla_dump_to=/tmp/x"
    monkeypatch.setenv("XLA_FLAGS", operator)
    force_virtual_devices(8)
    assert xla_flags() == operator          # exact no-op


def test_override_replaces_only_the_count_flag(monkeypatch, xla_flags):
    monkeypatch.setenv(
        "XLA_FLAGS",
        f"--xla_dump_to=/tmp/x {_COUNT_FLAG}=2 --xla_cpu_use_thunks=true")
    force_virtual_devices(16, override=True)
    flags = xla_flags().split()
    # the other operator flags survive, in order, exactly once
    assert flags[:2] == ["--xla_dump_to=/tmp/x", "--xla_cpu_use_thunks=true"]
    assert flags[2:] == [f"{_COUNT_FLAG}=16"]


def test_repeated_calls_are_idempotent(monkeypatch, xla_flags):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    force_virtual_devices(8)
    first = xla_flags()
    force_virtual_devices(8)
    force_virtual_devices(4)                 # existing flag wins
    assert xla_flags() == first


def test_override_from_unset_is_clean(monkeypatch, xla_flags):
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    force_virtual_devices(3, override=True)
    assert xla_flags() == f"{_COUNT_FLAG}=3"
