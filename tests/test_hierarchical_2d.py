"""2-D device mesh: ``devices=(P1, P2)`` vs the flat ``devices=P1*P2``.

The bit-identity contract (dist/rules.py, partition/distributed.py,
partition/batched.py, DESIGN.md §13):

* Flat solve — the points shard over the *product* of the
  ("coarse", "refine") axes, and every psum/pmax names the axis tuple,
  which reduces over exactly the same device set in the same order as
  the flat 1-D mesh. Labels, centers and influence are bit-for-bit
  identical to ``devices=P1*P2``.
* Hierarchical solve — the coarse cut runs the same product-sharded
  trace; the k1 refinements deal over the refine axis, where each block
  runs the *same local trace* as the host ``vmap``
  (``sharded_batched_balanced_kmeans``, psum-budget=0: refinement is
  communication-free). Bit-for-bit identical to ``devices=P1*P2``
  (coarse sharded + host-vmap refinement), including when k1 is not a
  multiple of P2 (padding with copies of block 0, outputs dropped).
"""
import jax
import numpy as np
import pytest

from repro.core import meshes
from repro.core.balanced_kmeans import BKMConfig
from repro.dist.rules import partition_mesh2d
from repro.partition import PartitionProblem, partition
from repro.partition.batched import (batched_balanced_kmeans,
                                     build_refinement_batch,
                                     sharded_batched_balanced_kmeans)

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(5)
    n = 4099
    return PartitionProblem(points=rng.random((n, 2)),
                            weights=rng.uniform(0.5, 2.0, n),
                            k=8, epsilon=0.05, seed=7)


@pytest.fixture(scope="module")
def mesh_problem():
    mesh = meshes.REGISTRY["delaunay2d"](4096, seed=0)
    return PartitionProblem.from_mesh(mesh, k=8, epsilon=0.03)


class TestMesh2dConstruction:
    def test_axis_names_and_shape(self):
        mesh = partition_mesh2d(2, 4)
        assert mesh.axis_names == ("coarse", "refine")
        assert mesh.devices.shape == (2, 4)

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            partition_mesh2d(0, 4)
        with pytest.raises(ValueError):
            partition_mesh2d(2, 0)

    def test_rejects_oversubscription(self):
        with pytest.raises(ValueError, match="device"):
            partition_mesh2d(64, 64)


@needs8
class TestFlat2dBitIdentity:
    @pytest.mark.parametrize("shape", [(2, 4), (4, 2), (8, 1), (1, 8)])
    def test_labels_match_flat_eight(self, problem, shape):
        flat = partition(problem, devices=8)
        two = partition(problem, devices=shape)
        assert np.array_equal(flat.labels, two.labels)
        assert np.array_equal(flat.centers, two.centers)
        assert np.array_equal(flat.influence, two.influence)

    def test_product_four_matches_flat_four(self, problem):
        flat = partition(problem, devices=4)
        two = partition(problem, devices=(2, 2))
        assert np.array_equal(flat.labels, two.labels)

    def test_stats_record_mesh_shape(self, problem):
        res = partition(problem, devices=(2, 4))
        assert res.stats["devices"] == [2, 4]

    def test_chunk_composes_with_mesh2d(self, problem):
        a = partition(problem, devices=(2, 4))
        b = partition(problem, devices=(2, 4), chunk=13)
        assert np.array_equal(a.labels, b.labels)


@needs8
class TestHierarchical2dBitIdentity:
    @pytest.mark.parametrize("hier", [(4, 2), (2, 4)])
    def test_hierarchy_matches_flat_devices(self, problem, hier):
        flat = partition(problem, hierarchy=hier, devices=8)
        two = partition(problem, hierarchy=hier, devices=(2, 4))
        assert np.array_equal(flat.labels, two.labels)

    def test_refine_stats_record_mesh(self, problem):
        res = partition(problem, hierarchy=(4, 2), devices=(2, 4))
        assert res.stats["levels"][1]["refine_devices"] == [2, 4]
        assert res.stats["levels"][0]["devices"] == [2, 4]

    def test_quality_mesh_matches_flat(self, mesh_problem):
        flat = partition(mesh_problem, hierarchy=(4, 2), devices=8,
                         evaluate=True)
        two = partition(mesh_problem, hierarchy=(4, 2), devices=(2, 4),
                        evaluate=True)
        assert np.array_equal(flat.labels, two.labels)
        assert flat.quality["cut"] == two.quality["cut"]
        assert two.imbalance() <= mesh_problem.epsilon + 1e-9


@needs8
class TestShardedBatchedRefinement:
    """sharded_batched_balanced_kmeans == batched_balanced_kmeans."""

    def _batch(self, problem, k1):
        # carve the problem into k1 coarse blocks and refine each into
        # k2 = k / k1 sub-blocks, exactly as hierarchical_partition does
        from repro.core.partitioner import sfc_initial_centers
        from repro.partition.algorithms import make_bkm_config
        k2 = problem.k // k1
        coarse = partition(problem.replace(k=k1), devices=8)
        cfg = make_bkm_config(problem, k=k2, warmup=False)
        bpts, bw, gather, counts = build_refinement_batch(
            problem.points, problem.weights, np.asarray(coarse.labels),
            k1)
        w_host = np.asarray(problem.weights, np.float64)
        centers0 = np.stack([
            sfc_initial_centers(bpts[b, :counts[b]], k2,
                                w_host[gather[b, :counts[b]]])
            for b in range(k1)])
        target = problem.total_weight / (k1 * k2)
        return bpts, bw, centers0, target, cfg

    @pytest.mark.parametrize("k1", [4, 2])
    def test_bitexact_vs_host_vmap(self, problem, k1):
        bpts, bw, centers0, target, cfg = self._batch(problem, k1)
        host = batched_balanced_kmeans(bpts, bw, centers0, cfg,
                                       target_weight=target)
        shrd = sharded_batched_balanced_kmeans(bpts, bw, centers0, cfg,
                                               devices=(2, 4),
                                               target_weight=target)
        for h, s in zip(host[:3], shrd[:3]):
            assert np.array_equal(np.asarray(h), np.asarray(s))

    def test_padded_batch_bitexact(self):
        # B=3 blocks over P2=4 refine devices: padded with block 0,
        # padding outputs dropped — results still bit-exact and B-sized
        rng = np.random.default_rng(9)
        B, m, k2 = 3, 256, 2
        bpts = rng.random((B, m, 2))
        bw = rng.uniform(0.5, 2.0, (B, m))
        centers0 = bpts[:, :k2, :].copy()
        cfg = BKMConfig(k=k2, epsilon=0.05, warmup=False)
        host = batched_balanced_kmeans(bpts, bw, centers0, cfg)
        shrd = sharded_batched_balanced_kmeans(bpts, bw, centers0, cfg,
                                               devices=(2, 4))
        assert np.asarray(shrd[0]).shape == (B, m)
        for h, s in zip(host[:3], shrd[:3]):
            assert np.array_equal(np.asarray(h), np.asarray(s))
        hleaves, hdef = jax.tree.flatten(host[3])
        sleaves, sdef = jax.tree.flatten(shrd[3])
        assert hdef == sdef
        for h, s in zip(hleaves, sleaves):
            assert np.array_equal(np.asarray(h), np.asarray(s))
