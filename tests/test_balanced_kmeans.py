"""Balanced k-means system invariants (paper Sections 4-5)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.balanced_kmeans import (BKMConfig, adapt_influence,
                                        erode_influence, assign_effective)
from repro.core.partitioner import geographer_partition
from repro.core import metrics


def test_balance_achieved_uniform():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (8000, 2))
    k = 16
    part, stats = geographer_partition(pts, k, return_stats=True)
    assert stats["final_imbalance"] <= 0.03 + 1e-6
    assert len(np.unique(part)) == k


def test_balance_achieved_heterogeneous():
    """Paper §4.2: heterogeneous densities need erosion; balance must hold."""
    rng = np.random.default_rng(1)
    dense = rng.normal(0.2, 0.03, (6000, 2))
    sparse = rng.uniform(0, 1, (2000, 2))
    pts = np.concatenate([dense, sparse])
    part, stats = geographer_partition(pts, 8, return_stats=True)
    assert stats["final_imbalance"] <= 0.03 + 1e-6


def test_balance_weighted_25d():
    """2.5D case: node weights (vertical columns) must balance, not counts."""
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 1, (6000, 2))
    w = 1.0 + 30.0 * np.exp(-((pts - 0.5) ** 2).sum(1) / 0.02)
    k = 8
    part = geographer_partition(pts, k, weights=w)
    imb = metrics.imbalance(part, k, w)
    assert imb <= 0.05  # weighted balance

def test_3d_balance():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (8000, 3))
    part, stats = geographer_partition(pts, 8, return_stats=True)
    assert stats["final_imbalance"] <= 0.03 + 1e-6


def test_skip_fraction_matches_paper_claim():
    """Paper §4.3: bounds skip the inner loop in ~80% of cases, more in
    later phases."""
    rng = np.random.default_rng(4)
    pts = rng.uniform(0, 1, (20000, 2))
    _, stats = geographer_partition(pts, 16, return_stats=True)
    h = stats["history"]["skip_fraction"]
    it = int(stats["iters"])
    late = h[max(it - 5, 0):it]
    assert late.mean() > 0.6, f"late-phase skip fraction too low: {late}"
    # later phases skip more than the first post-warmup rounds on average
    assert h[:it][-3:].mean() >= h[:it][:3].mean() - 0.1


def test_influence_update_direction():
    """Eq. (1) corrected: oversized -> influence down, undersized -> up."""
    infl = jnp.ones(3)
    sizes = jnp.array([2.0, 1.0, 0.5])
    target = jnp.array(1.0)
    new, factor = adapt_influence(infl, sizes, target, d_eff=2, clip=0.05)
    assert new[0] < 1.0 and new[2] > 1.0 and abs(new[1] - 1.0) < 1e-6
    # 5% clip respected
    assert jnp.all(jnp.abs(new / infl - 1.0) <= 0.05 + 1e-6)


def test_erosion_limits():
    """Eqs. (2)-(3): no movement -> unchanged; huge movement -> back to ~1."""
    infl = jnp.array([4.0, 0.25])
    same = erode_influence(infl, jnp.zeros(2), jnp.array(1.0))
    np.testing.assert_allclose(np.asarray(same), np.asarray(infl), rtol=1e-6)
    far = erode_influence(infl, jnp.full(2, 100.0), jnp.array(1.0))
    np.testing.assert_allclose(np.asarray(far), 1.0, atol=0.05)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_bounds_soundness(seed):
    """Hamerly property: whenever ub < lb, the cached assignment equals the
    freshly computed one (this is what makes the skip correct)."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.uniform(0, 1, (500, 2)), jnp.float32)
    ctr = jnp.asarray(rng.uniform(0, 1, (8, 2)), jnp.float32)
    infl = jnp.asarray(rng.uniform(0.8, 1.25, (8,)), jnp.float32)
    idx, best, second = assign_effective(pts, ctr, infl)
    # simulate a small center movement + influence change, relax bounds
    delta = jnp.asarray(rng.uniform(0, 0.02, (8,)), jnp.float32)
    moved = ctr + delta[:, None] / np.sqrt(2)
    infl_new = infl * jnp.asarray(rng.uniform(0.96, 1.04, (8,)), jnp.float32)
    ratio = infl / infl_new
    ub = best * ratio[idx] + delta[idx] / infl_new[idx]
    lb = jnp.maximum(second * jnp.min(ratio) - jnp.max(delta / infl_new), 0.0)
    idx2, _, _ = assign_effective(pts, moved, infl_new)
    skip = np.asarray(ub < lb)
    same = np.asarray(idx == idx2)
    assert np.all(same[skip]), "bound-skipped point changed cluster!"


def test_final_assignment_exact_not_sampled():
    """The returned assignment must cover all points (warm-up sampling must
    not leak into the final result)."""
    rng = np.random.default_rng(6)
    pts = rng.uniform(0, 1, (5000, 2))
    part = geographer_partition(pts, 4)
    assert part.shape == (5000,)
    assert set(np.unique(part)) <= set(range(4))


def test_voronoi_compactness_vs_sfc():
    """Shape quality: balanced k-means blocks should have smaller average
    spatial radius than SFC chunks (the paper's motivation)."""
    from repro.core.baselines import sfc_partition
    rng = np.random.default_rng(7)
    pts = rng.uniform(0, 1, (20000, 2))
    k = 16

    def mean_radius(part):
        r = 0.0
        for b in range(k):
            sub = pts[part == b]
            r += np.linalg.norm(sub - sub.mean(0), axis=1).mean()
        return r / k

    pg = geographer_partition(pts, k)
    ps = sfc_partition(pts, k)
    assert mean_radius(pg) <= mean_radius(ps) * 1.05


def test_use_kernel_deprecated_maps_to_pallas_backend():
    """The legacy flag must warn and keep its meaning: backend='pallas'."""
    with pytest.warns(DeprecationWarning, match="use_kernel"):
        cfg = BKMConfig(k=4, use_kernel=True)
    assert cfg.assign_backend == "pallas"
    # the replacement spelling is warning-free
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg2 = BKMConfig(k=4, backend="pallas")
    assert cfg2.assign_backend == "pallas"
