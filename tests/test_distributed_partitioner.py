"""Distributed (shard_map) Geographer: runs in a subprocess with 8 fake
devices so the main test process keeps a single device."""
import subprocess
import sys
import os
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.partitioner import make_distributed_partitioner
    from repro.core.balanced_kmeans import BKMConfig

    mesh = jax.make_mesh((8,), ('data',))
    k = 16
    run = make_distributed_partitioner(mesh, BKMConfig(k=k, max_iter=20))
    rng = np.random.default_rng(0)
    n = 16384
    pts = jnp.asarray(rng.uniform(0, 1, (n, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, (n,)), jnp.float32)
    A, rp, rv, centers, infl, imb, dropped = run(pts, w)
    A, rv = np.asarray(A), np.asarray(rv)
    assert int(dropped) == 0, f"redistribution dropped {int(dropped)} points"
    assert A[rv].size == n, "points lost in the bucket exchange"
    assert float(imb) <= 0.05, f"imbalance {float(imb)}"
    sizes = np.bincount(A[rv], minlength=k, weights=np.asarray(rv, np.float64)[rv] * 0 + 1)
    assert (sizes > 0).all(), "empty block"
    # spatial locality: each shard's received points have a tight bbox
    rp = np.asarray(rp); rv2 = rv.reshape(8, -1); rps = rp.reshape(8, -1, 2)
    spans = []
    for s in range(8):
        pvalid = rps[s][rv2[s]]
        span = (pvalid.max(0) - pvalid.min(0)).prod()
        spans.append(span)
    assert np.mean(spans) < 0.5, f"SFC redistribution not local: {spans}"
    print("DIST-OK")
""")


@pytest.mark.slow
def test_distributed_partitioner_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DIST-OK" in r.stdout, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
