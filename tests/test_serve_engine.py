"""Serving engine: batched greedy decoding, request masking, parity with a
manual decode loop."""
import jax
import jax.numpy as jnp
import numpy as np
from slot_utils import pad_rows

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve import Request, ServeEngine, make_serve_step

MESH = make_host_mesh()


def _setup(arch="gemma3_1b"):
    cfg = configs.get_config(arch, smoke=True)
    rules = resolve_rules(MESH, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, rules, params


def test_serve_step_greedy_matches_decode():
    cfg, rules, params = _setup()
    step = jax.jit(make_serve_step(cfg, rules))
    cache = M.init_cache(cfg, 2, 16, rules)
    toks = jnp.asarray([[3], [5]], jnp.int32)
    nxt, cache2, logits = step(params, cache, toks, jnp.int32(0))
    lg, _ = M.decode_step(params, cache, {"tokens": toks}, jnp.int32(0),
                          cfg, rules)
    expect = jnp.argmax(
        jnp.where(jnp.arange(cfg.vocab_padded) >= cfg.vocab_size, -jnp.inf,
                  lg.astype(jnp.float32)), -1)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(expect))
    assert int(nxt.max()) < cfg.vocab_size


def test_engine_batched_requests():
    cfg, rules, params = _setup()
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, (4 + i,))
                    .astype(np.int32),
                    max_new=5)
            for i in range(5)]                      # 5 reqs, batch 2 -> 3 groups
    engine = ServeEngine(cfg, rules, params, batch=2, max_seq=32)
    engine.run(reqs)
    for r in reqs:
        assert r.done
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_engine_greedy_parity_with_manual_loop():
    """Engine output for a single request equals a hand-rolled greedy loop
    (teacher-forced prefill + argmax decode)."""
    cfg, rules, params = _setup()
    prompt = np.asarray([1, 2, 3, 4], np.int32)
    req = Request(uid=0, prompt=prompt, max_new=4)
    engine = ServeEngine(cfg, rules, params, batch=1, max_seq=16)
    engine.run([req])

    step = jax.jit(make_serve_step(cfg, rules))
    cache = M.init_cache(cfg, 1, 16, rules)
    cur = None
    for p, tok in enumerate(prompt):
        cur, cache, _ = step(params, cache,
                             jnp.asarray([[tok]], jnp.int32), jnp.int32(p))
    manual = [int(cur[0, 0])]
    for t in range(3):
        cur, cache, _ = step(params, cache, cur, jnp.int32(len(prompt) + t))
        manual.append(int(cur[0, 0]))
    assert req.out == manual


def test_engine_matches_padded_slot_batch():
    """Mixed-length prompts in one group equal a manual loop over the
    pad_rows-built slot batch — the engine's prompt-slot discipline is
    exactly the shared slot_utils padding (all rows share the step
    position; short rows are pad-fed and transcribed from pmax on)."""
    cfg, rules, params = _setup()
    prompts = [np.asarray([1, 2, 3, 4, 5], np.int32),
               np.asarray([9, 6], np.int32)]
    max_new = 4
    reqs = [Request(uid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    engine = ServeEngine(cfg, rules, params, batch=2, max_seq=16)
    engine.run(reqs)

    toks, valid = pad_rows(prompts, pad_value=engine.pad_id)
    assert valid.shape == toks.shape and valid[1, 2:].sum() == 0
    step = jax.jit(make_serve_step(cfg, rules))
    cache = M.init_cache(cfg, 2, 16, rules)
    cur = None
    for p in range(toks.shape[1]):
        cur, cache, _ = step(params, cache,
                             jnp.asarray(toks[:, p:p + 1]), jnp.int32(p))
    manual = [[int(cur[i, 0])] for i in range(2)]
    for t in range(max_new - 1):
        cur, cache, _ = step(params, cache, cur,
                             jnp.int32(toks.shape[1] + t))
        for i in range(2):
            manual[i].append(int(cur[i, 0]))
    assert reqs[0].out == manual[0]
    assert reqs[1].out == manual[1]


def test_engine_eos_stops_row():
    cfg, rules, params = _setup()
    # find the first greedily-emitted token and use it as EOS
    probe = Request(uid=0, prompt=np.asarray([7, 8], np.int32), max_new=3)
    engine = ServeEngine(cfg, rules, params, batch=1, max_seq=16)
    engine.run([probe])
    eos = probe.out[0]
    req = Request(uid=1, prompt=np.asarray([7, 8], np.int32),
                  max_new=8, eos_id=eos)
    engine.run([req])
    assert req.out[0] == eos and len(req.out) == 1
