"""Every example must run its --quick mode to completion (exit 0) — the
docs point users at these entry points, so they can't be allowed to rot.

Subprocess smokes are the slow-harness class of test: the default run
(`pytest -x -q`, the tier-1 gate) still executes them, but CI moves them
to the tier2 job (see pytest.ini)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.tier2

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), os.pardir))

EXAMPLES = [
    "quickstart.py",
    "partition_mesh.py",
    "train_moe_kmeans.py",
]


@pytest.mark.parametrize("script", EXAMPLES)
def test_example_quick_exits_zero(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join("examples", script), "--quick"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{script} --quick failed (exit {proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout[-2000:]}\n"
        f"--- stderr ---\n{proc.stderr[-2000:]}")
