"""Unified partitioning engine: registry, hierarchical (k1 x k2) recursion,
batched-vs-sequential vmap parity."""
import numpy as np
import pytest

from repro.core import meshes, metrics
from repro.partition import (PartitionProblem, PartitionResult,
                             UnknownMethodError, available_methods,
                             batched_balanced_kmeans, build_refinement_batch,
                             factor_k, partition,
                             sequential_balanced_kmeans)
from repro.partition.algorithms import make_bkm_config

METHODS = ["geographer", "sfc", "rcb", "rib", "multijagged"]


@pytest.fixture(scope="module")
def problem():
    mesh = meshes.REGISTRY["delaunay2d"](4000, seed=0)
    return PartitionProblem.from_mesh(mesh, k=16, epsilon=0.03)


@pytest.fixture(scope="module")
def weighted_problem():
    mesh = meshes.REGISTRY["climate25d"](4000, seed=0)
    return PartitionProblem.from_mesh(mesh, k=16, epsilon=0.05)


# ---------------------------------------------------------------------------
# registry + front door
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_all_methods_through_front_door(problem, method):
    res = partition(problem, method=method)
    assert isinstance(res, PartitionResult)
    assert res.labels.shape == (problem.n,)
    assert set(np.unique(res.labels)) <= set(range(problem.k))
    assert len(np.unique(res.labels)) == problem.k
    # every registered method is balance-respecting on this mesh
    assert res.imbalance() <= problem.epsilon + 1e-6


def test_registry_rejects_unknown_method(problem):
    with pytest.raises(UnknownMethodError, match="available"):
        partition(problem, method="metis")
    with pytest.raises(UnknownMethodError):
        partition(problem, method="geographerr", hierarchy=(4, 4))


def test_registry_aliases(problem):
    assert set(METHODS) == set(available_methods())
    a = partition(problem, method="hsfc")
    b = partition(problem, method="sfc")
    np.testing.assert_array_equal(a.labels, b.labels)


def test_front_door_rejects_raw_arrays():
    with pytest.raises(TypeError, match="PartitionProblem"):
        partition(np.zeros((10, 2)), method="sfc")


def test_geographer_opts_forwarded_and_validated(problem):
    res = partition(problem, method="geographer", max_iter=5)
    assert res.labels.shape == (problem.n,)
    with pytest.raises(TypeError, match="unknown BKMConfig"):
        partition(problem, method="geographer", max_itr=5)


def test_evaluate_fills_quality(problem):
    res = partition(problem, method="rcb", evaluate=True)
    assert res.quality is not None
    assert res.quality["cut"] > 0
    assert res.quality["imbalance"] <= problem.epsilon + 1e-6
    # graph-less problems still get balance metrics
    p2 = PartitionProblem(points=problem.points, k=8)
    q = metrics.evaluate_problem(p2, partition(p2, method="sfc").labels)
    assert "imbalance" in q and "cut" not in q


# ---------------------------------------------------------------------------
# hierarchical k1 x k2
# ---------------------------------------------------------------------------

def _check_hierarchy(res, problem, k1, k2):
    assert res.k == k1 * k2
    assert res.stats["k1"] == k1 and res.stats["k2"] == k2
    # global balance against W / (k1*k2)
    assert res.imbalance() <= problem.epsilon + 1e-6
    # label-range consistency: block b owns [b*k2, (b+1)*k2)
    coarse = res.labels // k2
    for b in range(k1):
        sub = res.labels[coarse == b]
        assert sub.size > 0
        assert sub.min() >= b * k2 and sub.max() < (b + 1) * k2
    assert len(res.stats["levels"]) == 2


@pytest.mark.parametrize("k1,k2", [(4, 4), (2, 8)])
def test_hierarchical_balance_and_label_ranges(problem, k1, k2):
    res = partition(problem, hierarchy=(k1, k2))
    _check_hierarchy(res, problem, k1, k2)
    assert res.centers.shape == (k1 * k2, problem.dim)


def test_hierarchical_weighted(weighted_problem):
    res = partition(weighted_problem, hierarchy=(4, 4))
    _check_hierarchy(res, weighted_problem, 4, 4)


def test_hierarchical_string_spec_and_factoring(problem):
    res = partition(problem, hierarchy="4x4")
    _check_hierarchy(res, problem, 4, 4)
    assert factor_k(16) == (4, 4)
    assert factor_k(8) == (2, 4)
    assert factor_k(7) == (1, 7)
    with pytest.raises(ValueError, match="k1\\*k2"):
        partition(problem, hierarchy=(3, 4))


def test_hierarchical_k2_of_one(problem):
    """k2 == 1 degenerates to the coarse pass but must keep the stats
    contract (k1/k2 keys, two levels) and the full epsilon budget."""
    res = partition(problem, hierarchy=(16, 1))
    assert res.k == 16
    assert res.stats["k1"] == 16 and res.stats["k2"] == 1
    assert len(res.stats["levels"]) == 2
    assert res.stats["levels"][0]["epsilon"] == problem.epsilon
    assert res.stats["levels"][1]["dispatches"] == 0
    assert res.imbalance() <= problem.epsilon + 1e-6


def test_hierarchical_rejects_infeasible_blocks():
    """A coarse block smaller than k2 must fail loudly, not silently
    produce empty sub-blocks. One dominant node weight pins a weight-
    balanced coarse block to a handful of points < k2."""
    pts = np.random.default_rng(0).uniform(0, 1, (40, 2))
    w = np.ones(40)
    w[0] = 1000.0                      # one block ~= just this point
    prob = PartitionProblem(points=pts, k=32, weights=w, epsilon=0.03)
    with pytest.raises(ValueError, match="cannot be refined"):
        partition(prob, hierarchy=(4, 8))


def test_problem_normalizes_array_likes():
    """Lists are accepted and stored as ndarrays (frozen-dataclass
    normalization)."""
    prob = PartitionProblem(points=[[0.0, 0.0], [1.0, 1.0], [2.0, 0.5],
                                    [3.0, 1.5]], k=2, weights=[1, 1, 2, 2])
    assert prob.n == 4 and prob.dim == 2
    assert isinstance(prob.points, np.ndarray)
    assert isinstance(prob.weights, np.ndarray)
    res = partition(prob, method="sfc")
    assert res.labels.shape == (4,)


def test_hierarchical_baseline_refinement(problem):
    """Non-k-means refinement (per-block host loop) keeps the invariants."""
    res = partition(problem, hierarchy=(4, 4), refine_method="rcb")
    _check_hierarchy(res, problem, 4, 4)
    assert res.stats["levels"][1]["dispatches"] == 4


# ---------------------------------------------------------------------------
# batched vmap execution
# ---------------------------------------------------------------------------

def _small_problems(seed, n_list):
    """3 small meshes padded to a common cap with weight-0 validity mask."""
    rng = np.random.default_rng(seed)
    cap = max(n_list)
    k = 4
    pts, ws, c0s = [], [], []
    for i, n in enumerate(n_list):
        p = rng.uniform(0, 1, (n, 2))
        w = rng.uniform(0.5, 2.0, n)
        # pad by replicating real points with zero weight
        reps = -(-cap // n)
        idx = np.tile(np.arange(n), reps)[:cap]
        pts.append(p[idx])
        ws.append(np.where(np.arange(cap) < n, w[idx], 0.0))
        c0s.append(p[:: max(n // k, 1)][:k])
    return (np.stack(pts), np.stack(ws), np.stack(c0s), k)


def test_batched_matches_sequential_bitforbit():
    """The single-dispatch vmap path must equal the per-problem loop
    exactly (labels, centers, influence) on 3 different-sized meshes."""
    pts, w, c0, k = _small_problems(0, [500, 341, 512])
    cfg = make_bkm_config(
        PartitionProblem(points=pts[0], k=k, epsilon=0.03), warmup=False)
    A_b, C_b, I_b, S_b = batched_balanced_kmeans(pts, w, c0, cfg)
    A_s, C_s, I_s, S_s = sequential_balanced_kmeans(pts, w, c0, cfg)
    np.testing.assert_array_equal(np.asarray(A_b), np.asarray(A_s))
    np.testing.assert_array_equal(np.asarray(C_b), np.asarray(C_s))
    np.testing.assert_array_equal(np.asarray(I_b), np.asarray(I_s))
    np.testing.assert_array_equal(np.asarray(S_b["final_imbalance"]),
                                  np.asarray(S_s["final_imbalance"]))


def test_batched_respects_validity_mask():
    """Padded (weight-0) slots must not affect balance: per-problem
    imbalance is measured over real points only."""
    pts, w, c0, k = _small_problems(1, [400, 200, 300])
    cfg = make_bkm_config(
        PartitionProblem(points=pts[0], k=k, epsilon=0.03), warmup=False)
    A, _, _, stats = batched_balanced_kmeans(pts, w, c0, cfg)
    A = np.asarray(A)
    for b, n in enumerate([400, 200, 300]):
        sizes = np.bincount(A[b, :n], weights=w[b, :n], minlength=k)
        target = w[b, :n].sum() / k
        assert sizes.max() / target - 1.0 <= cfg.epsilon + 1e-5
    assert np.all(np.asarray(stats["final_imbalance"]) <= cfg.epsilon + 1e-5)


def test_build_refinement_batch_roundtrip(problem):
    """Gather indices cover each block exactly; padding replicates real
    block points with zero weight."""
    coarse = partition(problem.replace(k=4), method="geographer")
    bpts, bw, gather, counts = build_refinement_batch(
        problem.points, problem.weights, coarse.labels, 4)
    assert counts.sum() == problem.n
    cap = gather.shape[1]
    for b in range(4):
        ids = gather[b, : counts[b]]
        assert sorted(ids) == sorted(np.where(coarse.labels == b)[0])
        # padded entries point at real members of the same block
        assert set(gather[b, counts[b]:]) <= set(ids)
        assert np.all(bw[b, counts[b]:] == 0.0)
        np.testing.assert_array_equal(bpts[b], problem.points[gather[b]])
    assert cap == counts.max()


def test_batched_single_dispatch_stats(problem):
    """Hierarchical refinement reports exactly one device dispatch when
    batched (the acceptance criterion) and k1 when sequential."""
    r1 = partition(problem, hierarchy=(4, 4), batched=True)
    r2 = partition(problem, hierarchy=(4, 4), batched=False)
    assert r1.stats["levels"][1]["dispatches"] == 1
    assert r2.stats["levels"][1]["dispatches"] == 4
    np.testing.assert_array_equal(r1.labels, r2.labels)


# ---------------------------------------------------------------------------
# fused assign+reduce vs unfused fallback
# ---------------------------------------------------------------------------

def _fused_pair(problem, **opts):
    # pinned to the jnp backend: the bit-exactness contract is per-backend
    # (the fused jnp path and segment_moments share their reduction
    # structure; the pallas kernel's f32 VMEM tile accumulation is
    # tolerance-tested in tests/test_kernels.py instead)
    a = partition(problem, method="geographer", backend="jnp", fused=True,
                  **opts)
    b = partition(problem, method="geographer", backend="jnp", fused=False,
                  **opts)
    return a, b


@pytest.mark.parametrize("seed,k,warmup", [
    (0, 16, True), (1, 8, True), (2, 16, False), (3, 32, True),
])
def test_fused_bitexact_cold(seed, k, warmup):
    """Property: the fused assign+reduce hot loop is bit-for-bit identical
    to the unfused (assignment + segment_moments) fallback — labels,
    centers AND influence — across seeds, k, and warm-up settings."""
    mesh = meshes.REGISTRY["delaunay2d"](3000, seed=seed)
    prob = PartitionProblem.from_mesh(mesh, k=k, epsilon=0.03, seed=seed)
    a, b = _fused_pair(prob, warmup=warmup)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(np.asarray(a.centers),
                                  np.asarray(b.centers))
    np.testing.assert_array_equal(np.asarray(a.influence),
                                  np.asarray(b.influence))
    assert a.imbalance() <= prob.epsilon + 1e-6


def test_fused_bitexact_weighted(weighted_problem):
    a, b = _fused_pair(weighted_problem)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(np.asarray(a.centers),
                                  np.asarray(b.centers))


def test_fused_bitexact_warm_start(problem):
    """Warm repartition (pre-pass + movement loop) must also be bit-exact
    fused vs unfused."""
    from repro.partition import repartition
    prev_f = partition(problem, method="geographer", backend="jnp",
                       fused=True)
    prev_u = partition(problem, method="geographer", backend="jnp",
                       fused=False)
    rng = np.random.default_rng(0)
    w = 1.0 + rng.uniform(0, 0.4, problem.n)
    prob2 = problem.replace(weights=w)
    a = repartition(prob2, prev_f, backend="jnp", fused=True)
    b = repartition(prob2, prev_u, backend="jnp", fused=False)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(np.asarray(a.centers),
                                  np.asarray(b.centers))
    assert a.stats["iters"] == b.stats["iters"]


def test_pallas_fused_end_to_end(problem):
    """The pallas backend defaults to the fused kernel (VMEM moment
    accumulators); the full solve must stay balanced and cover every
    block. Bitwise parity with jnp is not expected (f32 tile order);
    the kernel-level agreement is tolerance-tested in test_kernels.py."""
    res = partition(problem, method="geographer", backend="pallas")
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k


def test_fused_true_requires_capable_backend(problem):
    """fused=True with a backend that lacks moment support must fail
    loudly, not silently fall back."""
    from repro.kernels.ops import register_assign_backend, _ASSIGN_BACKENDS
    from repro.kernels.ops import assign_argmin_jnp

    @register_assign_backend("_nomoments_test", supports_moments=False)
    def _plain(points, centers, influence, *, chunk=None, block_p=1024,
               block_c=128, precision="f32"):
        return assign_argmin_jnp(points, centers, influence, chunk=chunk,
                                 precision=precision)

    try:
        with pytest.raises(ValueError, match="support"):
            partition(problem, method="geographer",
                      backend="_nomoments_test", fused=True)
        # fused=None auto-falls back to the unfused path and still matches
        res = partition(problem, method="geographer",
                        backend="_nomoments_test")
        ref = partition(problem, method="geographer", backend="jnp")
        np.testing.assert_array_equal(res.labels, ref.labels)
    finally:
        _ASSIGN_BACKENDS.pop("_nomoments_test", None)
