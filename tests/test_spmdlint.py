"""spmdlint static pass: per-rule fixtures, waivers, budgets, and the
tree-wide zero-unwaived invariant (the CI spmdlint job's contract)."""
import os

import pytest

from tools.spmdlint import RULES
from tools.spmdlint.engine import lint_paths, lint_source
from tools.spmdlint.selftest import FIXTURES, WAIVER_FIXTURE, run_self_test
from tools.spmdlint.waivers import Config, Waiver, load_config

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- rule fixtures ---------------------------------------------------------

@pytest.mark.parametrize(
    "rule,should_flag,source",
    FIXTURES,
    ids=[f"{r}-{'pos' if f else 'neg'}" for r, f, _ in FIXTURES])
def test_rule_fixture(rule, should_flag, source):
    diags = [d for d in lint_source("<fixture>", source)
             if d.rule == rule]
    if should_flag:
        assert diags, f"{rule} positive fixture produced no finding"
    else:
        assert not diags, [d.format() for d in diags]


def test_fixtures_cover_every_rule_both_ways():
    for rule in RULES:
        kinds = {flag for r, flag, _ in FIXTURES if r == rule}
        assert kinds == {True, False}, (
            f"{rule} needs one positive and one negative fixture")


def test_self_test_passes():
    assert run_self_test(verbose=False) == 0


# -- waivers ---------------------------------------------------------------

def test_waiver_suppresses_matching_finding():
    config = Config(waivers=[Waiver(rule="SPMD001", path="x.py",
                                    symbol="build.local", reason="test")])
    diags = lint_source("x.py", WAIVER_FIXTURE, config)
    assert diags and all(d.waived_by for d in diags)


def test_waiver_does_not_suppress_other_rule_or_path():
    for waiver in (Waiver(rule="SPMD002", path="x.py"),
                   Waiver(rule="SPMD001", path="other.py"),
                   Waiver(rule="SPMD001", path="x.py", symbol="elsewhere")):
        diags = lint_source("x.py", WAIVER_FIXTURE,
                            Config(waivers=[waiver]))
        assert any(d.waived_by is None for d in diags), waiver


def test_waiver_path_matches_by_suffix():
    config = Config(waivers=[Waiver(rule="SPMD001", path="pkg/x.py")])
    diags = lint_source("/abs/prefix/pkg/x.py", WAIVER_FIXTURE, config)
    assert diags and all(d.waived_by for d in diags)


def test_mini_toml_loader(tmp_path):
    toml = tmp_path / "spmdlint.toml"
    toml.write_text(
        '# comment\n'
        '[spmd]\n'
        'axes = ["shard", "row"]\n'
        '\n'
        '[[waiver]]\n'
        'rule = "SPMD001"\n'
        'path = "a/b.py"\n'
        'symbol = "f"\n'
        'reason = "because"\n'
        '[[waiver]]\n'
        'rule = "KER001"\n'
        'path = "c.py"\n')
    config = load_config(str(toml))
    assert config.axes == frozenset({"shard", "row"})
    assert len(config.waivers) == 2
    assert config.waivers[0] == Waiver(rule="SPMD001", path="a/b.py",
                                       symbol="f", reason="because")
    assert config.waivers[1].symbol is None


def test_axes_override_feeds_spmd002(tmp_path):
    src = 'import jax\n\ndef f(x):\n    return jax.lax.psum(x, "row")\n'
    assert any(d.rule == "SPMD002" for d in lint_source("f.py", src))
    config = Config(waivers=[], axes=frozenset({"row"}))
    assert not [d for d in lint_source("f.py", src, config)
                if d.rule == "SPMD002"]


def test_missing_waiver_file_is_empty_config(tmp_path):
    config = load_config(str(tmp_path / "absent.toml"))
    assert config.waivers == [] and config.axes is None


# -- psum budgets ----------------------------------------------------------

def test_budget_counts_through_local_helpers():
    src = (
        "import jax\n\n"
        "def local(x, axis):  # spmdlint: psum-budget=4\n"
        "    def scatter_psum(v):\n"
        "        return jax.lax.psum(v, axis)\n"
        "    a = scatter_psum(x)\n"
        "    b = scatter_psum(x * 2)\n"
        "    c = scatter_psum(x * 3)\n"
        "    return a + b + c + jax.lax.psum(x, axis)\n")
    assert not [d for d in lint_source("f.py", src) if d.rule == "SPMD003"]
    wrong = src.replace("psum-budget=4", "psum-budget=3")
    [d] = [d for d in lint_source("f.py", wrong) if d.rule == "SPMD003"]
    assert "declared 3, counted 4" in d.message


def test_budget_directives_present_in_sharded_kernels():
    """The documented 4-psums/round budgets stay pinned in the source."""
    for rel in ("src/repro/eval/sharded.py", "src/repro/partition/refine.py"):
        with open(os.path.join(REPO, rel), encoding="utf-8") as fh:
            assert "spmdlint: psum-budget=4" in fh.read(), rel


# -- the tree-wide invariant ----------------------------------------------

def test_repo_tree_has_zero_unwaived_findings():
    config = load_config(os.path.join(REPO, "spmdlint.toml"))
    paths = [os.path.join(REPO, p)
             for p in ("src", "tests", "benchmarks", "tools")]
    active = [d for d in lint_paths(paths, config) if d.waived_by is None]
    assert not active, "\n".join(d.format() for d in active)


def test_waivers_all_still_match_something():
    config = load_config(os.path.join(REPO, "spmdlint.toml"))
    assert config.waivers, "spmdlint.toml lost its waiver entries"
    diags = lint_paths([os.path.join(REPO, "src")], config)
    for waiver in config.waivers:
        assert any(waiver.matches(d) for d in diags), (
            f"stale waiver: {waiver}")
