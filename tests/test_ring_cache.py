"""Window-sized ring KV cache (perf opt for SWA decode) vs the full-length
cache: identical logits token-for-token."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M

MESH = make_host_mesh()


def test_ring_cache_matches_full():
    cfg_full = configs.get_config("gemma3_1b", smoke=True)   # window=8
    cfg_ring = dataclasses.replace(cfg_full, swa_ring_cache=True)
    rules = resolve_rules(MESH, cfg_full, "decode")
    params = M.init_params(cfg_full, jax.random.PRNGKey(0))
    B, S = 2, 24                                  # 3x the window
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg_full.vocab_size, (B, S)).astype(np.int32)

    cache_f = M.init_cache(cfg_full, B, S, rules)
    cache_r = M.init_cache(cfg_ring, B, S, rules)
    # ring caches for swa layers are window-sized
    swa_pos = [i for i, sp in enumerate(cfg_full.pattern)
               if sp.attn == "swa"][0]
    assert cache_r[f"pos{swa_pos}"]["k"].shape[2] == cfg_full.window
    assert cache_f[f"pos{swa_pos}"]["k"].shape[2] == S

    step_f = jax.jit(lambda p, c, t, pos: M.decode_step(
        p, c, {"tokens": t}, pos, cfg_full, rules))
    step_r = jax.jit(lambda p, c, t, pos: M.decode_step(
        p, c, {"tokens": t}, pos, cfg_ring, rules))
    for t in range(S):
        tok = jnp.asarray(toks[:, t:t + 1])
        lf, cache_f = step_f(params, cache_f, tok, jnp.int32(t))
        lr, cache_r = step_r(params, cache_r, tok, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(lf, np.float32),
                                   np.asarray(lr, np.float32),
                                   rtol=2e-2, atol=2e-2,
                                   err_msg=f"step {t}")
