"""Graph metrics + baseline partitioners."""
import numpy as np
import pytest

from repro.core import baselines, meshes, metrics


@pytest.fixture(scope="module")
def small_mesh():
    return meshes.grid_triangulation(40, 40)


def test_edge_cut_known_value(small_mesh):
    """Vertical split of a 40x40 grid-triangulation: cut = ny + (ny-1) diag."""
    part = (small_mesh.points[:, 0] >= 20).astype(np.int64)
    cut = metrics.edge_cut(part, small_mesh.indptr, small_mesh.indices)
    assert cut == 40 + 39  # right edges + diagonals crossing the split


def test_comm_volume_two_blocks(small_mesh):
    part = (small_mesh.points[:, 0] >= 20).astype(np.int64)
    maxc, totc, per = metrics.comm_volume(part, small_mesh.indptr,
                                          small_mesh.indices, 2)
    # with 2 blocks, comm volume counts boundary vertices once each side
    assert totc == per.sum()
    assert maxc >= 40  # at least one column of boundary vertices per side
    assert totc <= 4 * 40


def test_imbalance_perfect():
    part = np.repeat(np.arange(4), 25)
    assert metrics.imbalance(part, 4) == 0.0


def test_imbalance_unit_weighted_consistent():
    """Regression: the unit branch used ceil(n/k) as target while the
    weighted branch used total/k — with k not dividing n the two branches
    disagreed for the SAME partition. Both must use total/k (paper §2,
    the bar the solvers balance against)."""
    part = np.array([0, 0, 1, 1, 2])          # n=5, k=3, max size 2
    unit = metrics.imbalance(part, 3)
    weighted = metrics.imbalance(part, 3, np.ones(5))
    assert unit == pytest.approx(weighted)
    # the shared target is n/k (no ceil): 2 / (5/3) - 1 = 0.2
    assert unit == pytest.approx(0.2)


def test_imbalance_matches_solver_bar():
    """A partition exactly at the solver's (1+eps)*W/k bound must measure
    imbalance == eps, not less (the old ceil'd unit target under-reported
    whenever k did not divide n)."""
    # 7 blocks over 100 points: two blocks of 16, five of 13.6 -> use
    # integer sizes 16,14,14,14,14,14,14
    sizes = [16, 14, 14, 14, 14, 14, 14]
    part = np.concatenate([np.full(s, b) for b, s in enumerate(sizes)])
    expect = 16 / (100 / 7) - 1.0
    assert metrics.imbalance(part, 7) == pytest.approx(expect)


def test_diameter_path_graph():
    """Path graph diameter is exact for double-sweep BFS."""
    n = 50
    indptr = np.zeros(n + 1, np.int64)
    rows, cols = [], []
    for i in range(n - 1):
        rows += [i, i + 1]
        cols += [i + 1, i]
    order = np.lexsort((cols, rows))
    rows, cols = np.array(rows)[order], np.array(cols)[order]
    np.add.at(indptr, rows + 1, 1)
    np.cumsum(indptr, out=indptr)
    part = np.zeros(n, np.int64)
    d = metrics.block_diameters(part, indptr, cols, 1)
    assert d[0] == n - 1


def test_disconnected_block_inf_diameter(small_mesh):
    part = np.zeros(small_mesh.n, np.int64)
    # two far-apart single vertices in block 1 -> disconnected
    part[0] = 1
    part[-1] = 1
    d = metrics.block_diameters(part, small_mesh.indptr, small_mesh.indices, 2)
    assert np.isinf(d[1])


def test_block_diameters_one_bfs_per_round(small_mesh, monkeypatch):
    """Regression: block_diameters ran a dead duplicate of the first BFS
    plus a second full connectivity BFS per block (two wasted O(V+E)
    sweeps). The first double-sweep now carries the reach count, so a
    block costs exactly ``rounds`` BFS calls — with unchanged results."""
    calls = {"n": 0}
    real = metrics._bfs_ecc

    def counting(*args, **kw):
        calls["n"] += 1
        return real(*args, **kw)

    monkeypatch.setattr(metrics, "_bfs_ecc", counting)
    part = (small_mesh.points[:, 0] >= 20).astype(np.int64)
    d = metrics.block_diameters(part, small_mesh.indptr,
                                small_mesh.indices, 2, rounds=3)
    assert calls["n"] == 2 * 3               # k blocks x rounds, no extras
    assert np.all(np.isfinite(d))            # both halves connected
    # double-sweep lower bound on a 20x40 grid half: at least the side len
    assert np.all(d >= 39)


@pytest.mark.parametrize("name", list(baselines.BASELINES))
def test_baselines_balance_and_coverage(name):
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (4000, 2))
    k = 16
    part = baselines.BASELINES[name](pts, k)
    assert part.shape == (4000,)
    assert len(np.unique(part)) == k
    assert metrics.imbalance(part, k) <= 0.05


@pytest.mark.parametrize("name", list(baselines.BASELINES))
def test_baselines_weighted(name):
    rng = np.random.default_rng(1)
    pts = rng.uniform(0, 1, (4000, 2))
    w = rng.uniform(0.5, 4.0, 4000)
    part = baselines.BASELINES[name](pts, 8, w)
    assert metrics.imbalance(part, 8, w) <= 0.25  # quantile cuts: coarse


def test_mesh_generators():
    for key in ["tri", "rgg2d", "delaunay2d", "refined2d", "climate25d",
                "aniso", "rggpow"]:
        m = meshes.REGISTRY[key](2500)
        assert m.n >= 2400
        assert m.indices.max() < m.n
        deg = np.diff(m.indptr)
        assert deg.mean() > 2.0, f"{key} too sparse: {deg.mean()}"
        # symmetry: every edge appears both ways
        src = np.repeat(np.arange(m.n), deg)
        fwd = set(zip(src.tolist(), m.indices.tolist()))
        assert all((b, a) in fwd for a, b in list(fwd)[:200])
    for key in ["rgg3d", "refined3d"]:
        m = meshes.REGISTRY[key](2000)
        assert m.dim == 3
        assert m.n >= 1900


def test_new_zoo_families_stress_properties():
    """The expanded §5 zoo keeps its defining traits: aniso stretches x by
    the aspect factor, rggpow draws heavy-tailed (but capped) weights."""
    a = meshes.stretched_grid(1600, aspect=6.0, seed=0)
    ext = a.points.max(axis=0) - a.points.min(axis=0)
    assert ext[0] / ext[1] == pytest.approx(6.0, rel=0.05)
    w = meshes.powerlaw_rgg(3000, seed=0).weights
    assert w is not None and np.all(w >= 1.0) and np.all(w <= 100.0)
    assert w.max() / np.median(w) > 5.0      # genuinely heavy-tailed


def test_rcb_powers_of_two_and_odd_k():
    rng = np.random.default_rng(2)
    pts = rng.uniform(0, 1, (3000, 2))
    for k in [3, 5, 7, 12]:
        part = baselines.rcb(pts, k)
        assert len(np.unique(part)) == k
        assert metrics.imbalance(part, k) < 0.1
