"""Out-of-core sharded deal: chunk streaming, dtype preservation, int32
index capacity, and the warm-path prev-labels sentinel.

The contract under test (partition/distributed.py, DESIGN.md §13):

* ``from_problem(..., chunk=c)`` / ``deal(..., chunk=c)`` /
  ``scatter_labels(..., chunk=c)`` are **bit-identical** to the one-shot
  deal for every chunk size — chunking bounds transient host staging,
  it never changes a result bit.
* The deal preserves the problem's floating dtype: a float32 problem
  never gets a float64 host copy (the memory-gate regression this PR
  fixes — the old deal up-cast everything through ``np.float64``).
* ``cap = ceil(n/P)`` must fit the int32 traced index dtype;
  ``check_index_capacity`` raises a naming error at the front door
  instead of letting indices wrap inside a kernel.
* When a direct warm-path caller omits ``prev_labels``, the dealt
  sentinel is -1 — it can never equal a real assignment, so the no-op
  shortcut cannot fire on a partition that never existed.
"""
import jax
import numpy as np
import pytest

from repro.partition import PartitionProblem, ShardedPartitionProblem
from repro.partition.distributed import (INT32_INDEX_CAP,
                                         check_index_capacity,
                                         geographer_repartition_sharded,
                                         partition_sharded)

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


def _problem(n=4099, k=8, seed=11, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return PartitionProblem(
        points=rng.random((n, 2)).astype(dtype),
        weights=rng.uniform(0.5, 2.0, n).astype(dtype),
        k=k, epsilon=0.05, seed=seed)


class TestChunkedDealParity:
    """chunked == one-shot, bit for bit, across the awkward cases."""

    @pytest.mark.parametrize("devices", [1, 2, 4, 8])
    @pytest.mark.parametrize("chunk", [1, 13, 100, 1 << 30])
    def test_from_problem_bitexact(self, devices, chunk):
        # n=4099 is prime: chunk never divides n, padding is always live
        prob = _problem()
        one = ShardedPartitionProblem.from_problem(prob, devices)
        spc = ShardedPartitionProblem.from_problem(prob, devices,
                                                   chunk=chunk)
        assert spc.points.dtype == one.points.dtype
        assert np.array_equal(one.points, spc.points)
        assert np.array_equal(one.weights, spc.weights)
        assert np.array_equal(one.gather, spc.gather)
        assert np.array_equal(one.valid, spc.valid)

    def test_chunk_below_cap_and_not_dividing_cap(self):
        # cap = ceil(4099/4) = 1025; chunk=7 is < cap and 7 does not
        # divide 1025 — the last slice is a partial one
        prob = _problem()
        one = prob.to_sharded(4)
        spc = prob.to_sharded(4, chunk=7)
        assert np.array_equal(one.points, spc.points)
        assert np.array_equal(one.gather, spc.gather)

    @pytest.mark.parametrize("chunk", [1, 13, 1 << 30])
    def test_deal_and_scatter_roundtrip(self, chunk):
        prob = _problem()
        sp = prob.to_sharded(4)
        vals = (np.arange(prob.n) * 7 % prob.k).astype(np.int64)
        dealt_one = sp.deal(vals)
        dealt_chunk = sp.deal(vals, chunk=chunk)
        assert np.array_equal(np.asarray(dealt_one),
                              np.asarray(dealt_chunk))
        back = sp.scatter_labels(np.asarray(dealt_chunk), chunk=chunk)
        assert np.array_equal(back, vals)

    @needs8
    def test_chunked_solve_bitexact(self):
        prob = _problem()
        a = partition_sharded(prob, 8)
        b = partition_sharded(prob, 8, chunk=13)
        assert np.array_equal(a.labels, b.labels)
        assert np.array_equal(a.centers, b.centers)


class TestDtypePreservation:
    """A float32 problem must stay float32 through the deal — the old
    path up-cast points AND weights through a full-host float64 copy,
    tripling the deal's peak footprint at d=2."""

    def test_float32_problem_deals_float32(self):
        sp = _problem(dtype=np.float32).to_sharded(4)
        assert sp.points.dtype == np.float32
        assert sp.weights.dtype == np.float32

    def test_float32_chunked_deal_stays_float32(self):
        sp = _problem(dtype=np.float32).to_sharded(4, chunk=17)
        assert sp.points.dtype == np.float32
        assert sp.weights.dtype == np.float32

    def test_float64_problem_keeps_float64(self):
        sp = _problem(dtype=np.float64).to_sharded(4)
        assert sp.points.dtype == np.float64
        assert sp.weights.dtype == np.float64

    def test_unit_weights_follow_points_dtype(self):
        prob = PartitionProblem(points=_problem().points, k=8,
                                epsilon=0.05, seed=11)
        sp = prob.to_sharded(4)
        assert sp.weights.dtype == np.float32

    def test_integer_weights_widen_to_float64(self):
        # non-float weights have no dtype to preserve; they widen safely
        base = _problem()
        prob = PartitionProblem(points=base.points,
                                weights=np.ones(base.n, np.int32),
                                k=8, epsilon=0.05, seed=11)
        assert prob.to_sharded(4).weights.dtype == np.float64

    @needs8
    def test_float32_labels_match_float64_layout(self):
        # dtype preservation changes memory, not the layout: gather and
        # valid are identical for the f32 and f64 views of one problem
        p32, p64 = _problem(dtype=np.float32), _problem(dtype=np.float64)
        s32, s64 = p32.to_sharded(8), p64.to_sharded(8)
        assert np.array_equal(s32.gather, s64.gather)
        assert np.array_equal(s32.valid, s64.valid)


class TestIndexCapacity:
    """cap = ceil(n/P) <= 2**31 - 1 is enforced at the front door."""

    def test_overflow_raises_naming_error(self):
        with pytest.raises(ValueError) as e:
            check_index_capacity(2 ** 31, 1)
        msg = str(e.value)
        assert "int32" in msg and "ceil(n/P)" in msg
        assert str(2 ** 31) in msg          # names n
        assert "more devices" in msg        # names the remedy

    def test_boundary_passes(self):
        assert check_index_capacity(2 ** 31 - 1, 1) == INT32_INDEX_CAP

    def test_more_devices_restore_capacity(self):
        assert check_index_capacity(2 ** 31, 2) == 2 ** 30
        assert check_index_capacity(2 ** 31, (1, 2)) == 2 ** 30

    def test_mesh_tuple_uses_device_product(self):
        with pytest.raises(ValueError):
            check_index_capacity(2 ** 32, (1, 2))
        assert check_index_capacity(2 ** 32, (2, 2)) == 2 ** 30


class TestWarmSentinel:
    """prev_labels=None must never satisfy no-op detection."""

    def test_sentinel_run_still_iterates(self):
        # k=1 with centers0 far off the centroid: if a synthetic
        # "previous partition" could register as unchanged, the solver
        # would no-op at iters=0 and keep the bogus centers. The -1
        # sentinel can't match any real assignment, so it must iterate
        # and pull the center onto the weighted centroid.
        prob = _problem(k=1)
        centers0 = np.array([[10.0, 10.0]])
        labels, centers, _, stats = geographer_repartition_sharded(
            prob, 2, centers0)
        assert int(stats["iters"]) >= 1
        assert np.array_equal(labels, np.zeros(prob.n, np.int64))
        centroid = np.average(prob.points, axis=0, weights=prob.weights)
        assert np.allclose(np.asarray(centers)[0], centroid, atol=1e-3)

    def test_real_prev_labels_still_noop(self):
        # the counterpart: a genuine fixed point re-submitted WITH its
        # labels is recognized and re-emitted at iters=0
        prob = _problem(k=1)
        centroid = np.average(prob.points, axis=0, weights=prob.weights)
        prev = np.zeros(prob.n, np.int64)
        labels, _, _, stats = geographer_repartition_sharded(
            prob, 2, centroid[None, :], prev_labels=prev)
        assert int(stats["iters"]) == 0
        assert np.array_equal(labels, prev)

    def test_sentinel_chunked_matches_oneshot(self):
        prob = _problem(k=4)
        centers0 = prob.points[:4].astype(np.float64)
        la, ca, _, _ = geographer_repartition_sharded(prob, 2, centers0)
        lb, cb, _, _ = geographer_repartition_sharded(prob, 2, centers0,
                                                      chunk=19)
        assert np.array_equal(la, lb)
        assert np.array_equal(np.asarray(ca), np.asarray(cb))
