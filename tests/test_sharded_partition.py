"""Sharded multi-device partition path: ``partition(problem, devices=P)``.

Runs in-process on 8 virtual CPU devices — tests/conftest.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before the first
jax import, so no subprocess is needed.

Documented agreement tolerance (see partition/distributed.py and
DESIGN.md §3b): ``devices=1`` must be bit-for-bit identical to the
single-device path. For ``devices=P>1`` with ``warmup=False`` the only
difference is float reduction order (per-shard partial sums + psum vs
one global segment_sum), so labels agree on >= 97% of points (100%
in 3 of 4 measured configs). With warm-up enabled (the default) the
per-shard sample masks differ from the global prefix by up to P-1
points per round, which on small problems can steer k-means to a
*different but equally balanced* local optimum — so only the balance
bound and quality invariants are guaranteed, not label agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import meshes
from repro.partition import (PartitionProblem, ShardedPartitionProblem,
                             distributed_methods, partition,
                             supports_devices)

LABEL_AGREEMENT = 0.97

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


@pytest.fixture(scope="module")
def problem():
    mesh = meshes.REGISTRY["delaunay2d"](4096, seed=0)
    return PartitionProblem.from_mesh(mesh, k=8, epsilon=0.03)


@pytest.fixture(scope="module")
def reference(problem):
    return partition(problem, method="geographer")


def test_conftest_forces_eight_devices():
    """The CI/test plumbing contract: CPU-only runners still expose 8
    devices for the multi-device tests."""
    assert len(jax.devices()) >= 8


def test_registry_declares_distributed_support():
    assert supports_devices("geographer")
    assert supports_devices("bkm")          # via alias
    assert not supports_devices("rcb")
    assert "geographer" in distributed_methods()


@needs8
def test_devices_one_is_bitforbit_single_device(problem, reference):
    res = partition(problem, method="geographer", devices=1)
    np.testing.assert_array_equal(res.labels, reference.labels)
    assert res.stats["devices"] == 1


@needs8
def test_sharded_matches_single_device_within_tolerance(problem):
    """warmup=False isolates the float-reduction-order difference — the
    documented >= 97% label-agreement tolerance applies to it."""
    ref = partition(problem, method="geographer", warmup=False)
    res = partition(problem, method="geographer", devices=8, warmup=False)
    agreement = float(np.mean(res.labels == ref.labels))
    assert agreement >= LABEL_AGREEMENT, f"label agreement {agreement:.4f}"
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    assert res.stats["devices"] == 8
    assert res.centers.shape == (problem.k, problem.dim)


@needs8
def test_sharded_default_warmup_keeps_invariants(problem, reference):
    """With warm-up (the default) trajectories may diverge to a different
    local optimum; balance and block-coverage must hold regardless."""
    res = partition(problem, method="geographer", devices=8)
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    # the single-device reference obeys the same bound (sanity anchor)
    assert reference.imbalance() <= problem.epsilon + 1e-6


@needs8
def test_uneven_n_padding_correctness():
    """P does not divide n: every original point labelled exactly once,
    padded slots carry weight zero and replicate real coordinates."""
    mesh = meshes.REGISTRY["delaunay2d"](4001, seed=1)
    prob = PartitionProblem.from_mesh(mesh, k=8, epsilon=0.03)
    sp = prob.to_sharded(8)
    assert isinstance(sp, ShardedPartitionProblem)
    assert sp.cap == -(-4001 // 8)
    ids = sp.gather[sp.valid]
    assert sorted(ids.tolist()) == list(range(4001))     # exactly once
    assert np.all(sp.weights[~sp.valid] == 0.0)
    np.testing.assert_array_equal(                      # padding is real pts
        sp.points.reshape(-1, 2),
        np.asarray(prob.points, np.float64)[sp.gather.reshape(-1)])
    res = partition(prob, devices=8)
    assert res.labels.shape == (4001,)
    assert res.imbalance() <= prob.epsilon + 1e-6


@needs8
def test_k_not_divisible_by_device_count():
    """Centers are replicated, so k has no divisibility constraint."""
    mesh = meshes.REGISTRY["delaunay2d"](4000, seed=2)
    prob = PartitionProblem.from_mesh(mesh, k=6, epsilon=0.03)
    res = partition(prob, devices=8)
    assert len(np.unique(res.labels)) == 6
    assert res.imbalance() <= prob.epsilon + 1e-6


@needs8
def test_weighted_25d_mesh_sharded():
    """2.5D fesom-style node weights balance against the weighted target
    under sharding."""
    mesh = meshes.REGISTRY["climate25d"](4000, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k=16, epsilon=0.05)
    res = partition(prob, devices=4)
    assert res.imbalance() <= prob.epsilon + 1e-6
    assert len(np.unique(res.labels)) == prob.k


@needs8
def test_hierarchical_composes_with_devices():
    """hierarchy=(k1, k2) + devices=P: distributed coarse cut, host
    batched refinement, global balance still composed."""
    mesh = meshes.REGISTRY["delaunay2d"](4000, seed=3)
    prob = PartitionProblem.from_mesh(mesh, k=16, epsilon=0.03)
    res = partition(prob, hierarchy=(4, 4), devices=8)
    assert res.imbalance() <= prob.epsilon + 1e-6
    assert res.stats["levels"][0]["devices"] == 8
    coarse = res.labels // 4
    for b in range(4):
        sub = res.labels[coarse == b]
        assert sub.size > 0
        assert sub.min() >= b * 4 and sub.max() < (b + 1) * 4


@needs8
def test_device_bootstrap_balances(problem):
    """Fully in-graph SFC bootstrap (psum'd histogram splitting) still
    yields a balanced partition using every block."""
    res = partition(problem, devices=4, bootstrap="device")
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    assert res.stats["bootstrap"] == "device"


@needs8
@pytest.mark.parametrize("warm", [False, True])
def test_fused_bitexact_sharded(problem, warm):
    """Fused assign+reduce vs unfused fallback on the devices=4 path:
    per-shard sweeps + the same psums must stay bit-for-bit identical,
    cold and warm-started."""
    from repro.partition import repartition
    if warm:
        prev = partition(problem, method="geographer", devices=4,
                         backend="jnp")
        rng = np.random.default_rng(1)
        prob2 = problem.replace(weights=1.0 + rng.uniform(0, 0.4, problem.n))
        a = repartition(prob2, prev, devices=4, backend="jnp", fused=True)
        b = repartition(prob2, prev, devices=4, backend="jnp", fused=False)
        assert a.stats["iters"] == b.stats["iters"]
    else:
        a = partition(problem, method="geographer", devices=4,
                      backend="jnp", fused=True)
        b = partition(problem, method="geographer", devices=4,
                      backend="jnp", fused=False)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(np.asarray(a.centers),
                                  np.asarray(b.centers))
    np.testing.assert_array_equal(np.asarray(a.influence),
                                  np.asarray(b.influence))


@needs8
def test_warmup_under_shard_map_needs_static_n_global():
    """Regression: balanced_kmeans(warmup=True) under shard_map derives
    the warm-up round count from the global point count — a Python loop
    bound. A traced n_global used to die with an opaque tracer-conversion
    error deep in int(); it must raise an actionable ValueError instead
    (and a static n_global — what the distributed driver passes — must
    keep working)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.balanced_kmeans import BKMConfig, balanced_kmeans
    from repro.dist.rules import PARTITION_AXIS, partition_mesh

    mesh = partition_mesh(4)
    pts = np.random.default_rng(0).uniform(0, 1, (1024, 2)).astype(np.float32)
    cfg = BKMConfig(k=4, warmup=True, backend="jnp")

    def run(traced_n_global):
        def local(p, ng):
            A, *_ = balanced_kmeans(
                p.reshape(256, 2), cfg, axis_name=PARTITION_AXIS,
                n_global=(ng if traced_n_global else 1024))
            return A[None]
        f = jax.jit(shard_map(local, mesh=mesh,
                              in_specs=(P(PARTITION_AXIS), P()),
                              out_specs=P(PARTITION_AXIS), check_rep=False))
        return f(jnp.asarray(pts), jnp.asarray(1024))

    # a traced global count cannot size the warm-up schedule
    with pytest.raises(ValueError, match="static"):
        run(traced_n_global=True)
    # the supported spelling: static python int
    labels = np.asarray(run(traced_n_global=False))
    assert labels.shape == (4, 256)
    assert set(np.unique(labels)) <= set(range(4))


def test_devices_rejected_for_host_only_methods(problem):
    with pytest.raises(ValueError, match="no multi-device path"):
        partition(problem, method="rcb", devices=4)
    with pytest.raises(ValueError, match="no multi-device path"):
        partition(problem, hierarchy=(4, 2), method="rcb", devices=4)


def test_bootstrap_requires_devices(problem):
    with pytest.raises(TypeError, match="devices"):
        partition(problem, method="geographer", bootstrap="device")


def test_invalid_device_counts(problem):
    with pytest.raises(ValueError, match="out of range"):
        partition(problem, devices=4096)
    with pytest.raises(ValueError):
        partition(problem, devices=0)
    with pytest.raises(ValueError, match="bootstrap"):
        partition(problem, devices=2, bootstrap="quantum")
