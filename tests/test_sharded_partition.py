"""Sharded multi-device partition path: ``partition(problem, devices=P)``.

Runs in-process on 8 virtual CPU devices — tests/conftest.py sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before the first
jax import, so no subprocess is needed.

Documented agreement tolerance (see partition/distributed.py and
DESIGN.md §3b): ``devices=1`` must be bit-for-bit identical to the
single-device path. For ``devices=P>1`` with ``warmup=False`` the only
difference is float reduction order (per-shard partial sums + psum vs
one global segment_sum), so labels agree on >= 97% of points (100%
in 3 of 4 measured configs). With warm-up enabled (the default) the
per-shard sample masks differ from the global prefix by up to P-1
points per round, which on small problems can steer k-means to a
*different but equally balanced* local optimum — so only the balance
bound and quality invariants are guaranteed, not label agreement.
"""
import jax
import numpy as np
import pytest

from repro.core import meshes
from repro.partition import (PartitionProblem, ShardedPartitionProblem,
                             distributed_methods, partition,
                             supports_devices)

LABEL_AGREEMENT = 0.97

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


@pytest.fixture(scope="module")
def problem():
    mesh = meshes.REGISTRY["delaunay2d"](4096, seed=0)
    return PartitionProblem.from_mesh(mesh, k=8, epsilon=0.03)


@pytest.fixture(scope="module")
def reference(problem):
    return partition(problem, method="geographer")


def test_conftest_forces_eight_devices():
    """The CI/test plumbing contract: CPU-only runners still expose 8
    devices for the multi-device tests."""
    assert len(jax.devices()) >= 8


def test_registry_declares_distributed_support():
    assert supports_devices("geographer")
    assert supports_devices("bkm")          # via alias
    assert not supports_devices("rcb")
    assert "geographer" in distributed_methods()


@needs8
def test_devices_one_is_bitforbit_single_device(problem, reference):
    res = partition(problem, method="geographer", devices=1)
    np.testing.assert_array_equal(res.labels, reference.labels)
    assert res.stats["devices"] == 1


@needs8
def test_sharded_matches_single_device_within_tolerance(problem):
    """warmup=False isolates the float-reduction-order difference — the
    documented >= 97% label-agreement tolerance applies to it."""
    ref = partition(problem, method="geographer", warmup=False)
    res = partition(problem, method="geographer", devices=8, warmup=False)
    agreement = float(np.mean(res.labels == ref.labels))
    assert agreement >= LABEL_AGREEMENT, f"label agreement {agreement:.4f}"
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    assert res.stats["devices"] == 8
    assert res.centers.shape == (problem.k, problem.dim)


@needs8
def test_sharded_default_warmup_keeps_invariants(problem, reference):
    """With warm-up (the default) trajectories may diverge to a different
    local optimum; balance and block-coverage must hold regardless."""
    res = partition(problem, method="geographer", devices=8)
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    # the single-device reference obeys the same bound (sanity anchor)
    assert reference.imbalance() <= problem.epsilon + 1e-6


@needs8
def test_uneven_n_padding_correctness():
    """P does not divide n: every original point labelled exactly once,
    padded slots carry weight zero and replicate real coordinates."""
    mesh = meshes.REGISTRY["delaunay2d"](4001, seed=1)
    prob = PartitionProblem.from_mesh(mesh, k=8, epsilon=0.03)
    sp = prob.to_sharded(8)
    assert isinstance(sp, ShardedPartitionProblem)
    assert sp.cap == -(-4001 // 8)
    ids = sp.gather[sp.valid]
    assert sorted(ids.tolist()) == list(range(4001))     # exactly once
    assert np.all(sp.weights[~sp.valid] == 0.0)
    np.testing.assert_array_equal(                      # padding is real pts
        sp.points.reshape(-1, 2),
        np.asarray(prob.points, np.float64)[sp.gather.reshape(-1)])
    res = partition(prob, devices=8)
    assert res.labels.shape == (4001,)
    assert res.imbalance() <= prob.epsilon + 1e-6


@needs8
def test_k_not_divisible_by_device_count():
    """Centers are replicated, so k has no divisibility constraint."""
    mesh = meshes.REGISTRY["delaunay2d"](4000, seed=2)
    prob = PartitionProblem.from_mesh(mesh, k=6, epsilon=0.03)
    res = partition(prob, devices=8)
    assert len(np.unique(res.labels)) == 6
    assert res.imbalance() <= prob.epsilon + 1e-6


@needs8
def test_weighted_25d_mesh_sharded():
    """2.5D fesom-style node weights balance against the weighted target
    under sharding."""
    mesh = meshes.REGISTRY["climate25d"](4000, seed=0)
    prob = PartitionProblem.from_mesh(mesh, k=16, epsilon=0.05)
    res = partition(prob, devices=4)
    assert res.imbalance() <= prob.epsilon + 1e-6
    assert len(np.unique(res.labels)) == prob.k


@needs8
def test_hierarchical_composes_with_devices():
    """hierarchy=(k1, k2) + devices=P: distributed coarse cut, host
    batched refinement, global balance still composed."""
    mesh = meshes.REGISTRY["delaunay2d"](4000, seed=3)
    prob = PartitionProblem.from_mesh(mesh, k=16, epsilon=0.03)
    res = partition(prob, hierarchy=(4, 4), devices=8)
    assert res.imbalance() <= prob.epsilon + 1e-6
    assert res.stats["levels"][0]["devices"] == 8
    coarse = res.labels // 4
    for b in range(4):
        sub = res.labels[coarse == b]
        assert sub.size > 0
        assert sub.min() >= b * 4 and sub.max() < (b + 1) * 4


@needs8
def test_device_bootstrap_balances(problem):
    """Fully in-graph SFC bootstrap (psum'd histogram splitting) still
    yields a balanced partition using every block."""
    res = partition(problem, devices=4, bootstrap="device")
    assert res.imbalance() <= problem.epsilon + 1e-6
    assert len(np.unique(res.labels)) == problem.k
    assert res.stats["bootstrap"] == "device"


def test_devices_rejected_for_host_only_methods(problem):
    with pytest.raises(ValueError, match="no multi-device path"):
        partition(problem, method="rcb", devices=4)
    with pytest.raises(ValueError, match="no multi-device path"):
        partition(problem, hierarchy=(4, 2), method="rcb", devices=4)


def test_bootstrap_requires_devices(problem):
    with pytest.raises(TypeError, match="devices"):
        partition(problem, method="geographer", bootstrap="device")


def test_invalid_device_counts(problem):
    with pytest.raises(ValueError, match="out of range"):
        partition(problem, devices=4096)
    with pytest.raises(ValueError):
        partition(problem, devices=0)
    with pytest.raises(ValueError, match="bootstrap"):
        partition(problem, devices=2, bootstrap="quantum")
