"""Training substrate: optimizer, train step, compression, checkpointing,
fault-tolerant resume, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt import CheckpointManager
from repro.data.pipeline import Prefetcher, SyntheticLM, sfc_batch_order
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig, adamw_init, adamw_update, make_schedule
from repro.train import (Trainer, TrainerConfig, TrainHParams,
                         init_train_state, make_train_step)

MESH = make_host_mesh()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params, cfg)
    target = jnp.asarray([1.0, 1.0])
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(params, g, opt, cfg, 5e-2)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_moment_dtype():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = adamw_init(params, cfg)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,))}
    _, opt2, _ = adamw_update(params, g, opt, cfg, 1e-3)
    assert opt2["mu"]["w"].dtype == jnp.bfloat16


def test_grad_clip_caps_update():
    cfg = AdamWConfig(weight_decay=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.full((3,), 1e6)}
    _, _, stats = adamw_update(params, g, opt, cfg, 1e-3)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_schedules_warmup_and_decay():
    for kind in ("cosine", "linear", "constant"):
        f = make_schedule(kind, peak=1.0, warmup_steps=10, total_steps=100)
        assert float(f(jnp.int32(0))) == 0.0
        assert abs(float(f(jnp.int32(10))) - 1.0) < 0.11
        if kind != "constant":
            assert float(f(jnp.int32(100))) < 0.05


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def _mini_setup(arch="granite_moe_3b_a800m", compress="none", micro=1):
    cfg = configs.get_config(arch, smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    hp = TrainHParams(microbatches=micro, grad_compress=compress,
                      lr_peak=5e-3, warmup_steps=2, total_steps=50,
                      z_loss=1e-4)
    state = init_train_state(cfg, jax.random.PRNGKey(0), hp)
    step = jax.jit(make_train_step(cfg, rules, hp))
    data = iter(SyntheticLM(cfg, batch=4, seq=32))
    return cfg, state, step, data


@pytest.mark.parametrize("compress", ["none", "bf16", "int8"])
def test_loss_decreases(compress):
    cfg, state, step, data = _mini_setup(compress=compress)
    losses = []
    for _ in range(25):
        batch = jax.tree.map(jnp.asarray, next(data))
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_microbatch_equivalence():
    """grad accumulation over 2 microbatches ~= single big batch update."""
    cfg1, s1, step1, _ = _mini_setup(micro=1)
    cfg2, s2, step2, _ = _mini_setup(micro=2)
    batch = jax.tree.map(jnp.asarray,
                         next(iter(SyntheticLM(cfg1, batch=4, seq=32))))
    s1n, m1 = step1(s1, batch)
    s2n, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2)
    d1 = jax.tree.leaves(s1n["params"])[0]
    d2 = jax.tree.leaves(s2n["params"])[0]
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32),
                               rtol=5e-2, atol=5e-4)


def test_kmeans_router_influence_updates():
    """The balanced-k-means router state must move in response to load
    (paper Eq. 1 applied to experts) and stay positive."""
    cfg, state, step, data = _mini_setup()
    infl0 = np.asarray(state["influence"])
    assert (infl0 == 1.0).all()
    for _ in range(3):
        batch = jax.tree.map(jnp.asarray, next(data))
        state, m = step(state, batch)
    infl = np.asarray(state["influence"])
    assert (infl > 0).all()
    assert not np.allclose(infl, 1.0)       # it actually adapts
    assert np.abs(np.log(infl)).max() < 1.0  # clipped at 5%/step


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "b": {"c": jnp.int32(7)}}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x + s, state))
    assert mgr.all_steps() == [2, 3]         # keep_n GC
    restored, step = mgr.restore(state)
    assert step == 3
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(state["a"]) + 3)
    assert int(restored["b"]["c"]) == 10


def test_ckpt_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=5)
    state = {"a": jnp.ones(3)}
    mgr.save(1, state)
    # simulate torn write: directory without manifest
    os.makedirs(tmp_path / "step_000000002")
    (tmp_path / "step_000000002" / "leaf_00000.npy").write_bytes(b"junk")
    assert mgr.latest_step() == 1


def test_ckpt_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"a": jnp.ones(64)}
    mgr.save(1, state)
    f = tmp_path / "step_000000001" / "leaf_00000.npy"
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        mgr.restore(state)


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = {"a": jnp.full((32,), 3.0)}
    mgr.save(5, state)
    mgr.wait()
    restored, step = mgr.restore(state)
    assert step == 5
    np.testing.assert_allclose(np.asarray(restored["a"]), 3.0)


def test_ckpt_elastic_reshard(tmp_path):
    """Restore with explicit shardings (the elastic-mesh path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(1, state)
    sh = {"w": NamedSharding(MESH, P("data"))}
    restored, _ = mgr.restore(state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))


def test_trainer_resume_after_interrupt(tmp_path):
    """Preemption-style fault tolerance: train, 'lose the node', resume
    from the latest checkpoint and reach the target step count."""
    cfg = configs.get_config("gemma3_1b", smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    hp = TrainHParams(lr_peak=1e-3, warmup_steps=2, total_steps=20)
    tc = TrainerConfig(steps=6, log_every=2, ckpt_every=2,
                       ckpt_dir=str(tmp_path), keep_n=2)
    t1 = Trainer(cfg, rules, hp, tc)
    data = SyntheticLM(cfg, batch=2, seq=32)
    state, _ = t1.fit(iter(data))
    assert t1.ckpt.latest_step() == 6

    tc2 = TrainerConfig(steps=10, log_every=2, ckpt_every=2,
                        ckpt_dir=str(tmp_path), keep_n=2)
    t2 = Trainer(cfg, rules, hp, tc2)     # fresh process analogue
    state2, start = t2.init_or_resume()
    assert start == 6                     # resumed, not restarted
    state2, hist = t2.fit(iter(data), state2, start)
    assert int(jax.device_get(state2["opt"]["step"])) == 10


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_lm_deterministic():
    cfg = configs.get_config("gemma3_1b", smoke=True)
    a = next(iter(SyntheticLM(cfg, 2, 16, seed=4)))
    b = next(iter(SyntheticLM(cfg, 2, 16, seed=4)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < cfg.vocab_size
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_prefetcher_order():
    out = list(Prefetcher(range(7)))
    assert out == list(range(7))


def test_sfc_batch_order_locality(rng):
    pts = rng.uniform(0, 1, (1024, 2))
    batches, rest = sfc_batch_order(pts, 32)
    assert batches.shape == (32, 32)
    # batches from the Hilbert order are far more compact than random ones
    def spread(idx):
        return np.mean(np.ptp(pts[idx], axis=0))
    sfc_spread = np.mean([spread(b) for b in batches])
    rnd_spread = np.mean([spread(rng.permutation(1024)[:32])
                          for _ in range(32)])
    assert sfc_spread < 0.5 * rnd_spread
