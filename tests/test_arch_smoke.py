"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned archs: instantiate the SMOKE config, run one
forward + grad (train path) and a short decode, asserting output shapes
and absence of NaNs. The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models import moe as MOE

MESH = make_host_mesh()


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.input_mode == "tokens":
        toks = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                             jnp.int32)
    elif cfg.input_mode == "codebooks":
        toks = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S, cfg.n_codebooks)),
            jnp.int32)}
        labels = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S, cfg.n_codebooks)),
            jnp.int32)
    else:
        toks = {"embeddings": jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)}
        labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                             jnp.int32)
    return toks, labels


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get_config(arch, smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch, labels = make_batch(cfg)
    rs = MOE.init_router_state(cfg)
    infl = None if rs is None else rs["influence"]

    def loss(p):
        logits, ninf, stats = M.forward(p, batch, cfg, rules, influence=infl)
        if cfg.input_mode == "codebooks":
            assert logits.shape == (2, 32, cfg.n_codebooks, cfg.vocab_padded)
        else:
            assert logits.shape == (2, 32, cfg.vocab_padded)
        return M.loss_fn(logits, labels, cfg)

    val, g = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    gn = sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
             for x in jax.tree.leaves(g))
    assert np.isfinite(float(gn)) and float(gn) > 0.0
    # loss near uniform at init (sanity on the padded-vocab masking)
    assert float(val) < np.log(cfg.vocab_size) + 2.0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_scan_unroll_agree(arch):
    """Scanned and python-unrolled stacks must produce identical logits —
    the roofline extrapolation relies on the unrolled path being the same
    program."""
    cfg = configs.get_config(arch, smoke=True)
    rules = resolve_rules(MESH, cfg, "train")
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch, _ = make_batch(cfg, seed=1)
    l1, _, _ = jax.jit(lambda p: M.forward(p, batch, cfg, rules,
                                           unroll=False, remat=False))(params)
    l2, _, _ = jax.jit(lambda p: M.forward(p, batch, cfg, rules,
                                           unroll=True, remat=False))(params)
    # identical math; XLA fuses scan vs straight-line differently, so bf16
    # accumulation order differs by a few ulp — structural divergence would
    # be O(1), far above this tolerance
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=5e-2, atol=3e-2)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_prefill(arch):
    """Greedy decode-with-cache over a prompt must produce the same logits
    as the full (teacher-forced) forward — validates the KV/SSM cache path
    of every architecture."""
    if configs.get_config(arch, smoke=True).input_mode == "embeddings":
        pytest.skip("VLM stub decodes from embeddings; parity covered by "
                    "test below")
    cfg = configs.get_config(arch, smoke=True)
    if cfg.moe is not None:
        # teacher-forced forward drops tokens at expert capacity, decode
        # (one token per row) never does — compare drop-free
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    rules_t = resolve_rules(MESH, cfg, "train")
    rules_d = resolve_rules(MESH, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 16
    batch, _ = make_batch(cfg, B=B, S=S, seed=2)
    full_logits, _, _ = jax.jit(
        lambda p: M.forward(p, batch, cfg, rules_t, remat=False))(params)

    cache = M.init_cache(cfg, B, S, rules_d)
    step = jax.jit(lambda p, c, t, pos:
                   M.decode_step(p, c, {"tokens": t}, pos, cfg, rules_d))
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = step(params, cache, tok, jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_decode_embeddings_mode():
    """internvl2 (embeddings stub): decode parity against forward."""
    cfg = configs.get_config("internvl2_76b", smoke=True)
    rules_t = resolve_rules(MESH, cfg, "train")
    rules_d = resolve_rules(MESH, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 2, 8
    batch, _ = make_batch(cfg, B=B, S=S, seed=3)
    full_logits, _, _ = jax.jit(
        lambda p: M.forward(p, batch, cfg, rules_t, remat=False))(params)
    cache = M.init_cache(cfg, B, S, rules_d)
    step = jax.jit(lambda p, c, e, pos:
                   M.decode_step(p, c, {"embeddings": e}, pos, cfg, rules_d))
    outs = []
    for t in range(S):
        emb = batch["embeddings"][:, t:t + 1]
        lg, cache = step(params, cache, emb, jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ["gemma3_1b", "jamba_1p5_large_398b",
                                  "rwkv6_3b"])
def test_prefill_then_decode(arch):
    """prefill() must hand decode_step a cache equivalent to stepping
    token-by-token (the serving handoff)."""
    cfg = configs.get_config(arch, smoke=True)
    rules = resolve_rules(MESH, cfg, "decode")
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    B, P, EXTRA = 2, 16, 4
    batch, _ = make_batch(cfg, B=B, S=P, seed=4)
    logits_p, cache = jax.jit(
        lambda p, b: M.prefill(p, b, cfg, rules))(params, batch)
    assert logits_p.shape[1] == 1
    cache = M.extend_cache(cache, cfg, P + EXTRA)
    step = jax.jit(lambda p, c, t, pos:
                   M.decode_step(p, c, {"tokens": t}, pos, cfg, rules))
    # reference: token-by-token from scratch
    cache2 = M.init_cache(cfg, B, P + EXTRA, rules)
    for t in range(P):
        lg2, cache2 = step(params, cache2,
                           batch["tokens"][:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(lg2, np.float32),
                               rtol=5e-2, atol=5e-2)
    tok = jnp.argmax(logits_p[..., :cfg.vocab_size], -1).astype(jnp.int32)
    lg_a, cache = step(params, cache, tok, jnp.int32(P))
    lg_b, cache2 = step(params, cache2, tok, jnp.int32(P))
    np.testing.assert_allclose(np.asarray(lg_a, np.float32),
                               np.asarray(lg_b, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_param_counts_match_class():
    """Full configs land in the advertised parameter class."""
    expect = {"starcoder2_7b": (6e9, 9e9),
              "phi4_mini_3p8b": (3e9, 5e9),
              "phi3_mini_3p8b": (3e9, 4.6e9),
              "gemma3_1b": (0.7e9, 1.6e9),
              "musicgen_large": (1.5e9, 3e9),
              "jamba_1p5_large_398b": (330e9, 450e9),
              "llama4_maverick_400b_a17b": (350e9, 450e9),
              "granite_moe_3b_a800m": (2.5e9, 4e9),
              "rwkv6_3b": (2.5e9, 4e9),
              "internvl2_76b": (60e9, 80e9)}
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_active_params_moe():
    cfg = configs.get_config("llama4_maverick_400b_a17b")
    act = cfg.active_param_count()
    assert 12e9 <= act <= 25e9            # "a17b"
    g = configs.get_config("granite_moe_3b_a800m")
    assert 0.5e9 <= g.active_param_count() <= 1.2e9
