"""Property-based suite for the quality-metric layer (paper §2) and its
sharded counterpart (repro.eval) — runs under real hypothesis when
installed, or the deterministic fixed-example stub (tests/_stubs)
otherwise.

Host invariants (any partition of any mesh):
  * 0 <= edge_cut <= m, and totalCommVol <= 2 * edge_cut
  * totalCommVol >= maxCommVol >= 0, boundaryNodes <= totalCommVol
  * edge_cut == 0  <=>  comm_volume == 0  <=>  boundary_nodes == 0
  * imbalance(part, k) == imbalance(part, k, ones(n))
  * migration metrics are symmetric under (prev, new) swap and satisfy
    migration_fraction + retained_fraction == 1

Lock tests: ``comm_volume`` / ``boundary_nodes`` against a brute-force
per-node reference (the loop the vectorized unique-per-row formulation
replaced).

Sharded equality (tier2): the in-graph metrics agree with host numpy
bit-for-bit on randomized meshes at devices in {1, 2, 4, 8} — integer
counts commute exactly, so this is equality, not tolerance.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import meshes, metrics

FAMILIES = ["tri", "delaunay2d", "refined2d", "aniso", "rggpow"]

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 (virtual) jax devices")


def _instance(family: str, n: int, k: int, seed: int):
    """Randomized (mesh, labels) pair; labels cover arbitrary subsets of
    [0, k) including empty blocks."""
    mesh = meshes.REGISTRY[family](n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, k, mesh.n).astype(np.int64)
    return mesh, labels


def _brute_force_comm(part, indptr, indices, k):
    """The per-node reference implementation the vectorized formulation
    must match: walk each vertex's CSR row with a Python set."""
    per_block = np.zeros(k, np.int64)
    boundary = np.zeros(k, np.int64)
    for v in range(len(indptr) - 1):
        nbs = indices[indptr[v]:indptr[v + 1]]
        remote = set(part[nbs].tolist()) - {int(part[v])}
        per_block[part[v]] += len(remote)
        boundary[part[v]] += bool(remote)
    return per_block, boundary


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(150, 900),
       st.integers(1, 12), st.integers(0, 10 ** 6))
def test_host_metric_invariants(family, n, k, seed):
    mesh, labels = _instance(family, n, k, seed)
    cut = metrics.edge_cut(labels, mesh.indptr, mesh.indices)
    maxc, totc, per_block = metrics.comm_volume(labels, mesh.indptr,
                                                mesh.indices, k)
    bnd, bnd_pb = metrics.boundary_nodes(labels, mesh.indptr,
                                         mesh.indices, k)
    assert 0 <= cut <= mesh.m
    assert totc >= maxc >= 0
    assert totc == per_block.sum() and maxc == per_block.max(initial=0)
    assert totc <= 2 * cut                       # <= directed cut edges
    assert bnd == bnd_pb.sum() <= totc
    assert np.all(bnd_pb <= metrics.block_sizes(labels, k))
    assert (cut == 0) == (totc == 0) == (bnd == 0)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(0, 10 ** 6))
def test_cut_zero_iff_commvol_zero_on_uncut_partition(family, seed):
    """The <=> direction with an actually-uncut partition: everything in
    one block."""
    mesh, _ = _instance(family, 300, 4, seed)
    labels = np.zeros(mesh.n, np.int64)
    assert metrics.edge_cut(labels, mesh.indptr, mesh.indices) == 0
    maxc, totc, _ = metrics.comm_volume(labels, mesh.indptr,
                                        mesh.indices, 4)
    assert (maxc, totc) == (0, 0)
    assert metrics.boundary_nodes(labels, mesh.indptr,
                                  mesh.indices, 4)[0] == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 400), st.integers(1, 9), st.integers(0, 10 ** 6))
def test_imbalance_unit_equals_explicit_ones(n, k, seed):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, n).astype(np.int64)
    assert metrics.imbalance(part, k) == pytest.approx(
        metrics.imbalance(part, k, np.ones(n)))


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 500), st.integers(2, 8), st.integers(0, 10 ** 6))
def test_migration_metrics_symmetric_and_complementary(n, k, seed):
    rng = np.random.default_rng(seed)
    prev = rng.integers(0, k, n)
    new = rng.integers(0, k, n)
    w = rng.uniform(0.1, 5.0, n)
    for weights in (None, w):
        # moving A -> B costs exactly what moving B -> A would
        assert metrics.migration_volume(prev, new, weights) == \
            pytest.approx(metrics.migration_volume(new, prev, weights))
        frac = metrics.migration_fraction(prev, new, weights)
        assert frac == pytest.approx(
            metrics.migration_fraction(new, prev, weights))
        assert 0.0 <= frac <= 1.0
        assert metrics.retained_fraction(prev, new, weights) == \
            pytest.approx(1.0 - frac)
    # unit weights == explicit ones
    assert metrics.migration_volume(prev, new) == pytest.approx(
        float(metrics.migration_volume(prev, new, np.ones(n))))


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(100, 500),
       st.integers(1, 10), st.integers(0, 10 ** 6))
def test_comm_volume_matches_brute_force(family, n, k, seed):
    """Behavior lock for the vectorized unique-per-row formulation (and
    the shared helper behind boundary_nodes): exact match with the
    per-node set-walk reference."""
    mesh, labels = _instance(family, n, k, seed)
    ref_pb, ref_bnd = _brute_force_comm(labels, mesh.indptr,
                                        mesh.indices, k)
    maxc, totc, per_block = metrics.comm_volume(labels, mesh.indptr,
                                                mesh.indices, k)
    np.testing.assert_array_equal(per_block, ref_pb)
    assert totc == ref_pb.sum()
    assert maxc == ref_pb.max(initial=0)
    bnd, bnd_pb = metrics.boundary_nodes(labels, mesh.indptr,
                                         mesh.indices, k)
    np.testing.assert_array_equal(bnd_pb, ref_bnd)
    assert bnd == ref_bnd.sum()


@pytest.mark.tier2
@needs8
@settings(max_examples=5, deadline=None)
@given(st.sampled_from(FAMILIES), st.integers(120, 700),
       st.integers(1, 10), st.integers(0, 10 ** 6),
       st.sampled_from([1, 2, 4, 8]))
def test_sharded_equals_host_randomized(family, n, k, seed, devices):
    """Acceptance: eval.edge_cut_sharded / comm_volume_sharded /
    boundary_nodes_sharded agree EXACTLY with the numpy metrics at
    devices=1 and devices in {2, 4, 8}, on randomized meshes and
    randomized (not solver-produced) labelings."""
    from repro.eval import (boundary_nodes_sharded, comm_volume_sharded,
                            edge_cut_sharded)
    from repro.partition import PartitionProblem

    mesh, labels = _instance(family, n, k, seed)
    prob = PartitionProblem.from_mesh(mesh, k=max(k, 1), seed=seed)
    sg = prob.to_sharded_graph(devices)
    assert edge_cut_sharded(sg, labels) == metrics.edge_cut(
        labels, mesh.indptr, mesh.indices)
    hmax, htot, hpb = metrics.comm_volume(labels, mesh.indptr,
                                          mesh.indices, prob.k)
    smax, stot, spb = comm_volume_sharded(sg, labels)
    assert (smax, stot) == (hmax, htot)
    np.testing.assert_array_equal(spb, hpb)
    hbnd, hbnd_pb = metrics.boundary_nodes(labels, mesh.indptr,
                                           mesh.indices, prob.k)
    sbnd, sbnd_pb = boundary_nodes_sharded(sg, labels)
    assert sbnd == hbnd
    np.testing.assert_array_equal(sbnd_pb, hbnd_pb)
