"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only quality,...]

All partitioning benchmarks go through the unified engine
(``repro.partition``)::

    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
    res  = partition(prob, method="geographer")     # or rcb/rib/sfc/mj
    res  = partition(prob, hierarchy=(8, 8))        # hierarchical k1 x k2

so every tool row is one ``partition(problem, method=...)`` call and the
hierarchical (coarse Geographer + batched vmap refinement) mode appears
as its own row/column where applicable.

Modules:
  quality     — Tables 1-2 + Fig 2 (partition quality vs RCB/RIB/HSFC/MJ
                + hierarchical k1xk2)
  scaling     — Fig 3a/3b (weak/strong scaling; flat vs hierarchical)
  repartition — dynamic repartitioning: warm-started Geographer vs cold
                restart on a drifting-hotspot workload (iterations,
                migration volume, per-step balance)
  serving     — multi-tenant PartitionServer: slot-bucketed batched
                dispatch + warm-state cache vs all-cold serving
                (throughput, request latency, warm-hit rate)
  experiments — §5 comparison matrix: every registered method × the
                expanded mesh zoo, sharded in-graph evaluation, with the
                paper-trend summary (geographer vs sfc/rcb comm volume)
  components  — §5.3.2 component shares + §4.3 bound-skip-rate claim
  moe_router  — paper Eq. (1) as MoE load balancing (framework integration)
  roofline    — §Roofline/§Dry-run aggregation from results/dryrun/*.json
"""
from __future__ import annotations

import argparse
import time
import traceback

ALL = ["quality", "scaling", "repartition", "serving", "experiments",
       "components", "moe_router", "roofline"]


def _force_virtual_devices() -> None:
    """Expose 8 virtual CPU devices so the SPMD scaling section runs on
    single-CPU hosts. Must run before the first jax import — main() calls
    this before importing any benchmark module."""
    from repro.envflags import force_virtual_devices
    force_virtual_devices(8)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", action="store_true",
                    help="also emit machine-readable BENCH_<name>.json "
                         "regression files (quality, scaling, "
                         "repartition, serving, experiments)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else ALL
    _force_virtual_devices()

    failures = []
    for name in names:
        print(f"\n{'=' * 72}\n== benchmark: {name}\n{'=' * 72}")
        t0 = time.perf_counter()
        try:
            if name == "quality":
                from . import quality
                quality.run(quick=args.quick, json_out=args.json)
            elif name == "scaling":
                from . import scaling
                scaling.run(quick=args.quick, json_out=args.json)
            elif name == "repartition":
                from . import repartition
                repartition.run(quick=args.quick, json_out=args.json)
            elif name == "serving":
                from . import serving
                serving.run(quick=args.quick, json_out=args.json)
            elif name == "experiments":
                from . import experiments
                experiments.run(quick=args.quick, json_out=args.json)
            elif name == "components":
                from . import components
                components.run(quick=args.quick)
            elif name == "moe_router":
                from . import moe_router
                moe_router.run(quick=args.quick)
            elif name == "roofline":
                from . import roofline_table
                roofline_table.run(quick=args.quick)
            else:
                raise KeyError(name)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"[{name}] done in {time.perf_counter() - t0:.1f}s")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
