"""Aggregate results/dryrun/*.json into the §Roofline / §Dry-run tables.

Reads every per-cell record the dry-run sweep wrote and emits the
EXPERIMENTS.md tables: three terms + bottleneck + useful-compute ratio per
(arch x shape) on the single-pod mesh, plus the multi-pod fit table.

Also emits the §Partition-kernel roofline section: the analytic
assign-kernel sweep (launch/kernel_roofline.py) across platforms at the
hot-loop gate shape, plus the measured utilization record from
``BENCH_scaling.json`` when present.
"""
from __future__ import annotations

import glob
import json
import os

from .common import md_table, save_json

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")
BENCH_SCALING = "BENCH_scaling.json"


def load(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_rows(recs):
    rows = []
    for r in recs:
        if r.get("mesh") != "single" or r.get("skipped") or not r.get("ok"):
            continue
        rl = r.get("roofline")
        if not rl:
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "bottleneck": rl["bottleneck"],
            "model_GF": rl["model_flops"] / 1e9,
            "useful_ratio": rl["useful_ratio"],
            "roofline_frac": rl["roofline_frac"],
        })
    return rows


def fit_rows(recs):
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"arch": r.get("arch"), "shape": r.get("shape"),
                         "mesh": r.get("mesh"), "status": "FAILED"})
            continue
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skipped (full attn)"})
            continue
        m = r["memory"]
        rows.append({"arch": r["arch"], "shape": r["shape"],
                     "mesh": r["mesh"],
                     "status": "ok" if m["fits_hbm_16g"] else "OOM>16G",
                     "args_GB": m["argument_size_in_bytes"] / 2 ** 30,
                     "temp_GB": m["temp_size_in_bytes"] / 2 ** 30,
                     "live_GB": m["live_bytes"] / 2 ** 30,
                     "compile_s": r.get("compile_s", {}).get("compile")})
    return rows


def partition_kernel_rows(n: int = 1 << 20, d: int = 2, k: int = 64):
    """Analytic assign-kernel roofline per platform at the gate shape,
    with the measured record (BENCH_scaling.json) appended when present.
    Useful-vs-wasted compute shows up through ``prune_frac``: rows are
    emitted at 0% and 50% tile pruning so the table brackets what
    ``stats["tiles_pruned_frac"]`` buys at this shape."""
    from repro.launch.kernel_roofline import PLATFORMS, predict
    rows = []
    for platform in PLATFORMS:
        backend = "jnp" if platform == "cpu_host" else "pallas"
        for prune in (0.0, 0.5):
            p = predict(n, d, k, platform=platform, backend=backend,
                        prune_frac=prune)
            rows.append({
                "platform": platform, "backend": backend,
                "prune_frac": prune, "ai": p["ai"],
                "compute_ms": p["compute_s"] * 1e3,
                "memory_ms": p["memory_s"] * 1e3,
                "bound_ms": p["bound_s"] * 1e3,
                "bottleneck": p["bottleneck"], "utilization": None,
            })
    if os.path.exists(BENCH_SCALING):
        with open(BENCH_SCALING) as f:
            rec = json.load(f).get("roofline")
        if rec:
            rows.append({
                "platform": rec["platform"] + " (measured)",
                "backend": rec["backend"],
                "prune_frac": rec["prune_frac"], "ai": rec["ai"],
                "compute_ms": rec["compute_s"] * 1e3,
                "memory_ms": rec["memory_s"] * 1e3,
                "bound_ms": rec["bound_s"] * 1e3,
                "bottleneck": rec["bottleneck"],
                "utilization": rec["utilization"],
            })
    return rows


def run(quick: bool = False):
    pk = partition_kernel_rows()
    print("\n### §Partition-kernel roofline — assign sweep at the "
          "hot-loop gate shape (n=2^20, d=2, k=64)\n")
    print(md_table(pk, ["platform", "backend", "prune_frac", "ai",
                        "compute_ms", "memory_ms", "bound_ms",
                        "bottleneck", "utilization"]))
    recs = load()
    if not recs:
        print("no dry-run records found; run repro.launch.dryrun first")
        save_json("roofline_table", {"partition_kernel": pk})
        return {"partition_kernel": pk}
    rl = roofline_rows(recs)
    ft = fit_rows(recs)
    print("\n### §Roofline — three terms per (arch x shape), single pod "
          "(16x16 = 256 chips)\n")
    print(md_table(rl, ["arch", "shape", "compute_s", "memory_s",
                        "collective_s", "bottleneck", "useful_ratio",
                        "roofline_frac"]))
    print("\n### §Dry-run — compile + HBM fit, both meshes\n")
    print(md_table(ft, ["arch", "shape", "mesh", "status", "args_GB",
                        "temp_GB", "live_GB", "compile_s"]))
    ok = sum(1 for r in ft if r["status"] == "ok")
    sk = sum(1 for r in ft if "skip" in r["status"])
    bad = [r for r in ft if r["status"] not in ("ok",)
           and "skip" not in r["status"]]
    print(f"\ncells ok={ok} skipped={sk} problems={len(bad)}")
    out = {"roofline": rl, "fit": ft, "partition_kernel": pk}
    save_json("roofline_table", out)
    return out


if __name__ == "__main__":
    run()
