"""Paper Figure 3 + 4 analogue: scaling of the partitioner, through the
unified ``repro.partition`` engine.

No MPI cluster exists in this container, so the paper's weak/strong axes
map to what is measurable here:

* SPMD scaling — the headline section: the sharded shard_map partitioner
  (``partition(problem, method=..., devices=d)``) over 1/2/4/8 virtual
  host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
  set by benchmarks/run.py), flat geographer vs hierarchical k1 x k2 with
  a distributed coarse pass. Communication structure is identical to the
  paper's MPI version: psum'd global vector sums only. Per row we record
  wall time (steady-state, compile separated out), edge cut, total comm
  volume, imbalance and movement iterations — the regression-gate metric
  set of ``BENCH_scaling.json``.
* weak scaling — n grows with k at fixed n/k ("vertices per block"),
  wall-time per partition call (Fig. 3a analogue);
* strong scaling — fixed n, growing k (Fig. 3b analogue), flat vs
  hierarchical ``partition(hierarchy=(k1, k2))``.
* hot loop — one movement-iteration sweep (assignment + per-cluster
  moment reductions) at n=2^20: the fused assign+reduce backend mode vs
  the PR 4 fixed-chunk fused baseline, the unfused fallback (assignment,
  then a separate ``segment_moments`` sweep — bit-for-bit identical
  results) and the legacy pre-fusion hot loop (scatter-masked second-best
  + three global ``segment_sum`` passes, the shape this engine shipped
  with). Gated by ``tools/bench_compare.py``: fused must be >= 1.3x over
  legacy and >= 1.1x over the PR 4 fused baseline, must not lose to the
  fallback, and must stay bit-exact.
* roofline — analytic FLOPs/bytes/arithmetic-intensity of the hot-loop
  sweep (launch/kernel_roofline.py) against per-platform peaks, with the
  measured fused median folded in as achieved utilization; gated by
  ``compare_roofline`` (structure hard, utilization regression with
  ``--gate-time``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.core import meshes as MESH
from repro.partition import PartitionProblem, factor_k, partition

from .common import md_table, save_bench_json, save_json, timer

SPMD_DEVICE_COUNTS = (1, 2, 4, 8)
HOTLOOP_N = 1 << 20
HOTLOOP_K = 64

# weak-scaling memory probe (the paper's §6 scale claim, DESIGN.md §13):
# full-size nightly runs n = 2^24 (measured ratio ~0.78); the quick CI
# gate runs 2^22 — the smallest size where XLA's ~95 MiB fixed
# compile/runtime arena amortizes below the ceiling (2^21 measures ~1.47
# on fixed overhead alone). The probe runs in a FRESH subprocess because
# ru_maxrss/VmHWM are process-lifetime high-water marks — any earlier
# benchmark section would pollute the measurement.
WEAK_MEM_N = 1 << 24
WEAK_MEM_N_QUICK = 1 << 22
WEAK_MEM_K = 16
WEAK_MEM_DEVICES = 8
WEAK_MEM_CHUNK = 1 << 16
# hard memory ceiling: incremental peak RSS (over the post-import
# interpreter baseline) must stay <= 1.25x the analytic sharded working
# set — the old float64 full-host deal alone would add ~3x the source
# points on top (f64 dealt copy + f64 weights), blowing this envelope
WEAK_MEM_RSS_CEILING = 1.25


def _rss_now_bytes() -> int:
    """Current RSS (Linux /proc; 0 where unavailable)."""
    try:
        with open("/proc/self/statm") as fh:
            return int(fh.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError, IndexError):
        return 0


def _rss_peak_bytes() -> int:
    """Lifetime peak RSS: VmHWM (Linux) with an ru_maxrss fallback."""
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) * 1024
    except (OSError, ValueError, IndexError):
        pass
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024


def weak_mem_working_set_bytes(n: int, d: int, devices: int,
                               chunk: int) -> int:
    """Analytic resident working set of ``from_problem``+solve, in bytes.

    Every term is an intended O(n) allocation of the streaming-deal
    sharded path (float32 problem, float32 solve dtype); the memory gate
    asserts the *measured* incremental peak stays within
    ``WEAK_MEM_RSS_CEILING`` of this sum — a reintroduced float64 host
    copy of the dealt points (+weights) adds ~12n bytes on top of the
    8n-byte f32 source at d=2 and breaks the envelope.
    """
    cap = -(-n // devices)
    pc = devices * cap                       # padded point count (~n)
    return (
        n * d * 4                # problem.points (f32 source)
        + 8 * n                  # seed permutation (int64, deal staging)
        + 8 * pc + pc            # gather (int64) + valid (bool)
        + devices * min(chunk, cap) * (d + 1) * 4   # per-slice staging
        + pc * (d + 1) * 4       # committed device points + weights (f32)
        + 4 * n                  # host unit weights staged during the deal
        + 4 * pc                 # prev-labels placeholder (int32)
        + 9 * 4 * pc             # solver live set (~9 n-sized f32/i32)
        + 8 * n + 4 * pc         # scattered labels (i64) + host label copy
    )


def memprobe(n: int, k: int, devices: int, chunk: int) -> dict:
    """Measure peak RSS of one out-of-core sharded partition call.

    Runs ``from_problem`` (streaming deal, placement-commit) + the solve
    with the in-graph device bootstrap — the path with no O(n) float64
    host allocation — and reports the incremental peak RSS over the
    post-import interpreter baseline against the analytic working set.
    Invoked in a fresh subprocess by ``weak_scaling_memory`` (the RSS
    high-water mark is only meaningful in a process that has run nothing
    else); prints the record as JSON on stdout with ``--memprobe``.
    """
    baseline = _rss_now_bytes()
    rng = np.random.default_rng(0)
    pts = rng.random((n, 2), dtype=np.float32)
    prob = PartitionProblem(points=pts, k=k, epsilon=0.05, seed=5)
    t0 = timer()
    res = partition(prob, method="geographer", devices=devices,
                    chunk=chunk, bootstrap="device", warmup=False,
                    max_iter=5)
    dt = timer() - t0
    peak = _rss_peak_bytes()
    ws = weak_mem_working_set_bytes(n, 2, devices, chunk)
    delta = max(peak - baseline, 0)
    return {
        "n": n, "k": k, "d": 2, "devices": devices, "chunk": chunk,
        "baseline_rss_bytes": baseline, "peak_rss_bytes": peak,
        "incremental_peak_bytes": delta, "working_set_bytes": ws,
        "rss_ratio": delta / ws, "rss_ceiling": WEAK_MEM_RSS_CEILING,
        "under_ceiling": bool(delta <= WEAK_MEM_RSS_CEILING * ws),
        "naive_f64_extra_bytes": 12 * n,     # the fixed up-cast would add
        "time_s": dt, "imbalance": float(res.imbalance()),
        "points_dtype": "float32",
    }


def _parity_checks() -> dict:
    """In-process bit-parity booleans riding on the weak_scaling record:
    chunked deal == one-shot deal, and 2-D mesh (2, 4) == flat 8 on both
    the flat and the hierarchical label path (modest n — the property is
    layout/trace identity, not scale)."""
    import jax
    rng = np.random.default_rng(3)
    n = 4099
    prob = PartitionProblem(points=rng.random((n, 2)).astype(np.float32),
                            weights=rng.uniform(0.5, 2.0, n)
                            .astype(np.float32),
                            k=8, epsilon=0.05, seed=11)
    one = prob.to_sharded(4)
    deal_ok = all(
        np.array_equal(one.points, sp.points)
        and np.array_equal(one.weights, sp.weights)
        and np.array_equal(one.gather, sp.gather)
        and np.array_equal(one.valid, sp.valid)
        for sp in (prob.to_sharded(4, chunk=c) for c in (1, 17, 1 << 30)))
    roundtrip = one.scatter_labels(
        np.asarray(one.deal(np.arange(n) % prob.k, chunk=13)), chunk=13)
    deal_ok = deal_ok and bool(np.array_equal(roundtrip, np.arange(n) % 8))
    if len(jax.devices()) < 8:
        return {"chunked_deal_bitexact": deal_ok,
                "mesh2d_labels_equal": None}
    flat = partition(prob, devices=8)
    flat2d = partition(prob, devices=(2, 4))
    hier = partition(prob, hierarchy=(4, 2), devices=8)
    hier2d = partition(prob, hierarchy=(4, 2), devices=(2, 4))
    return {
        "chunked_deal_bitexact": deal_ok,
        "mesh2d_labels_equal": bool(
            np.array_equal(flat.labels, flat2d.labels)
            and np.array_equal(hier.labels, hier2d.labels)),
    }


def weak_scaling_memory(quick: bool = False) -> dict:
    """The §6 scale-claim record: subprocess peak-RSS probe of the
    out-of-core sharded deal + solve, plus the bit-parity booleans.

    The probe result is gated hard by ``tools/bench_compare.py``
    (``compare_weak_scaling``): incremental peak RSS <= 1.25x the
    analytic sharded working set, chunked deal bit-identical to one-shot,
    and 2-D mesh labels bit-identical to the flat composition.
    """
    n = WEAK_MEM_N_QUICK if quick else WEAK_MEM_N
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{WEAK_MEM_DEVICES}")
    env.setdefault("PYTHONPATH", "src")
    cmd = [sys.executable, "-m", "benchmarks.scaling", "--memprobe",
           str(n), str(WEAK_MEM_K), str(WEAK_MEM_DEVICES),
           str(WEAK_MEM_CHUNK)]
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=repo_root, check=False)
    if proc.returncode != 0:
        raise RuntimeError(
            f"memprobe subprocess failed ({proc.returncode}):\n"
            f"{proc.stdout}\n{proc.stderr}")
    rec = json.loads(proc.stdout.splitlines()[-1])
    rec.update(_parity_checks())
    print(f"  weak-mem n=2^{int(np.log2(rec['n']))} "
          f"devices={rec['devices']} chunk={rec['chunk']}: "
          f"peak={rec['incremental_peak_bytes'] / 2**20:.0f}MiB over "
          f"baseline vs working-set={rec['working_set_bytes'] / 2**20:.0f}"
          f"MiB -> ratio={rec['rss_ratio']:.2f} "
          f"(ceiling {rec['rss_ceiling']}), t={rec['time_s']:.2f}s, "
          f"deal_bitexact={rec['chunked_deal_bitexact']} "
          f"mesh2d_equal={rec['mesh2d_labels_equal']}")
    return rec


def _available_device_counts():
    import jax
    n = len(jax.devices())
    return tuple(d for d in SPMD_DEVICE_COUNTS if d <= n)


def _spmd_row(prob, method, d):
    """Timed sharded run: first call (compile + run), second call
    (steady state), then the paper metric set."""
    kw = (dict(method="geographer", devices=d) if method == "flat"
          else dict(hierarchy=factor_k(prob.k), devices=d))
    t0 = timer()
    partition(prob, **kw)
    t_first = timer() - t0
    t0 = timer()
    res = partition(prob, **kw)
    t_steady = timer() - t0
    ev = res.evaluate()
    # movement iterations: the flat path reports them at level 0, the
    # hierarchical path per refinement block at level 1 — take the max
    per_level = [lvl.get("iters") for lvl in res.stats["levels"]
                 if lvl.get("iters") is not None]
    iters = int(max(np.max(v) for v in per_level)) if per_level else None
    row = {"method": method, "devices": d, "n": prob.n, "k": prob.k,
           "time_s": t_steady, "compile_s": max(t_first - t_steady, 0.0),
           "cut": ev["cut"], "totalCommVol": ev["totalCommVol"],
           "imbalance": ev["imbalance"], "iters": iters,
           "balanced": bool(ev["imbalance"] <= prob.epsilon + 1e-6)}
    return row


def spmd_scaling(n: int = 60_000, k: int = 64, quick: bool = False):
    """Flat and hierarchical sharded runs over 1/2/4/8 virtual devices."""
    if quick:
        n, k = 8_000, 16
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=3)
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
    rows = []
    for method in ("flat", "hierarchical"):
        for d in _available_device_counts():
            row = _spmd_row(prob, method, d)
            rows.append(row)
            print(f"  spmd {method:12s} devices={d} t={row['time_s']:.2f}s "
                  f"(compile {row['compile_s']:.1f}s) cut={row['cut']} "
                  f"imb={row['imbalance']:.3f}")
    return rows


def weak_scaling(per_block: int = 1500, ks=(4, 8, 16, 32, 64),
                 quick: bool = False):
    if quick:
        per_block, ks = 800, (4, 8, 16)
    rows = []
    for k in ks:
        n = per_block * k
        mesh = MESH.REGISTRY["delaunay2d"](n, seed=1)
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        res = partition(prob, method="geographer")
        dt = timer() - t0
        rows.append({"k": k, "n": n, "time_s": dt,
                     "us_per_point": dt / n * 1e6,
                     "blocks_used": int(len(np.unique(res.labels)))})
        print(f"  weak k={k:4d} n={n:8d} t={dt:.2f}s")
    return rows


def strong_scaling(n: int = 60_000, ks=(4, 8, 16, 32, 64, 128),
                   quick: bool = False):
    """Flat vs hierarchical wall time as k grows at fixed n."""
    if quick:
        n, ks = 12_000, (4, 16, 64)
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=2)
    rows = []
    for k in ks:
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        flat = partition(prob, method="geographer")
        t_flat = timer() - t0
        k1, k2 = factor_k(k)
        if k2 > 1:
            t0 = timer()
            hier = partition(prob, hierarchy=(k1, k2))
            t_hier = timer() - t0
            imb_h = hier.imbalance()
        else:
            t_hier, imb_h = float("nan"), float("nan")
        rows.append({"k": k, "n": n, "time_flat_s": t_flat,
                     "time_hier_s": t_hier, "hier": f"{k1}x{k2}",
                     "imb_flat": flat.imbalance(), "imb_hier": imb_h})
        print(f"  strong k={k:4d} flat={t_flat:.2f}s "
              f"hier[{k1}x{k2}]={t_hier:.2f}s")
    return rows


def hotloop(n: int = HOTLOOP_N, k: int = HOTLOOP_K, d: int = 2,
            reps: int = 5, quick: bool = False):
    """The paper's hot loop (one movement-iteration sweep) four ways.

    * ``fused``     — backend ``return_moments=True``: assignment +
      moments in ONE pass over the points (the engine default: adaptive
      ``default_chunk`` keeps the [chunk, k] scratch cache-resident and
      the argmin-free epilogue keeps every reduction vectorized).
    * ``fused_pr4`` — the PR 4 fused hot loop exactly as it shipped
      (fixed ``chunk=65536``, argmin epilogue), inlined here so later
      optimizations to ``assign_argmin_jnp`` can't leak into the
      baseline the >= 1.1x gate measures against; labels stay
      bit-identical to ``fused`` (chunk-invariance + the exact
      first-occurrence index trick).
    * ``fallback``  — the shipped unfused path for backends without moment
      support: assignment, then a ``segment_moments`` sweep sharing the
      fused path's reduction structure (results bit-for-bit identical).
    * ``legacy``    — the pre-fusion hot loop exactly as the seed shipped
      it: scatter-masked second-best in the assignment plus three global
      ``segment_sum`` reductions (reads every point twice).

    Also emits the ``roofline`` record (launch/kernel_roofline.py):
    analytic FLOPs/bytes/AI of the sweep plus the measured ``fused``
    median -> achieved utilization, gated by ``compare_roofline``.

    ``quick`` does not shrink the problem — the gate's n=2^20 case runs
    in CI too, with the full rep count (the median feeds a hard gate).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import (assign_argmin_jnp, default_chunk,
                                   resolve_assign_backend, segment_moments)
    from repro.launch.kernel_roofline import kernel_roofline_record

    del quick
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1, (n, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, n).astype(np.float32))
    ctr = jnp.asarray(rng.uniform(0, 1, (k, d)).astype(np.float32))
    infl = jnp.ones(k, jnp.float32)

    @jax.jit
    def fused(p, w_, c, i_):
        return assign_argmin_jnp(p, c, i_, weights=w_, return_moments=True)

    @jax.jit
    def fused_pr4(p, w_, c, i_):
        # the PR 4 fused hot loop exactly as it shipped: fixed
        # chunk=65536 and the argmin-based epilogue (self-contained so
        # later optimizations to assign_argmin_jnp can't leak in)
        inv2 = 1.0 / (i_ * i_)
        cn = jnp.sum(c * c, axis=1)

        def one_chunk(args):
            pc, wc = args
            pn = jnp.sum(pc * pc, axis=1, keepdims=True)
            eff = jnp.maximum(pn + cn[None, :] - 2.0 * pc @ c.T,
                              0.0) * inv2[None, :]
            idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
            onehot = idx[:, None] == jnp.arange(k)[None, :]
            best = jnp.min(eff, axis=1)
            second = jnp.min(jnp.where(onehot, jnp.inf, eff), axis=1)
            ww = jnp.where(onehot, wc[:, None], 0.0)
            stacked = jnp.concatenate(
                [pc, jnp.ones((pc.shape[0], 1), pc.dtype),
                 best[:, None]], axis=1)
            return idx, best, second, ww.T @ stacked

        chunk = 65536
        pp = p.reshape(-1, chunk, d)
        wc = w_.reshape(-1, chunk)
        idx, b, s, m = jax.lax.map(one_chunk, (pp, wc))
        m = m.sum(axis=0)
        return (idx.reshape(-1), b.reshape(-1), s.reshape(-1),
                m[:, :d], m[:, d], m[:, d + 1])

    @jax.jit
    def fallback(p, w_, c, i_):
        idx, b, s = assign_argmin_jnp(p, c, i_)
        return (idx, b, s) + segment_moments(p, w_, idx, b, k)

    @jax.jit
    def legacy(p, w_, c, i_):
        inv2 = 1.0 / (i_ * i_)
        cn = jnp.sum(c * c, axis=1)

        def one_chunk(pc):
            pn = jnp.sum(pc * pc, axis=1, keepdims=True)
            eff = jnp.maximum(pn + cn[None, :] - 2.0 * pc @ c.T,
                              0.0) * inv2[None, :]
            idx = jnp.argmin(eff, axis=1).astype(jnp.int32)
            best = jnp.take_along_axis(eff, idx[:, None], axis=1)[:, 0]
            masked = eff.at[jnp.arange(pc.shape[0]), idx].set(jnp.inf)
            return idx, best, jnp.min(masked, axis=1)

        chunk = 65536
        pad = (-p.shape[0]) % chunk
        pp = jnp.pad(p, ((0, pad), (0, 0)))
        idx, b, s = jax.lax.map(one_chunk, pp.reshape(-1, chunk, d))
        idx = idx.reshape(-1)[:p.shape[0]]
        b = b.reshape(-1)[:p.shape[0]]
        s = s.reshape(-1)[:p.shape[0]]
        csum = jax.ops.segment_sum(w_[:, None] * p, idx, num_segments=k)
        cw = jax.ops.segment_sum(w_, idx, num_segments=k)
        rad2 = jax.ops.segment_sum(w_ * b, idx, num_segments=k)
        return idx, b, s, csum, cw, rad2

    fns = {"fused": fused, "fused_pr4": fused_pr4, "fallback": fallback,
           "legacy": legacy}
    outs, times = {}, {v: [] for v in fns}
    for name, f in fns.items():                       # compile
        outs[name] = jax.block_until_ready(f(pts, w, ctr, infl))
    for _ in range(reps):                             # interleave reps
        for name, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(pts, w, ctr, infl))
            times[name].append(time.perf_counter() - t0)
    med = {name: float(np.median(ts)) for name, ts in times.items()}
    bitexact = all(bool(jnp.all(a == b))
                   for a, b in zip(outs["fused"], outs["fallback"]))
    labels_equal = all(bool(jnp.all(outs["fused"][0] == outs[v][0]))
                       for v in ("fused_pr4", "fallback", "legacy"))
    backend = resolve_assign_backend("auto")
    roofline = kernel_roofline_record(
        n, d, k, measured_s=med["fused"], backend=backend)
    roofline["chunk"] = default_chunk(k)
    out = {
        "n": n, "k": k, "d": d, "reps": reps,
        "rows": [{"variant": v, "time_s": med[v]} for v in fns],
        "speedup_vs_legacy": med["legacy"] / med["fused"],
        "speedup_vs_fallback": med["fallback"] / med["fused"],
        "speedup_vs_pr4_fused": med["fused_pr4"] / med["fused"],
        "bitexact": bitexact, "labels_equal": labels_equal,
        "roofline": roofline,
    }
    print(f"  hotloop n={n} k={k}: "
          f"fused={med['fused']:.3f}s pr4={med['fused_pr4']:.3f}s "
          f"fallback={med['fallback']:.3f}s "
          f"legacy={med['legacy']:.3f}s -> {out['speedup_vs_legacy']:.2f}x "
          f"vs legacy, {out['speedup_vs_pr4_fused']:.2f}x vs pr4 fused, "
          f"bitexact={bitexact}")
    print(f"  roofline [{roofline['platform']}/{backend}]: "
          f"AI={roofline['ai']:.2f} flop/byte, "
          f"bound={roofline['bound_s'] * 1e3:.1f}ms "
          f"({roofline['bottleneck']}), measured={med['fused'] * 1e3:.1f}ms "
          f"-> utilization={roofline['utilization']:.3f}")
    return out


def run(quick: bool = False, json_out: bool = False):
    print("\n### SPMD scaling — sharded shard_map partitioner, "
          "1/2/4/8 virtual devices (flat vs hierarchical)\n")
    spmd = spmd_scaling(quick=quick)
    print(md_table(spmd, ["method", "devices", "time_s", "compile_s",
                          "cut", "totalCommVol", "imbalance", "iters"]))
    print("\n### Fig 3a analogue — weak scaling (n/k fixed)\n")
    weak = weak_scaling(quick=quick)
    print(md_table(weak, ["k", "n", "time_s", "us_per_point"]))
    print("\n### Fig 3b analogue — strong scaling (n fixed, k grows; "
          "flat vs hierarchical k1xk2)\n")
    strong = strong_scaling(quick=quick)
    print(md_table(strong, ["k", "hier", "time_flat_s", "time_hier_s",
                            "imb_flat", "imb_hier"]))
    print("\n### Hot loop — fused assign+reduce vs unfused "
          "(one movement-iteration sweep, n=2^20)\n")
    hot = hotloop(quick=quick)
    print(md_table(hot["rows"], ["variant", "time_s"]))
    roofline = hot.pop("roofline")
    print("\n### Weak-scaling memory — out-of-core sharded deal, "
          "subprocess peak-RSS probe\n")
    weak_mem = weak_scaling_memory(quick=quick)
    out = {"spmd": spmd, "weak": weak, "strong": strong, "hotloop": hot,
           "roofline": roofline, "weak_scaling": weak_mem, "quick": quick}
    save_json("scaling", out)
    if json_out:
        save_bench_json("scaling", out)
    return out


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--memprobe":
        n_, k_, p_, c_ = (int(a) for a in sys.argv[2:6])
        print(json.dumps(memprobe(n_, k_, p_, c_)))
    else:
        run()
