"""Paper Figure 3 + 4 analogue: scaling of the partitioner, through the
unified ``repro.partition`` engine.

No MPI cluster exists in this container, so the paper's weak/strong axes
map to what is measurable here:

* SPMD scaling — the headline section: the sharded shard_map partitioner
  (``partition(problem, method=..., devices=d)``) over 1/2/4/8 virtual
  host devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
  set by benchmarks/run.py), flat geographer vs hierarchical k1 x k2 with
  a distributed coarse pass. Communication structure is identical to the
  paper's MPI version: psum'd global vector sums only. Per row we record
  wall time (steady-state, compile separated out), edge cut, total comm
  volume, imbalance and movement iterations — the regression-gate metric
  set of ``BENCH_scaling.json``.
* weak scaling — n grows with k at fixed n/k ("vertices per block"),
  wall-time per partition call (Fig. 3a analogue);
* strong scaling — fixed n, growing k (Fig. 3b analogue), flat vs
  hierarchical ``partition(hierarchy=(k1, k2))``.
"""
from __future__ import annotations

import numpy as np

from repro.core import meshes as MESH
from repro.partition import PartitionProblem, factor_k, partition

from .common import md_table, save_bench_json, save_json, timer

SPMD_DEVICE_COUNTS = (1, 2, 4, 8)


def _available_device_counts():
    import jax
    n = len(jax.devices())
    return tuple(d for d in SPMD_DEVICE_COUNTS if d <= n)


def _spmd_row(prob, method, d):
    """Timed sharded run: first call (compile + run), second call
    (steady state), then the paper metric set."""
    kw = (dict(method="geographer", devices=d) if method == "flat"
          else dict(hierarchy=factor_k(prob.k), devices=d))
    t0 = timer()
    partition(prob, **kw)
    t_first = timer() - t0
    t0 = timer()
    res = partition(prob, **kw)
    t_steady = timer() - t0
    ev = res.evaluate()
    # movement iterations: the flat path reports them at level 0, the
    # hierarchical path per refinement block at level 1 — take the max
    per_level = [lvl.get("iters") for lvl in res.stats["levels"]
                 if lvl.get("iters") is not None]
    iters = int(max(np.max(v) for v in per_level)) if per_level else None
    row = {"method": method, "devices": d, "n": prob.n, "k": prob.k,
           "time_s": t_steady, "compile_s": max(t_first - t_steady, 0.0),
           "cut": ev["cut"], "totalCommVol": ev["totalCommVol"],
           "imbalance": ev["imbalance"], "iters": iters,
           "balanced": bool(ev["imbalance"] <= prob.epsilon + 1e-6)}
    return row


def spmd_scaling(n: int = 60_000, k: int = 64, quick: bool = False):
    """Flat and hierarchical sharded runs over 1/2/4/8 virtual devices."""
    if quick:
        n, k = 8_000, 16
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=3)
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
    rows = []
    for method in ("flat", "hierarchical"):
        for d in _available_device_counts():
            row = _spmd_row(prob, method, d)
            rows.append(row)
            print(f"  spmd {method:12s} devices={d} t={row['time_s']:.2f}s "
                  f"(compile {row['compile_s']:.1f}s) cut={row['cut']} "
                  f"imb={row['imbalance']:.3f}")
    return rows


def weak_scaling(per_block: int = 1500, ks=(4, 8, 16, 32, 64),
                 quick: bool = False):
    if quick:
        per_block, ks = 800, (4, 8, 16)
    rows = []
    for k in ks:
        n = per_block * k
        mesh = MESH.REGISTRY["delaunay2d"](n, seed=1)
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        res = partition(prob, method="geographer")
        dt = timer() - t0
        rows.append({"k": k, "n": n, "time_s": dt,
                     "us_per_point": dt / n * 1e6,
                     "blocks_used": int(len(np.unique(res.labels)))})
        print(f"  weak k={k:4d} n={n:8d} t={dt:.2f}s")
    return rows


def strong_scaling(n: int = 60_000, ks=(4, 8, 16, 32, 64, 128),
                   quick: bool = False):
    """Flat vs hierarchical wall time as k grows at fixed n."""
    if quick:
        n, ks = 12_000, (4, 16, 64)
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=2)
    rows = []
    for k in ks:
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        flat = partition(prob, method="geographer")
        t_flat = timer() - t0
        k1, k2 = factor_k(k)
        if k2 > 1:
            t0 = timer()
            hier = partition(prob, hierarchy=(k1, k2))
            t_hier = timer() - t0
            imb_h = hier.imbalance()
        else:
            t_hier, imb_h = float("nan"), float("nan")
        rows.append({"k": k, "n": n, "time_flat_s": t_flat,
                     "time_hier_s": t_hier, "hier": f"{k1}x{k2}",
                     "imb_flat": flat.imbalance(), "imb_hier": imb_h})
        print(f"  strong k={k:4d} flat={t_flat:.2f}s "
              f"hier[{k1}x{k2}]={t_hier:.2f}s")
    return rows


def run(quick: bool = False, json_out: bool = False):
    print("\n### SPMD scaling — sharded shard_map partitioner, "
          "1/2/4/8 virtual devices (flat vs hierarchical)\n")
    spmd = spmd_scaling(quick=quick)
    print(md_table(spmd, ["method", "devices", "time_s", "compile_s",
                          "cut", "totalCommVol", "imbalance", "iters"]))
    print("\n### Fig 3a analogue — weak scaling (n/k fixed)\n")
    weak = weak_scaling(quick=quick)
    print(md_table(weak, ["k", "n", "time_s", "us_per_point"]))
    print("\n### Fig 3b analogue — strong scaling (n fixed, k grows; "
          "flat vs hierarchical k1xk2)\n")
    strong = strong_scaling(quick=quick)
    print(md_table(strong, ["k", "hier", "time_flat_s", "time_hier_s",
                            "imb_flat", "imb_hier"]))
    out = {"spmd": spmd, "weak": weak, "strong": strong, "quick": quick}
    save_json("scaling", out)
    if json_out:
        save_bench_json("scaling", out)
    return out


if __name__ == "__main__":
    run()
