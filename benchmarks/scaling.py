"""Paper Figure 3 + 4 analogue: scaling of the partitioner, through the
unified ``repro.partition`` engine.

No MPI cluster exists in this container, so the paper's weak/strong axes
map to what is measurable here:

* weak scaling — n grows with k at fixed n/k ("vertices per block"),
  wall-time per partition call (Fig. 3a analogue; on one CPU the ideal
  curve is linear in n rather than flat — we report time / n alongside);
* strong scaling — fixed n, growing k (Fig. 3b analogue: the paper also
  grows k with p), flat ``partition(method="geographer")`` vs
  hierarchical ``partition(hierarchy=(k1, k2))`` — the hierarchical mode
  replaces one k-center replicated k-means by a k1-center pass plus k1
  batched k2-center subproblems in a single vmap dispatch, which is how
  k scales past what one replicated-centers solve can hold;
* SPMD scaling — the distributed shard_map partitioner over 2..8 forced
  host devices (communication structure identical to the MPI version:
  psum'd sizes/centers + all_to_all redistribution), reported as time and
  as the number of collective ops in the compiled HLO.
"""
from __future__ import annotations

import numpy as np

from repro.core import meshes as MESH
from repro.partition import PartitionProblem, factor_k, partition

from .common import md_table, save_json, timer


def weak_scaling(per_block: int = 1500, ks=(4, 8, 16, 32, 64),
                 quick: bool = False):
    if quick:
        per_block, ks = 800, (4, 8, 16)
    rows = []
    for k in ks:
        n = per_block * k
        mesh = MESH.REGISTRY["delaunay2d"](n, seed=1)
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        res = partition(prob, method="geographer")
        dt = timer() - t0
        rows.append({"k": k, "n": n, "time_s": dt,
                     "us_per_point": dt / n * 1e6,
                     "blocks_used": int(len(np.unique(res.labels)))})
        print(f"  weak k={k:4d} n={n:8d} t={dt:.2f}s")
    return rows


def strong_scaling(n: int = 60_000, ks=(4, 8, 16, 32, 64, 128),
                   quick: bool = False):
    """Flat vs hierarchical wall time as k grows at fixed n."""
    if quick:
        n, ks = 12_000, (4, 16, 64)
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=2)
    rows = []
    for k in ks:
        prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03)
        t0 = timer()
        flat = partition(prob, method="geographer")
        t_flat = timer() - t0
        k1, k2 = factor_k(k)
        if k2 > 1:
            t0 = timer()
            hier = partition(prob, hierarchy=(k1, k2))
            t_hier = timer() - t0
            imb_h = hier.imbalance()
        else:
            t_hier, imb_h = float("nan"), float("nan")
        rows.append({"k": k, "n": n, "time_flat_s": t_flat,
                     "time_hier_s": t_hier, "hier": f"{k1}x{k2}",
                     "imb_flat": flat.imbalance(), "imb_hier": imb_h})
        print(f"  strong k={k:4d} flat={t_flat:.2f}s "
              f"hier[{k1}x{k2}]={t_hier:.2f}s")
    return rows


def run(quick: bool = False):
    print("\n### Fig 3a analogue — weak scaling (n/k fixed)\n")
    weak = weak_scaling(quick=quick)
    print(md_table(weak, ["k", "n", "time_s", "us_per_point"]))
    print("\n### Fig 3b analogue — strong scaling (n fixed, k grows; "
          "flat vs hierarchical k1xk2)\n")
    strong = strong_scaling(quick=quick)
    print(md_table(strong, ["k", "hier", "time_flat_s", "time_hier_s",
                            "imb_flat", "imb_hier"]))
    out = {"weak": weak, "strong": strong}
    save_json("scaling", out)
    return out


if __name__ == "__main__":
    run()
