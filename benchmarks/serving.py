"""Multi-tenant partition serving benchmark (DESIGN.md §10).

Drives ``repro.serve.PartitionServer`` with a fleet of tenants whose load
drifts every step (the PR 3 drifting-hotspot workload), twice over the
SAME request stream:

* **warm** — the caching server: step 0 cold-starts every tenant, every
  later request hits the warm-state slot cache and resumes balanced
  k-means from the tenant's previous (centers, influence);
* **cold** — ``cache_slots=0``: the identical stream served with every
  solve cold-started (fresh SFC bootstrap), the fair all-cold baseline.

Reported (and gated by ``tools/bench_compare.py compare_serving`` against
``benchmarks/baselines/BENCH_serving.json``):

* ``iters_ratio`` — cold/warm mean movement iterations over the steady
  state (steps >= 1); the acceptance claim is >= 3x.
* ``warm_hit_rate`` — fraction of requests served from warm state
  (steady state: (T-1)/T with a large-enough cache).
* ``problems_per_s`` / ``p50_ms`` / ``p99_ms`` — serving throughput and
  request latency over the post-compile steady state (wall-clock: soft
  gates unless ``--gate-time``).
* every request balanced, in both runs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import meshes as MESH
from repro.partition import PartitionProblem
from repro.serve import PartitionServer, request_stream

from .common import md_table, save_bench_json, save_json

STEPS = {"quick": 8, "full": 12}
# heterogeneous fleet: (n, k) per tenant, spanning two tiers and two k's
# so the slot-bucket router actually multiplexes (quick: 2048/4096 caps)
TENANTS = {
    "quick": [(1800, 8), (2048, 8), (3500, 16), (4000, 16)],
    "full": [(7000, 16), (8192, 16), (14000, 32), (16000, 32)],
}
TIERS = {"quick": (1024, 2048, 4096), "full": (2048, 4096, 8192, 16384)}
SLOTS = 2
WARMUP_STEPS = 2     # step 0 compiles cold buckets, step 1 warm buckets


def _fleet(quick: bool) -> list[PartitionProblem]:
    probs = []
    for i, (n, k) in enumerate(TENANTS["quick" if quick else "full"]):
        mesh = MESH.REGISTRY["delaunay2d"](n, seed=10 + i)
        probs.append(PartitionProblem(points=mesh.points, k=k,
                                      epsilon=0.03, seed=10 + i,
                                      name=mesh.name))
    return probs


def _run_mode(problems, workload, steps: int, tiers, *,
              cache_slots: int) -> dict:
    server = PartitionServer(tiers=tiers, slots=SLOTS,
                             cache_slots=cache_slots)
    per_step = []
    for t, batch in enumerate(request_stream(problems, workload, steps)):
        t0 = time.perf_counter()
        responses = server.serve(batch)
        dt = time.perf_counter() - t0
        per_step.append({
            "step": t,
            "requests": len(responses),
            "warm_hits": sum(r.warm for r in responses),
            "mean_iters": float(np.mean([r.iters for r in responses])),
            "max_imbalance": float(max(r.imbalance for r in responses)),
            "all_balanced": bool(all(r.balanced for r in responses)),
            "latencies_s": [r.time_s for r in responses],
            "step_time_s": dt,
        })
    return {"per_step": per_step, "server_stats": dict(server.stats)}


def _summarize(warm: dict, cold: dict, steps: int) -> dict:
    wsteps, csteps = warm["per_step"], cold["per_step"]
    steady_w = [r for r in wsteps if r["step"] >= 1]
    steady_c = [r for r in csteps if r["step"] >= 1]
    warm_iters = float(np.mean([r["mean_iters"] for r in steady_w]))
    cold_iters = float(np.mean([r["mean_iters"] for r in steady_c]))
    total_req = sum(r["requests"] for r in wsteps)
    # latency/throughput over the post-compile steady state only
    measured = [r for r in wsteps if r["step"] >= WARMUP_STEPS]
    lats = np.asarray([lat for r in measured for lat in r["latencies_s"]])
    wall = float(sum(r["step_time_s"] for r in measured))
    n_meas = int(sum(r["requests"] for r in measured))
    return {
        "iters_ratio": cold_iters / max(warm_iters, 1e-9),
        "warm_mean_iters": warm_iters,
        "cold_mean_iters": cold_iters,
        "warm_hit_rate": (sum(r["warm_hits"] for r in wsteps)
                          / max(total_req, 1)),
        "warm_all_balanced": bool(all(r["all_balanced"] for r in wsteps)),
        "cold_all_balanced": bool(all(r["all_balanced"] for r in csteps)),
        "problems_per_s": n_meas / max(wall, 1e-9),
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_ms": float(np.percentile(lats, 99) * 1e3),
        "measured_steps": len(measured),
        "requests_measured": n_meas,
        "requests_total": total_req,
    }


def run(quick: bool = False, json_out: bool = False):
    cfg_key = "quick" if quick else "full"
    steps = STEPS[cfg_key]
    tiers = TIERS[cfg_key]
    problems = _fleet(quick)
    workload = MESH.WORKLOADS["drifting_hotspot"]()

    print(f"\n### Partition serving — {len(problems)} tenants x "
          f"{steps} steps, tiers={tiers}, slots={SLOTS} "
          f"(warm slot cache vs all-cold serving)\n")
    warm = _run_mode(problems, workload, steps, tiers,
                     cache_slots=len(problems))
    cold = _run_mode(problems, workload, steps, tiers, cache_slots=0)

    for mode, run_ in (("warm", warm), ("cold", cold)):
        print(f"-- {mode}")
        print(md_table(run_["per_step"],
                       ["step", "requests", "warm_hits", "mean_iters",
                        "max_imbalance", "step_time_s"]))
        print()

    summary = _summarize(warm, cold, steps)
    print(f"cold/warm mean iters: {summary['cold_mean_iters']:.2f} / "
          f"{summary['warm_mean_iters']:.2f}  (ratio = "
          f"{summary['iters_ratio']:.1f}x, claim >= 3x)")
    print(f"warm-hit rate: {summary['warm_hit_rate']:.3f}  "
          f"(steady-state bound {(steps - 1) / steps:.3f})")
    print(f"throughput: {summary['problems_per_s']:.2f} problems/s, "
          f"p50 {summary['p50_ms']:.1f} ms, p99 {summary['p99_ms']:.1f} ms "
          f"over {summary['requests_measured']} steady-state requests")

    # per-step latency lists are for local inspection only — keep the
    # regression file schema-stable and small
    for run_ in (warm, cold):
        for r in run_["per_step"]:
            r.pop("latencies_s", None)
    out = {
        "quick": quick, "steps": steps, "slots": SLOTS,
        "tiers": list(tiers),
        "workload": "drifting_hotspot",
        "tenants": [{"tenant": i, "n": p.n, "k": p.k}
                    for i, p in enumerate(problems)],
        "warm": warm, "cold": cold, "summary": summary,
    }
    save_json("serving", out)
    if json_out:
        save_bench_json("serving", out)
    return out


if __name__ == "__main__":
    run()
