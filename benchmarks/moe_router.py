"""Paper-technique-in-framework benchmark: balanced-k-means MoE routing.

The paper's influence-balancing (Eq. 1) applied to expert routing is an
aux-loss-free load balancer: oversubscribed experts lose influence and
shed tokens. We measure, on a skewed synthetic token distribution
(clustered embeddings so a plain nearest-centroid router is badly
imbalanced):

* token drop fraction at fixed capacity factor,
* max-expert load imbalance,

for (a) linear-logit router, (b) nearest-centroid router without
balancing (influence frozen at 1 — the 'vanilla k-means' ablation), and
(c) the paper's balanced router with influence adaptation over steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.dist.rules import resolve_rules
from repro.launch.mesh import make_host_mesh
from repro.models import model as M  # noqa: F401 (params init helpers)
from repro.models import moe as MOE

from .common import md_table, save_json


def _skewed_stream(rng, D, E):
    """Token-embedding generator: E latent clusters with zipf-ish mass.
    Returns (sample_fn(B, S), data_centroids) — the paper seeds centers
    from the data (SFC-strided points), so the router's centroids are
    initialized from sampled tokens, not cold noise."""
    centers = rng.standard_normal((E, D)) * 2.0
    p = 1.0 / np.arange(1, E + 1)
    p /= p.sum()

    def sample(B, S):
        ids = rng.choice(E, size=(B, S), p=p)
        x = centers[ids] + 0.3 * rng.standard_normal((B, S, D))
        return jnp.asarray(x, jnp.float32)

    # data-derived centroid seeds: one sampled token per latent cluster
    seeds = centers + 0.3 * rng.standard_normal((E, D))
    return sample, jnp.asarray(seeds, jnp.float32)


def run(steps: int = 40, quick: bool = False):
    if quick:
        steps = 15
    arch = "granite_moe_3b_a800m"
    cfg = configs.get_config(arch, smoke=True)
    m = cfg.moe
    E = m.n_experts
    B, S, D = 8, 64, cfg.d_model
    mesh = make_host_mesh()
    rules = resolve_rules(mesh, cfg, "train")
    rng = np.random.default_rng(0)

    key = jax.random.PRNGKey(0)
    counter = [0]

    def create(shape, axes, scale, init="normal"):
        counter[0] += 1
        return jax.random.normal(jax.random.fold_in(key, counter[0]),
                                 shape) * 0.05
    params = MOE.moe_params(cfg, create)
    sample, seeds = _skewed_stream(rng, D, E)
    params["centroids"] = seeds      # paper-style: centers seeded from data

    apply_fn = jax.jit(lambda p, x, infl: MOE.moe_apply(p, x, cfg, rules,
                                                        infl))

    rows = []
    for mode in ("linear", "kmeans_frozen", "kmeans_balanced"):
        infl = jnp.ones(E, jnp.float32)
        drops, imbs = [], []
        for t in range(steps):
            x = sample(B, S)
            if mode == "linear":
                import dataclasses
                cfg_l = dataclasses.replace(
                    cfg, moe=dataclasses.replace(m, router="linear"))
                out, ninf, st = jax.jit(
                    lambda p, x: MOE.moe_apply(p, x, cfg_l, rules, None))(
                        params, x)
            else:
                out, ninf, st = apply_fn(params, x, infl)
                if mode == "kmeans_balanced" and ninf is not None:
                    infl = ninf
            drops.append(float(st["dropped_frac"]))
            imbs.append(float(st["load_imbalance"]))
        rows.append({"router": mode,
                     "drop_frac_first5": float(np.mean(drops[:5])),
                     "drop_frac_last5": float(np.mean(drops[-5:])),
                     "imb_first5": float(np.mean(imbs[:5])),
                     "imb_last5": float(np.mean(imbs[-5:]))})
        print(f"  {mode:16s} drop {np.mean(drops[:5]):.3f} -> "
              f"{np.mean(drops[-5:]):.3f}  imb {np.mean(imbs[:5]):.2f} -> "
              f"{np.mean(imbs[-5:]):.2f}")

    print("\n### MoE router benchmark — paper Eq. (1) as aux-loss-free "
          "expert balancing\n")
    print(md_table(rows, ["router", "drop_frac_first5", "drop_frac_last5",
                          "imb_first5", "imb_last5"]))
    save_json("moe_router", rows)
    return rows


if __name__ == "__main__":
    run()
