"""Dynamic repartitioning benchmark: warm-started Geographer vs cold
restart on the drifting-hotspot workload (DESIGN.md §8).

A simulation whose load drifts every step must repartition cheaply while
migrating little data. This benchmark drives
``core.timeseries.simulate_loadbalance`` twice over the same
drifting-Gaussian-hotspot weight field — once warm-started from each
previous step's (centers, influence), once cold-restarted (fresh SFC
bootstrap + relabel matching, the fair baseline) — and reports, per step:
movement iterations, migration volume/fraction, imbalance, wall time.

The headline claims gated by ``tools/bench_compare.py`` against
``benchmarks/baselines/BENCH_repartition.json``:

* warm needs >= 3x fewer balanced-k-means movement iterations, and
* warm migrates <= 30% of the weight a cold restart moves,
* while staying balanced (imbalance <= epsilon) at every step.
"""
from __future__ import annotations

from repro.core import meshes as MESH
from repro.partition import PartitionProblem

from .common import md_table, save_bench_json, save_json

STEPS = {"quick": 8, "full": 12}


def _strip(sim: dict) -> dict:
    """JSON-serializable view of a simulate_loadbalance() output."""
    out = {k: v for k, v in sim.items() if k != "final_result"}
    return out


def run(quick: bool = False, json_out: bool = False):
    n, k = (8_000, 16) if quick else (30_000, 16)
    steps = STEPS["quick" if quick else "full"]
    from repro.core.timeseries import simulate_loadbalance

    mesh = MESH.REGISTRY["delaunay2d"](n, seed=5)
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03, seed=5)
    workload = MESH.WORKLOADS["drifting_hotspot"]()

    print(f"\n### Dynamic repartitioning — {type(workload).__name__}, "
          f"n={prob.n} k={k} T={steps} (warm restart vs cold restart)\n")
    runs = {}
    for mode in ("warm", "cold"):
        sim = simulate_loadbalance(prob, workload, steps, mode=mode)
        runs[mode] = _strip(sim)
        print(f"-- {mode}")
        print(md_table(sim["per_step"],
                       ["step", "iters", "migration_fraction",
                        "retained_fraction", "imbalance", "time_s"]))
        print()

    sw, sc = runs["warm"]["summary"], runs["cold"]["summary"]
    summary = {
        "iters_ratio": sc["mean_iters"] / max(sw["mean_iters"], 1e-9),
        "migration_ratio": (sw["mean_migration_fraction"]
                            / max(sc["mean_migration_fraction"], 1e-9)),
        "warm_mean_iters": sw["mean_iters"],
        "cold_mean_iters": sc["mean_iters"],
        "warm_mean_migration_fraction": sw["mean_migration_fraction"],
        "cold_mean_migration_fraction": sc["mean_migration_fraction"],
        "warm_all_balanced": sw["all_balanced"],
        "cold_all_balanced": sc["all_balanced"],
    }
    print(f"warm/cold mean iters: {sw['mean_iters']:.2f} / "
          f"{sc['mean_iters']:.2f}  (cold/warm = "
          f"{summary['iters_ratio']:.1f}x, claim >= 3x)")
    print(f"warm/cold mean migration fraction: "
          f"{sw['mean_migration_fraction']:.4f} / "
          f"{sc['mean_migration_fraction']:.4f}  (warm/cold = "
          f"{summary['migration_ratio']:.3f}, claim <= 0.30)")

    out = {"workload": "drifting_hotspot", "n": prob.n, "k": k,
           "steps": steps, "epsilon": prob.epsilon, "quick": quick,
           "warm": runs["warm"], "cold": runs["cold"], "summary": summary}
    save_json("repartition", out)
    if json_out:
        save_bench_json("repartition", out)
    return out


if __name__ == "__main__":
    run()
