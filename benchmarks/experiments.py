"""Paper §5 comparison-matrix experiment harness (CLI wrapper around
``repro.eval.experiments``): every registered method × the expanded mesh
zoo, each cell evaluated with the sharded in-graph metrics, emitting
``BENCH_experiments.json`` for the CI regression + paper-trend gate
(``tools/bench_compare.py compare_experiments``).

    PYTHONPATH=src python -m benchmarks.experiments [--quick] [--json]
    PYTHONPATH=src python -m benchmarks.experiments --n 2000 --k 8
"""
from __future__ import annotations

from .common import md_table, save_bench_json, save_json

ROW_COLS = ["family", "graph", "tool", "cut", "maxCommVol", "totalCommVol",
            "boundaryNodes", "imbalance", "time_partition_s",
            "time_refine_s", "time_eval_s"]


def run(n: int = 20_000, k: int = 32, quick: bool = False,
        json_out: bool = False, seed: int = 0,
        eval_devices: int | None = None) -> dict:
    # imported here so main() can force virtual devices before the first
    # jax import (repro.eval pulls in jax transitively)
    from repro.eval.experiments import CELL_METRICS, run_matrix
    if quick:
        n, k = 4_000, 16
    out = run_matrix(n, k, eval_devices=eval_devices, seed=seed,
                     quick=quick)
    for r in out["rows"]:
        print(f"  {r['graph']:18s} {r['tool']:12s} cut={r['cut']:8d} "
              f"maxCV={r['maxCommVol']:6d} sumCV={r['totalCommVol']:8d} "
              f"bnd={r['boundaryNodes']:7d} imb={r['imbalance']:.3f} "
              f"t={r['time_partition_s']:.2f}s "
              f"eval={r['time_eval_s']:.2f}s@{out['eval_devices']}dev")
    save_json("experiments", out)
    if json_out:
        save_bench_json("experiments", out)
    print(f"\n### §5 comparison matrix (n={out['n']}, k={out['k']}, "
          f"eval over {out['eval_devices']} shards)\n")
    print(md_table(out["rows"], ROW_COLS))
    print("\n### Paper-trend summary (geographer metric / tool metric, "
          "geomean over the zoo; < 1 means geographer better)\n")
    trend_rows = [dict({"tool": tool}, **ratios)
                  for tool, ratios in out["summary"]["geo_over_tool"].items()]
    print(md_table(trend_rows, ["tool", *CELL_METRICS]))
    if out["summary"]["geo_refined_over_tool"]:
        print("\n### Refined trend (refined geographer / unrefined tool, "
              "geomean over the zoo — the tightened CI ceiling)\n")
        rt_rows = [dict({"tool": tool}, **ratios) for tool, ratios
                   in out["summary"]["geo_refined_over_tool"].items()]
        print(md_table(rt_rows, ["tool", *CELL_METRICS]))
    if out["summary"]["refined_over_unrefined"]:
        print("\n### Refinement gain (refined / unrefined per tool, "
              "geomean over the zoo; < 1 means refinement helps)\n")
        gain_rows = [dict({"tool": tool}, **ratios) for tool, ratios
                     in out["summary"]["refined_over_unrefined"].items()]
        print(md_table(gain_rows, ["tool", *CELL_METRICS]))
    return out


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (n=4000, k=16)")
    ap.add_argument("--json", action="store_true",
                    help="emit BENCH_experiments.json")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-devices", type=int, default=None,
                    help="shard count for metric evaluation "
                         "(default min(4, visible devices))")
    args = ap.parse_args()
    # must precede the first jax import (run() imports repro.eval lazily)
    from repro.envflags import force_virtual_devices
    force_virtual_devices(8)
    run(n=args.n, k=args.k, quick=args.quick, json_out=args.json,
        seed=args.seed, eval_devices=args.eval_devices)


if __name__ == "__main__":
    main()
