"""Paper §5.3.2 "Components" + §4.3 bound-skip-rate claims.

Breaks one Geographer run into its phases (Hilbert keys, global sort,
balanced k-means) and reports the Hamerly-bound skip fraction per
movement iteration — the paper reports ~80% of inner loops skipped,
rising in later iterations.
"""
from __future__ import annotations

import numpy as np

from repro.core import meshes as MESH
from repro.core.balanced_kmeans import BKMConfig
from repro.core.partitioner import geographer_partition
from repro.core.sfc import hilbert_index_np

from .common import md_table, save_json, timer


def run(n: int = 40_000, k: int = 64, quick: bool = False):
    if quick:
        n, k = 10_000, 32
    mesh = MESH.REGISTRY["delaunay2d"](n, seed=3)

    t0 = timer()
    keys = hilbert_index_np(mesh.points)
    t_keys = timer() - t0
    t0 = timer()
    np.argsort(keys, kind="stable")
    t_sort = timer() - t0
    t0 = timer()
    part, stats = geographer_partition(mesh.points, k,
                                       cfg=BKMConfig(k=k, epsilon=0.03),
                                       return_stats=True)
    t_kmeans = timer() - t0
    total = t_keys + t_sort + t_kmeans
    comps = [{"component": "hilbert_keys", "time_s": t_keys,
              "share": t_keys / total},
             {"component": "sort/redistribute", "time_s": t_sort,
              "share": t_sort / total},
             {"component": "balanced_kmeans", "time_s": t_kmeans,
              "share": t_kmeans / total}]
    print("\n### §5.3.2 analogue — component shares\n")
    print(md_table(comps, ["component", "time_s", "share"]))

    iters = int(stats["iters"])
    hist = stats["history"]
    skip_rows = [{"iter": i,
                  "skip_fraction": float(hist["skip_fraction"][i]),
                  "balance_iters": float(hist["balance_iters"][i]),
                  "imbalance": float(hist["imbalance"][i])}
                 for i in range(iters)]
    print("\n### §4.3 claim — Hamerly-bound skip fraction per iteration "
          "(paper: ~80%, higher late)\n")
    print(md_table(skip_rows, ["iter", "skip_fraction", "balance_iters",
                               "imbalance"]))
    late = [r["skip_fraction"] for r in skip_rows[len(skip_rows) // 2:]]
    summary = {"components": comps, "skip_per_iter": skip_rows,
               "late_phase_skip_fraction": float(np.mean(late)) if late
               else None,
               "final_imbalance": float(stats["final_imbalance"])}
    print(f"\nlate-phase mean skip fraction: "
          f"{summary['late_phase_skip_fraction']:.3f} "
          f"(paper claims ~0.8); final imbalance "
          f"{summary['final_imbalance']:.4f} (target <= 0.03)")
    save_json("components", summary)
    return summary


if __name__ == "__main__":
    run()
