"""Shared benchmark helpers: timing, markdown tables, result storage."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")
# machine-readable BENCH_*.json land here (repo root by default) — the CI
# bench-gate job uploads them as artifacts and tools/bench_compare.py
# diffs them against benchmarks/baselines/
BENCH_JSON_DIR = os.environ.get("REPRO_BENCH_JSON_DIR", ".")


def timer():
    return time.perf_counter()


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


def save_bench_json(name: str, obj) -> str:
    """Write the machine-readable ``BENCH_<name>.json`` regression file."""
    os.makedirs(BENCH_JSON_DIR, exist_ok=True)
    path = os.path.join(BENCH_JSON_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float, sort_keys=True)
    print(f"[bench-json] wrote {path}")
    return path


def md_table(rows: list[dict], cols: list[str], floatfmt: str = ".4g") -> str:
    def fmt(v):
        if isinstance(v, float):
            return format(v, floatfmt)
        return str(v)
    head = "| " + " | ".join(cols) + " |"
    sep = "|" + "|".join(["---"] * len(cols)) + "|"
    body = ["| " + " | ".join(fmt(r.get(c, "")) for c in cols) + " |"
            for r in rows]
    return "\n".join([head, sep] + body)


def geomean(xs) -> float:
    import numpy as np
    xs = np.asarray([x for x in xs if x > 0], dtype=np.float64)
    if xs.size == 0:
        return 0.0
    return float(np.exp(np.mean(np.log(xs))))
