"""Paper Tables 1-2 + Figure 2 analogue: partition quality of Geographer
(balanced k-means) vs the geometric baselines (RCB / RIB / HSFC / MJ) over
2D / 2.5D-weighted / 3D mesh classes — all through the unified engine
(``repro.partition.partition(problem, method=...)``), plus the
hierarchical k = k1 x k2 mode (coarse Geographer + batched vmap
refinement) as its own tool row.

Metrics per (mesh, tool): wall time, edge cut, max/total communication
volume, diameter (harmonic mean over blocks), imbalance — the paper's
metric set minus the physical SpMV timing (no MPI cluster here; the
total/max comm volume IS the paper's proxy for it).

Figure-2 analogue: per class, geometric-mean ratio of each metric vs the
Geographer baseline.
"""
from __future__ import annotations

from repro.core import meshes as MESH
from repro.partition import PartitionProblem, factor_k, partition

from .common import geomean, md_table, save_bench_json, save_json, timer

CLASSES = {
    "2d": ["tri", "refined2d", "rgg2d", "delaunay2d"],
    "2.5d": ["climate25d"],
    "3d": ["delaunay3d", "rgg3d"],
}

METRICS = ["cut", "maxCommVol", "totalCommVol", "diameter_harmonic_mean"]


def run_tool(tool: str, mesh, k: int, seed: int = 0):
    prob = PartitionProblem.from_mesh(mesh, k, epsilon=0.03, seed=seed)
    t0 = timer()
    if tool == "hierarchical":
        res = partition(prob, hierarchy=factor_k(k))
    else:
        res = partition(prob, method=tool)
    dt = timer() - t0
    ev = dict(res.evaluate(with_diameter=True))
    ev.update(tool=tool, time_s=dt, graph=mesh.name, k=k, n=mesh.n)
    return ev


def run(n: int = 20_000, k: int = 32, seeds=(0,), quick: bool = False,
        json_out: bool = False):
    if quick:
        n, k, seeds = 6_000, 16, (0,)
    tools = ["geographer", "hierarchical", "rcb", "rib", "hsfc", "mj"]
    rows = []
    for cls, gens in CLASSES.items():
        for g in gens:
            for seed in seeds:
                mesh = MESH.REGISTRY[g](n, seed=seed)
                for tool in tools:
                    ev = run_tool(tool, mesh, k, seed)
                    ev["class"] = cls
                    rows.append(ev)
                    print(f"  {mesh.name:16s} {tool:10s} cut={ev['cut']:8d} "
                          f"sumCV={ev['totalCommVol']:8d} "
                          f"imb={ev['imbalance']:.3f} t={ev['time_s']:.2f}s")

    # Figure 2 analogue: per-class geometric-mean ratios vs geographer
    ratios = []
    for cls in CLASSES:
        for tool in tools[1:]:
            row = {"class": cls, "tool": tool}
            for met in METRICS:
                rs = []
                for r in rows:
                    if r["class"] != cls or r["tool"] != tool:
                        continue
                    base = next(b for b in rows
                                if b["class"] == cls
                                and b["graph"] == r["graph"]
                                and b["k"] == r["k"]
                                and b["tool"] == "geographer")
                    if base[met] > 0:
                        rs.append(r[met] / base[met])
                row[met + "_ratio"] = geomean(rs)
            ratios.append(row)

    out = {"rows": rows, "ratios_vs_geographer": ratios,
           "n": n, "k": k}
    save_json("quality", out)
    if json_out:
        save_bench_json("quality", out)
    cols = ["graph", "tool", "time_s", "cut", "maxCommVol", "totalCommVol",
            "diameter_harmonic_mean", "imbalance"]
    print("\n### Tables 1-2 analogue (per-mesh quality)\n")
    print(md_table(rows, cols))
    print("\n### Figure 2 analogue (geo-mean metric ratios vs Geographer; "
          ">1 means Geographer better)\n")
    print(md_table(ratios, ["class", "tool"] +
                   [m + "_ratio" for m in METRICS]))
    return out


if __name__ == "__main__":
    run()
